"""Docs rot-check: every ```python fenced block in the Markdown docs must at
least parse.

    python tools/check_docs.py [paths...]

Defaults to README.md + docs/*.md.  Blocks are compile()d, not executed —
snippets may reference variables established in surrounding prose, but they
cannot silently drift into syntax that no longer exists.  Exit code 1 lists
every offending file/line.  Run by the CI docs job and tests/test_docs.py.
"""

from __future__ import annotations

import pathlib
import re
import sys
from typing import Iterator, List, Tuple

_FENCE = re.compile(r"^```(\w*)\s*$")


def python_blocks(text: str) -> Iterator[Tuple[int, str]]:
    """Yield (start_line, source) for each ```python fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m and m.group(1) in ("python", "py"):
            start = i + 2  # 1-based line of the block's first source line
            body: List[str] = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield start, "\n".join(body)
        i += 1


def default_paths(root: pathlib.Path) -> List[pathlib.Path]:
    paths = [root / "README.md"]
    paths += sorted((root / "docs").glob("*.md"))
    return [p for p in paths if p.exists()]


def check(paths: List[pathlib.Path]) -> List[str]:
    errors = []
    total = 0
    for path in paths:
        for line, src in python_blocks(path.read_text()):
            total += 1
            try:
                compile(src, f"{path}:{line}", "exec")
            except SyntaxError as exc:
                errors.append(f"{path}:{line}: {exc.msg} (block line {exc.lineno})")
    print(f"[check_docs] {total} python block(s) across {len(paths)} file(s)")
    return errors


def main(argv: List[str]) -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    paths = [pathlib.Path(a) for a in argv] or default_paths(root)
    errors = check(paths)
    for err in errors:
        print(f"[check_docs] FAIL {err}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
