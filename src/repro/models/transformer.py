"""Model assembly: parameter templates, scan-based stacks, train/decode steps.

One code path covers all ten assigned architectures:

  dense / vlm / audio   — GQA attention + SwiGLU FFN blocks
  moe                   — GQA or MLA attention + routed expert FFN
  ssm                   — Mamba2 SSD blocks (attention-free)
  hybrid                — Mamba2 blocks + a single *shared* attention+FFN
                          block applied every ``attn_every`` layers (Zamba2)

Parameters are layer-stacked pytrees (leading axis = n_layers) consumed by
``jax.lax.scan`` — constant compile time in depth, which is what makes the
512-device dry-run of a 94-layer MoE tractable.  ``param_specs`` builds the
same pytree as ShapeDtypeStructs (no allocation) for the dry-run;
``init_params`` materializes it for real runs.

[vlm]/[audio] frontends are stubs per the assignment: ``forward`` accepts
precomputed ``embeddings`` in place of token ids.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.kernels import lm_head_ce
from repro.models import attention, layers, moe, ssm

Constrain = Callable[[jax.Array, str], jax.Array]
_id: Constrain = lambda x, tag: x

__all__ = [
    "param_template",
    "init_params",
    "param_specs",
    "quantize_params",
    "forward",
    "init_cache",
    "init_paged_cache",
    "loss_fn",
    "train_step_fn",
    "decode_step_fn",
    "paged_decode_step_fn",
]


# ------------------------------------------------------------ param layout --
def _lin(cfg, d_in, d_out):
    """(storage_shape, fan_in, dip_meta) for a linear under the config's
    weight storage.  ``dip_meta`` is ``(d_in, d_out, perm_tile)`` when the
    weight lives as an ``api.DipWeight``, else None."""
    if cfg.uses_dip_storage:
        shape = api.DipWeight.storage_dims(d_in, d_out)
        return shape, d_in, (d_in, d_out, api.PERM_TILE)
    return (d_in, d_out), d_in, None


def param_template(cfg) -> Dict[str, Any]:
    """Nested dict: leaf = (shape, dtype_str, fan_in[, dip_meta]).
    Layer-stacked; ``shape`` is the *storage* shape (padded for DiP)."""
    d, v = cfg.d_model, cfg.padded_vocab
    pdt = cfg.param_dtype
    t: Dict[str, Any] = {
        "embed": ((v, d), pdt, d),
        "final_norm": ((d,), pdt, None),
    }
    if not cfg.tie_embeddings:
        shape, fan, dip = _lin(cfg, d, v)
        t["lm_head"] = (shape, pdt, fan, dip)

    def stacked(shape, fan, L, dip=None):
        return ((L,) + shape, pdt, fan, dip)

    L = cfg.n_layers
    blk: Dict[str, Any] = {}

    if cfg.ssm_state:  # mamba2 blocks (ssm and hybrid families)
        dims = ssm.ssm_dims(cfg)
        nl = L
        s_in, f_in, dip_in = _lin(cfg, d, dims["in_dim"])
        s_out, f_out, dip_out = _lin(cfg, dims["d_inner"], d)
        blk.update(
            norm_in=stacked((d,), None, nl),
            in_proj=stacked(s_in, f_in, nl, dip_in),
            conv_w=stacked((cfg.ssm_conv, dims["conv_dim"]), cfg.ssm_conv, nl),
            conv_b=stacked((dims["conv_dim"],), None, nl),
            dt_bias=stacked((dims["heads"],), None, nl),
            A_log=stacked((dims["heads"],), None, nl),
            D=stacked((dims["heads"],), None, nl),
            norm=stacked((dims["d_inner"],), None, nl),
            out_proj=stacked(s_out, f_out, nl, dip_out),
        )
        t["layers"] = blk
        if cfg.is_hybrid:
            hd = cfg.resolved_head_dim
            sh: Dict[str, Any] = {"attn_norm": ((d,), pdt, None), "ffn_norm": ((d,), pdt, None)}
            for nm, (di, do) in dict(
                wq=(d, cfg.n_heads * hd), wk=(d, cfg.n_kv_heads * hd),
                wv=(d, cfg.n_kv_heads * hd), wo=(cfg.n_heads * hd, d),
                w_gate=(d, cfg.d_ff), w_up=(d, cfg.d_ff), w_down=(cfg.d_ff, d),
            ).items():
                shape, fan, dip = _lin(cfg, di, do)
                sh[nm] = (shape, pdt, fan, dip)
            t["shared_attn"] = sh
        return t

    # transformer families
    hd = cfg.resolved_head_dim
    blk["attn_norm"] = stacked((d,), None, L)
    blk["ffn_norm"] = stacked((d,), None, L)
    if cfg.use_mla:
        dn, dr, dvh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        rr = cfg.kv_lora_rank
        for nm, (di, do) in dict(
            wq=(d, cfg.n_heads * (dn + dr)), w_dkv=(d, rr), w_krope=(d, dr),
            w_uk=(rr, cfg.n_heads * dn), w_uv=(rr, cfg.n_heads * dvh),
            wo=(cfg.n_heads * dvh, d),
        ).items():
            shape, fan, dip = _lin(cfg, di, do)
            blk[nm] = stacked(shape, fan, L, dip)
    else:
        for nm, (di, do) in dict(
            wq=(d, cfg.n_heads * hd), wk=(d, cfg.n_kv_heads * hd),
            wv=(d, cfg.n_kv_heads * hd), wo=(cfg.n_heads * hd, d),
        ).items():
            shape, fan, dip = _lin(cfg, di, do)
            blk[nm] = stacked(shape, fan, L, dip)
        if cfg.qkv_bias:
            blk["bq"] = stacked((cfg.n_heads * hd,), None, L)
            blk["bk"] = stacked((cfg.n_kv_heads * hd,), None, L)
            blk["bv"] = stacked((cfg.n_kv_heads * hd,), None, L)

    if cfg.is_moe:
        e, ffe = cfg.n_experts, cfg.d_ff_expert
        blk["router"] = stacked((d, e), d, L)
        blk["w_gate"] = stacked((e, d, ffe), d, L)
        blk["w_up"] = stacked((e, d, ffe), d, L)
        blk["w_down"] = stacked((e, ffe, d), ffe, L)
        if cfg.n_shared_experts:
            sff = cfg.n_shared_experts * ffe
            for nm, (di, do) in dict(
                shared_w_gate=(d, sff), shared_w_up=(d, sff), shared_w_down=(sff, d)
            ).items():
                shape, fan, dip = _lin(cfg, di, do)
                blk[nm] = stacked(shape, fan, L, dip)
    else:
        for nm, (di, do) in dict(
            w_gate=(d, cfg.d_ff), w_up=(d, cfg.d_ff), w_down=(cfg.d_ff, d)
        ).items():
            shape, fan, dip = _lin(cfg, di, do)
            blk[nm] = stacked(shape, fan, L, dip)

    t["layers"] = blk
    return t


def _map_template(t, fn):
    if isinstance(t, dict):
        return {k: _map_template(v, fn) for k, v in t.items()}
    return fn(*t)


def param_specs(cfg) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation).
    DiP-stored linears appear as ``DipWeight`` (or, under
    ``cfg.quantization``, ``QuantizedDipWeight``) nodes wrapping the spec of
    their (padded) storage, mirroring ``init_params`` exactly."""
    scheme = cfg.quant_scheme

    def mk(shape, dt, fan, dip=None):
        if dip is not None and scheme is not None:
            info = api.quant.scheme_info(scheme)
            data = jax.ShapeDtypeStruct(shape, jnp.dtype(info.storage_dtype))
            scale = jax.ShapeDtypeStruct(shape[:-2] + (1, shape[-1]), jnp.float32)
            return api.QuantizedDipWeight(data, scale, *dip, scheme=scheme)
        spec = jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
        return api.DipWeight(spec, *dip) if dip is not None else spec

    return _map_template(param_template(cfg), mk)


def quantize_params(params: Dict[str, Any], scheme: str) -> Dict[str, Any]:
    """Quantize every DiP-stored projection to ``scheme`` storage.

    Only ``DipWeight`` nodes are quantized (embeddings, norms, biases, and
    the SSM scalars stay float — they are not DiP-array operands); already
    quantized nodes pass through ``quant.quantize`` untouched.  This is the
    offline calibration step: run it once at init / checkpoint load, never
    per forward.
    """
    dip_types = (api.DipWeight, api.QuantizedDipWeight)
    return jax.tree_util.tree_map(
        lambda t: api.quant.quantize(t, scheme) if isinstance(t, dip_types) else t,
        params,
        is_leaf=lambda t: isinstance(t, dip_types),
    )


def init_params(key: jax.Array, cfg) -> Dict[str, Any]:
    """Materialized parameters (truncated-normal fan-in scaling; norms at 1).

    DiP-stored weights are initialized in natural layout then converted with
    ``api.DipWeight.from_natural`` — the offline permutation step of paper
    Fig. 3, run once at init / checkpoint-load, never per step.
    """
    template = param_template(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(
        template, is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
    )
    keys = jax.random.split(key, len(leaves))

    def make(leaf, k):
        shape, dt, fan = leaf[:3]
        dip = leaf[3] if len(leaf) > 3 else None
        dt = jnp.dtype(dt)
        if fan is None:  # norms / biases / scalars
            init = jnp.ones(shape, dt)
            return init

        # special-cased SSM scalars by shape heuristics handled below
        scale = (1.0 / max(1, fan)) ** 0.5
        if dip is not None:
            d_in, d_out, perm_tile = dip
            nat_shape = shape[:-2] + (d_in, d_out)
            nat = (
                jax.random.truncated_normal(k, -2, 2, nat_shape, jnp.float32) * scale
            ).astype(dt)
            return api.DipWeight.from_natural(nat, perm_tile)
        return (jax.random.truncated_normal(k, -2, 2, shape, jnp.float32) * scale).astype(dt)

    params = jax.tree_util.tree_unflatten(treedef, [make(l, k) for l, k in zip(leaves, keys)])

    # SSM-specific parameter semantics
    if cfg.ssm_state:
        lyr = params["layers"]
        nl = cfg.n_layers
        dims = ssm.ssm_dims(cfg)
        k1, k2 = jax.random.split(key)
        lyr["A_log"] = jnp.log(
            jax.random.uniform(k1, (nl, dims["heads"]), jnp.float32, 1.0, 16.0)
        ).astype(jnp.dtype(cfg.param_dtype))
        dt0 = jax.random.uniform(k2, (nl, dims["heads"]), jnp.float32, 1e-3, 0.1)
        lyr["dt_bias"] = (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(jnp.dtype(cfg.param_dtype))
        lyr["conv_b"] = jnp.zeros_like(lyr["conv_b"])
    if cfg.qkv_bias and "bq" in params.get("layers", {}):
        for nm in ("bq", "bk", "bv"):
            params["layers"][nm] = jnp.zeros_like(params["layers"][nm])
    if cfg.quant_scheme is not None:
        params = quantize_params(params, cfg.quant_scheme)
    return params


# ---------------------------------------------------------------- forward ---
def _fuses_rmsnorm(cfg) -> bool:
    """Whether the configured backend fuses the RMSNorm prologue into its
    kernels' load stage (``api.backend_prologues``).  When it does, the
    blocks hand the UN-normalized residual stream plus the norm gain to the
    projections and the normed (B, S, d) tensor never round-trips HBM; when
    it does not, the blocks normalize up front exactly as before (passing
    the prologue anyway would decompose to one rms_norm PER projection)."""
    return "rmsnorm" in api.get_backend(cfg.matmul_backend).prologues


def _transformer_block(x, lp, cfg, *, positions, rope, cache, kv_chunk,
                       constrain, plan=None, unroll=False, attn_backend=None):
    fuse_norm = _fuses_rmsnorm(cfg)
    attn_in, attn_g = (
        (x, lp["attn_norm"]) if fuse_norm
        else (layers.rms_norm(x, lp["attn_norm"], cfg.norm_eps), None)
    )
    # mid-block residual fused into the attention out-projection's flush
    # (one HBM write instead of write + re-read + add); the fused result is
    # left to propagation like the explicit add was (constraining it forces
    # an extra scatter/gather pair per layer — §Perf iter 4, refuted)
    attn = attention.mla_attention if cfg.use_mla else attention.gqa_attention
    x, new_cache = attn(
        attn_in, lp, cfg, positions=positions, cache=cache,
        kv_chunk=kv_chunk, constrain=constrain, unroll=unroll,
        rope=rope, residual=x, norm=attn_g, attn_backend=attn_backend,
    )
    if cfg.is_moe:
        # the router and the experts both read the normed stream; MoE keeps
        # the explicit norm (fusing it into each expert dispatch would
        # recompute it per projection)
        ffn_in = layers.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        f, aux, _ = moe.moe_ffn(ffn_in, lp, cfg, plan=plan,
                                constrain=constrain)
        x = x + f
    else:
        ffn_in, ffn_g = (
            (x, lp["ffn_norm"]) if fuse_norm
            else (layers.rms_norm(x, lp["ffn_norm"], cfg.norm_eps), None)
        )
        # skip connection fused into the down-projection
        x = moe.dense_ffn(ffn_in, lp, cfg, constrain=constrain, residual=x,
                          norm=ffn_g)
        aux = jnp.zeros((), jnp.float32)
    # the scan carry is saved per layer for backward — constraining it keeps
    # the saved residuals in the sequence-sharded layout (16x less memory)
    return constrain(x, "act_btd"), new_cache, aux


def _mamba_block(x, lp, cfg, *, cache, constrain):
    inner_in = layers.rms_norm(x, lp["norm_in"], cfg.norm_eps)
    # skip connection fused into ssd_block's out-projection
    return ssm.ssd_block(inner_in, lp, cfg, cache=cache, constrain=constrain,
                         residual=x)


def forward(
    params: Dict[str, Any],
    cfg,
    *,
    tokens: Optional[jax.Array] = None,        # (B, S) int32
    embeddings: Optional[jax.Array] = None,    # (B, S, d) — [vlm]/[audio] stubs
    cache: Optional[Dict] = None,              # layer-stacked cache pytree
    kv_chunk: int = 0,
    plan=None,                                 # repro.distributed.ShardingPlan
    constrain: Optional[Constrain] = None,     # legacy hook; plan wins
    unroll: bool = False,                      # dry-run cost-probe mode: unroll
                                               # layer scans so XLA cost analysis
                                               # counts every layer (see
                                               # launch/dryrun.py probe logic)
    logits_positions: str = "all",             # "all" | "last" — serving prefill
                                               # needs only the next-token logits
    return_hidden: bool = False,               # skip the lm_head: return the
                                               # final-normed hidden states for
                                               # the fused lm_head+CE loss
                                               # (kernels.lm_head_ce)
    attn_backend: Optional[str] = None,        # api.attention backend for the
                                               # attention core ("flash" routes
                                               # serving prefill through the
                                               # fused kernel; forward-only)
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (logits, new_cache, aux_loss).

    Distribution enters through ``plan``: its activation constraints replace
    the old bare ``constrain`` callback, and DiP weights that carry the
    plan's per-weight metadata dispatch the explicit sharded backends when
    ``cfg.matmul_backend`` names one (``dip_tp`` / ``dip_sp`` /
    ``dip_fsdp`` / ``dip_ep``)."""
    constrain = layers.resolve_constrain(plan, constrain)
    cd = jnp.dtype(cfg.compute_dtype)
    if embeddings is not None:
        x = embeddings.astype(cd)
    else:
        x = params["embed"].astype(cd)[tokens]
    x = constrain(x, "act_btd")
    b, s = x.shape[:2]

    start = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = start + jnp.arange(s, dtype=jnp.int32)

    remat = cfg.remat == "block"

    if cfg.ssm_state:
        x, new_layer_caches = _scan_mamba(params, cfg, x, cache, remat, constrain,
                                          unroll, kv_chunk, attn_backend)
        if cfg.is_hybrid:
            pass  # handled inside _scan_mamba
        aux_total = jnp.zeros((), jnp.float32)
    else:
        # RoPE cos/sin hoisted out of the per-layer path: position-only, so
        # ONE table per forward (a scan constant) instead of n_layers
        # transcendental sweeps
        rope_dim = cfg.qk_rope_head_dim if cfg.use_mla else cfg.resolved_head_dim
        rope = layers.rope_tables(positions, rope_dim, cfg.rope_theta)

        def block(carry, xs):
            x, aux = carry
            lp, lcache = xs
            if lcache is not None:
                lcache = dict(lcache, pos=start)  # all layers share the position
            x, new_cache, aux_i = _transformer_block(
                x, lp, cfg, positions=positions, rope=rope, cache=lcache,
                kv_chunk=kv_chunk, constrain=constrain, plan=plan,
                unroll=unroll, attn_backend=attn_backend,
            )
            if new_cache is not None:
                new_cache = _strip_pos(new_cache)
            return (x, aux + aux_i), new_cache

        block_fn = jax.checkpoint(block) if remat else block
        layer_caches = cache["layers"] if cache is not None else None
        (x, aux_total), new_layer_caches = jax.lax.scan(
            block_fn, (x, jnp.zeros((), jnp.float32)), (params["layers"], layer_caches),
            unroll=cfg.n_layers if unroll else 1,
        )

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_positions == "last":
        # serving prefill: one row through the lm_head instead of S rows —
        # removes the (B, S, V) logits and their gathers (§Perf pair 3)
        x = x[:, -1:]

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_caches
        new_cache["pos"] = cache["pos"] + s

    if return_hidden:
        # fused lm_head+CE training path: the caller feeds these hidden
        # states straight into kernels.lm_head_ce, so the (B, S, V) logits
        # are never formed at all
        return x, new_cache, aux_total

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        logits = jnp.matmul(
            x, head.astype(cd), preferred_element_type=jnp.float32
        ).astype(jnp.float32)
    else:
        logits = layers.linear(
            x, head, backend=cfg.matmul_backend, compute_dtype=cd,
        ).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the padding lanes (never sampled, -inf in the softmax/loss);
        # keeping the padded width lets the vocab dim shard over any axis
        lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(lane < cfg.vocab_size, logits, -1e30)
    logits = constrain(logits, "logits")
    return logits, new_cache, aux_total


def _scan_mamba(params, cfg, x, cache, remat, constrain, unroll=False,
                kv_chunk=0, attn_backend=None):
    """Scan over mamba blocks; hybrid: shared attn applied per superblock."""
    lp_all = params["layers"]
    lcaches = cache["layers"] if cache is not None else None

    pos_now = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)

    def mblock(x, lp, lcache):
        if lcache is not None:
            lcache = dict(lcache, pos=pos_now)
        x, nc = _mamba_block(x, lp, cfg, cache=lcache, constrain=constrain)
        return x, (_strip_pos(nc) if nc is not None else None)

    mblock = jax.checkpoint(mblock) if remat else mblock

    if not cfg.is_hybrid:
        def body(x, xs):
            lp, lc = xs
            return mblock(x, lp, lc)
        return jax.lax.scan(body, x, (lp_all, lcaches),
                            unroll=cfg.n_layers if unroll else 1)

    # hybrid: group layers into superblocks of attn_every mamba layers,
    # each followed by the single shared attention+FFN block.
    ae = cfg.attn_every
    n_super = cfg.n_layers // ae
    shared = params["shared_attn"]
    b, s = x.shape[:2]
    positions = pos_now + jnp.arange(s, dtype=jnp.int32)
    # hoisted RoPE tables for the shared attention block (scan constant)
    rope = layers.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)

    def regroup(t):
        return t.reshape((n_super, ae) + t.shape[1:])

    lp_grp = jax.tree_util.tree_map(regroup, lp_all)
    # split cache: mamba caches (stacked L) + shared-attn caches (stacked n_super)
    mcache_grp = (
        jax.tree_util.tree_map(regroup, {k: v for k, v in lcaches.items() if k != "attn"})
        if lcaches is not None else None
    )
    acache = lcaches["attn"] if lcaches is not None else None

    fuse_norm = _fuses_rmsnorm(cfg)

    def shared_block(x, sc):
        if sc is not None:
            sc = dict(sc, pos=pos_now)
        attn_in, attn_g = (
            (x, shared["attn_norm"]) if fuse_norm
            else (layers.rms_norm(x, shared["attn_norm"], cfg.norm_eps), None)
        )
        x, new_sc = attention.gqa_attention(
            attn_in, shared, cfg, positions=positions, cache=sc,
            kv_chunk=kv_chunk, constrain=constrain, unroll=unroll,
            rope=rope, residual=x, norm=attn_g, attn_backend=attn_backend,
        )
        ffn_in, ffn_g = (
            (x, shared["ffn_norm"]) if fuse_norm
            else (layers.rms_norm(x, shared["ffn_norm"], cfg.norm_eps), None)
        )
        x = moe.dense_ffn(ffn_in, shared, cfg, constrain=constrain, residual=x,
                          norm=ffn_g)
        return x, (_strip_pos(new_sc) if new_sc is not None else None)

    def superblock(x, xs):
        lp, mc, ac = xs
        def inner(x, ys):
            ilp, imc = ys
            return mblock(x, ilp, imc)
        x, new_mc = jax.lax.scan(inner, x, (lp, mc), unroll=ae if unroll else 1)
        x, new_ac = shared_block(x, ac)
        return x, (new_mc, new_ac)

    x, (new_mc, new_ac) = jax.lax.scan(
        superblock, x, (lp_grp, mcache_grp, acache),
        unroll=n_super if unroll else 1,
    )
    if cache is None:
        return x, None
    new_mc = jax.tree_util.tree_map(
        lambda t: t.reshape((cfg.n_layers,) + t.shape[2:]), new_mc
    )
    new_mc["attn"] = new_ac
    return x, new_mc


# ------------------------------------------------------------------ caches --
def init_cache(cfg, batch: int, max_seq: int) -> Dict[str, Any]:
    """Layer-stacked decode cache (leading axis = n_layers / n_super)."""
    cd = jnp.dtype(cfg.compute_dtype)

    def stack(make, n):
        caches = [make() for _ in range(n)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)

    if cfg.ssm_state:
        base = stack(lambda: _strip_pos(ssm.init_ssm_cache(batch, cfg, cd)), cfg.n_layers)
        if cfg.is_hybrid:
            n_super = cfg.n_layers // cfg.attn_every
            base["attn"] = stack(
                lambda: _strip_pos(
                    attention.init_gqa_cache(
                        batch, cfg.n_kv_heads, max_seq, cfg.resolved_head_dim, cd
                    )
                ),
                n_super,
            )
        layers_cache = base
    elif cfg.use_mla:
        layers_cache = stack(
            lambda: _strip_pos(attention.init_mla_cache(batch, max_seq, cfg, cd)),
            cfg.n_layers,
        )
    else:
        layers_cache = stack(
            lambda: _strip_pos(
                attention.init_gqa_cache(
                    batch, cfg.n_kv_heads, max_seq, cfg.resolved_head_dim, cd
                )
            ),
            cfg.n_layers,
        )
    return {"layers": layers_cache, "pos": jnp.zeros((), jnp.int32)}


def _strip_pos(c: Dict) -> Dict:
    return {k: v for k, v in c.items() if k != "pos"}


def init_paged_cache(cfg, num_blocks: int, block_size: int, *, slots: int,
                     kv_quant: str = "none") -> Dict[str, Any]:
    """Layer-stacked *paged* decode cache for the serving engine.

    Attention K/V (and the MLA latent) live in a shared pool of
    ``num_blocks`` fixed-size blocks indexed through per-slot block tables;
    SSM conv/scan state is O(1) per sequence, so it gets a plain per-slot
    pool (batch axis = ``slots``) rather than pages.  There is no global
    ``pos`` — positions are per-slot and passed to each decode step.  Block 0
    is reserved as the null block (see ``repro.serving.kv_cache``).
    """
    cd = jnp.dtype(cfg.compute_dtype)

    def stack(make, n):
        caches = [make() for _ in range(n)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)

    def gqa_pool():
        return attention.init_paged_gqa_cache(
            num_blocks, block_size, cfg.n_kv_heads, cfg.resolved_head_dim,
            cd, kv_quant,
        )

    if cfg.ssm_state:
        base = stack(lambda: _strip_pos(ssm.init_ssm_cache(slots, cfg, cd)),
                     cfg.n_layers)
        if cfg.is_hybrid:
            n_super = cfg.n_layers // cfg.attn_every
            base["attn"] = stack(gqa_pool, n_super)
        layers_cache = base
    elif cfg.use_mla:
        layers_cache = stack(
            lambda: attention.init_paged_mla_cache(
                num_blocks, block_size, cfg, cd, kv_quant
            ),
            cfg.n_layers,
        )
    else:
        layers_cache = stack(gqa_pool, cfg.n_layers)
    return {"layers": layers_cache}


def paged_decode_step_fn(cfg, *, plan=None, constrain: Optional[Constrain] = None):
    """Returns ``step(params, cache, tokens, positions, block_tables)``
    -> ``(logits, cache)`` — the serving engine's one compiled decode step.

    ``tokens``: (slots, 1) int32; ``positions``: (slots,) int32 absolute
    position of each slot's current token; ``block_tables``:
    (slots, blocks_per_seq) int32 into the paged pools of ``cache`` (from
    :func:`init_paged_cache`).  Each slot attends only to its own blocks with
    its own positions, so the rows are fully independent — free slots point
    at the reserved null block and their logits are garbage the engine
    ignores.  All shapes are static: one compilation serves the pool for the
    whole engine lifetime.
    """
    constrain = layers.resolve_constrain(plan, constrain)
    kvq = cfg.kv_quant

    def step(params, cache, tokens, positions, block_tables):
        cd = jnp.dtype(cfg.compute_dtype)
        x = params["embed"].astype(cd)[tokens]          # (B, 1, d)
        x = constrain(x, "act_btd")

        if cfg.ssm_state:
            x, new_layer_caches = _paged_scan_mamba(
                params, cfg, x, cache, positions, block_tables, kvq, constrain
            )
        else:
            rope_dim = cfg.qk_rope_head_dim if cfg.use_mla else cfg.resolved_head_dim
            rope = layers.rope_tables(positions[:, None], rope_dim, cfg.rope_theta)
            attn = (attention.paged_mla_attention if cfg.use_mla
                    else attention.paged_gqa_attention)

            fuse_norm = _fuses_rmsnorm(cfg)

            def block(x, xs):
                lp, lcache = xs
                attn_in, attn_g = (
                    (x, lp["attn_norm"]) if fuse_norm
                    else (layers.rms_norm(x, lp["attn_norm"], cfg.norm_eps),
                          None)
                )
                x, new_cache = attn(
                    attn_in, lp, cfg, positions=positions, cache=lcache,
                    block_tables=block_tables, kv_quant=kvq,
                    constrain=constrain, rope=rope, residual=x, norm=attn_g,
                )
                if cfg.is_moe:
                    ffn_in = layers.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
                    f, _, _ = moe.moe_ffn(ffn_in, lp, cfg, plan=plan,
                                          constrain=constrain)
                    x = x + f
                else:
                    ffn_in, ffn_g = (
                        (x, lp["ffn_norm"]) if fuse_norm
                        else (layers.rms_norm(x, lp["ffn_norm"], cfg.norm_eps),
                              None)
                    )
                    x = moe.dense_ffn(ffn_in, lp, cfg, constrain=constrain,
                                      residual=x, norm=ffn_g)
                return constrain(x, "act_btd"), new_cache

            x, new_layer_caches = jax.lax.scan(
                block, x, (params["layers"], cache["layers"])
            )

        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        if cfg.tie_embeddings:
            logits = jnp.matmul(
                x, head.astype(cd), preferred_element_type=jnp.float32
            ).astype(jnp.float32)
        else:
            logits = layers.linear(
                x, head, backend=cfg.matmul_backend, compute_dtype=cd,
            ).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            logits = jnp.where(lane < cfg.vocab_size, logits, -1e30)
        logits = constrain(logits, "logits")
        return logits, {"layers": new_layer_caches}

    return step


def _paged_scan_mamba(params, cfg, x, cache, positions, block_tables, kvq,
                      constrain):
    """Paged-serving analogue of :func:`_scan_mamba`: mamba state is a plain
    per-slot pool (the O(1) decode never reads positions), the hybrid shared
    attention goes through the paged path."""
    lp_all = params["layers"]
    lcaches = cache["layers"]
    zero = jnp.zeros((), jnp.int32)   # ssd_block's pos bookkeeping — unused here

    def mblock(x, lp, lcache):
        x, nc = _mamba_block(x, lp, cfg, cache=dict(lcache, pos=zero),
                             constrain=constrain)
        return x, _strip_pos(nc)

    if not cfg.is_hybrid:
        def body(x, xs):
            lp, lc = xs
            return mblock(x, lp, lc)
        x, new_mc = jax.lax.scan(body, x, (lp_all, lcaches))
        return x, new_mc

    ae = cfg.attn_every
    n_super = cfg.n_layers // ae
    shared = params["shared_attn"]
    rope = layers.rope_tables(positions[:, None], cfg.resolved_head_dim,
                              cfg.rope_theta)

    def regroup(t):
        return t.reshape((n_super, ae) + t.shape[1:])

    lp_grp = jax.tree_util.tree_map(regroup, lp_all)
    mcache_grp = jax.tree_util.tree_map(
        regroup, {k: v for k, v in lcaches.items() if k != "attn"}
    )
    acache = lcaches["attn"]

    fuse_norm = _fuses_rmsnorm(cfg)

    def shared_block(x, sc):
        attn_in, attn_g = (
            (x, shared["attn_norm"]) if fuse_norm
            else (layers.rms_norm(x, shared["attn_norm"], cfg.norm_eps), None)
        )
        x, new_sc = attention.paged_gqa_attention(
            attn_in, shared, cfg, positions=positions, cache=sc,
            block_tables=block_tables, kv_quant=kvq,
            constrain=constrain, rope=rope, residual=x, norm=attn_g,
        )
        ffn_in, ffn_g = (
            (x, shared["ffn_norm"]) if fuse_norm
            else (layers.rms_norm(x, shared["ffn_norm"], cfg.norm_eps), None)
        )
        x = moe.dense_ffn(ffn_in, shared, cfg, constrain=constrain, residual=x,
                          norm=ffn_g)
        return x, new_sc

    def superblock(x, xs):
        lp, mc, ac = xs
        def inner(x, ys):
            ilp, imc = ys
            return mblock(x, ilp, imc)
        x, new_mc = jax.lax.scan(inner, x, (lp, mc))
        x, new_ac = shared_block(x, ac)
        return x, (new_mc, new_ac)

    x, (new_mc, new_ac) = jax.lax.scan(superblock, x, (lp_grp, mcache_grp, acache))
    new_mc = jax.tree_util.tree_map(
        lambda t: t.reshape((cfg.n_layers,) + t.shape[2:]), new_mc
    )
    new_mc["attn"] = new_ac
    return x, new_mc


# ------------------------------------------------------------- objectives ---
def _natural_head(params, cfg):
    """The lm_head as a natural (D, padded_vocab) array for the fused loss."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if isinstance(head, api.QuantizedDipWeight):
        return head.to_natural(jnp.float32)
    if isinstance(head, api.DipWeight):
        return head.to_natural()
    return head


def loss_fn(params, cfg, batch, *, plan=None, constrain: Optional[Constrain] = None,
            unroll: bool = False, kv_chunk: int = 0,
            fused_ce: Optional[bool] = None) -> jax.Array:
    """Next-token cross entropy (+ router aux).  ``batch["loss_mask"]``
    (optional, (B, S), nonzero = train on this position) and the -100
    ``ignore_index`` convention in ``labels`` both exclude tokens from the
    loss mean and gradient.

    ``fused_ce=None`` auto-selects the fused lm_head+cross-entropy kernel
    (``kernels.lm_head_ce``) whenever no sharding plan / constrain hook
    needs to see the logits: the (B, S, V) logits then never reach HBM in
    either direction.  Pass ``False`` to force the unfused path (oracle for
    parity tests), ``True`` to force fusion.
    """
    mask = batch.get("loss_mask")
    shift_mask = None if mask is None else mask[:, 1:]
    if fused_ce is None:
        fused_ce = plan is None and constrain is None
    if fused_ce:
        hidden, _, aux = forward(
            params, cfg,
            tokens=batch.get("tokens"), embeddings=batch.get("embeddings"),
            plan=plan, constrain=constrain, unroll=unroll, kv_chunk=kv_chunk,
            return_hidden=True,
        )
        loss = lm_head_ce.fused_cross_entropy_loss(
            hidden[:, :-1], _natural_head(params, cfg),
            batch["labels"][:, 1:], mask=shift_mask,
            vocab_size=cfg.vocab_size, interpret=api.default_interpret(),
        )
        return loss + aux
    logits, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeddings=batch.get("embeddings"),
        plan=plan, constrain=constrain, unroll=unroll, kv_chunk=kv_chunk,
    )
    loss = layers.cross_entropy_loss(
        logits[:, :-1], batch["labels"][:, 1:], mask=shift_mask,
    )
    return loss + aux


def train_step_fn(cfg, optimizer, *, plan=None, constrain: Optional[Constrain] = None,
                  unroll: bool = False, kv_chunk: int = 0, microbatch: int = 1,
                  fused_ce: Optional[bool] = None, guard: bool = False):
    """Returns step(state, batch) -> (state, metrics).  Pure; jit at call site.

    ``plan`` carries the distribution decisions (see :func:`forward`).
    ``microbatch > 1`` enables gradient accumulation: the global batch is
    split into ``microbatch`` slices scanned sequentially with the summed
    gradient applied once — live activation memory scales with the slice
    size (the standard fit-the-HBM lever for the biggest train cells).

    ``guard=True`` wraps the step in the reliability guard
    (``repro.reliability.guard``): nonfinite loss/grad screening plus the
    parameter-fingerprint integrity check, with poisoned steps skipped
    (update discarded, counters advanced).  The guarded state carries the
    fingerprint side-car next to params/opt_state/step — initialize it
    with ``reliability.init_guard_state``.
    """

    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, plan=plan, constrain=constrain,
                              unroll=unroll, kv_chunk=kv_chunk,
                              fused_ce=fused_ce)
        )(params)

    def step(state, batch):
        params, opt_state, step_no = state["params"], state["opt_state"], state["step"]
        if microbatch <= 1:
            loss, grads = grad_of(params, batch)
        else:
            def split(t):
                b = t.shape[0]
                return t.reshape((microbatch, b // microbatch) + t.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss_i, g_i = grad_of(params, mb)
                return (
                    loss_acc + loss_i,
                    jax.tree_util.tree_map(jnp.add, g_acc, g_i),
                ), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zero_g), micro
            )
            inv = 1.0 / microbatch
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        gnorm = optimizer.last_grad_norm(opt_state)
        new_state = {"params": params, "opt_state": opt_state, "step": step_no + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm, "step": step_no + 1}

    if guard:
        from repro.reliability import guard as guard_lib  # lazy: no cycle

        return guard_lib.guarded_step_fn(step)
    return step


def decode_step_fn(cfg, *, plan=None, constrain: Optional[Constrain] = None,
                   unroll: bool = False, attn_backend: Optional[str] = None):
    """Returns serve_step(params, cache, tokens) -> (logits, cache).

    ``attn_backend="flash"`` routes the attention core through the fused
    ``api.attention`` kernel — the serving chunked-prefill path (forward
    only, so decode/prefill steps qualify; training does not)."""

    def step(params, cache, tokens):
        logits, new_cache, _ = forward(
            params, cfg, tokens=tokens, cache=cache, plan=plan,
            constrain=constrain, unroll=unroll, attn_backend=attn_backend,
        )
        return logits, new_cache

    return step
