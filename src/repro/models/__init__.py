"""Architecture zoo: one scan-based assembly covering all ten assigned archs."""

from repro.models import attention, layers, moe, ssm, transformer

__all__ = ["attention", "layers", "moe", "ssm", "transformer"]
