"""Attention: GQA with KV cache, chunked (online-softmax) prefill, and
DeepSeek-style MLA (multi-head latent attention) with absorbed decode.

All functions are pure; distribution enters only through the ``constrain``
callback (a `with_sharding_constraint` hook supplied by repro.distributed —
identity on a single device).  Semantic tags passed to ``constrain``:

    "act_btd"    (batch, seq, d_model) residual-stream activations
    "q_bthd"     (batch, seq, heads, head_dim)
    "kv_bthd"    (batch, seq, kv_heads, head_dim)
    "scores"     attention scores
    "cache_bhsd" KV cache (batch, kv_heads, max_seq, head_dim)
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import api
from repro.models import layers

Constrain = Callable[[jax.Array, str], jax.Array]
_id: Constrain = lambda x, tag: x


def _natural(w):
    """Natural-layout view of a weight (de-shears an ``api.DipWeight``;
    dequantizes an ``api.QuantizedDipWeight`` first — MLA's absorbed form
    contracts these per-head, so the permutated/quantized storage cannot be
    consumed directly)."""
    if isinstance(w, (api.DipWeight, api.QuantizedDipWeight)):
        return w.to_natural()
    return w

__all__ = [
    "attention_core",
    "gqa_attention",
    "mla_attention",
    "init_gqa_cache",
    "init_mla_cache",
    "init_paged_gqa_cache",
    "init_paged_mla_cache",
    "paged_gqa_attention",
    "paged_mla_attention",
    "paged_write",
    "paged_read",
]

NEG_INF = -1e30


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
    """(..., Sq, Sk) additive mask from absolute positions."""
    return jnp.where(q_pos[..., :, None] >= k_pos[..., None, :], 0.0, NEG_INF)


def attention_core(
    q: jax.Array,           # (B, Sq, H, D)
    k: jax.Array,           # (B, Sk, KV, D)
    v: jax.Array,           # (B, Sk, KV, Dv)
    q_pos: jax.Array,       # (Sq,)
    k_pos: jax.Array,       # (Sk,)
    *,
    kv_valid_len: Optional[jax.Array] = None,  # decode: live cache length
    kv_chunk: int = 0,
    constrain: Constrain = _id,
    unroll: bool = False,   # cost-probe mode: unroll the chunk scan so XLA
                            # cost analysis counts every chunk (launch/dryrun)
    backend: Optional[str] = None,  # api.attention backend name; None = the
                                    # XLA dense/chunked paths below
) -> jax.Array:
    """Scaled-dot-product GQA attention, optionally KV-chunked.

    ``kv_chunk > 0`` streams KV in chunks with an online softmax
    (flash-attention recurrence) — O(Sq * chunk) live scores instead of
    O(Sq * Sk).  Exact (not approximate); validated against the dense path.

    ``backend`` routes through the ``api.attention`` registry instead
    (e.g. the fused ``"flash"`` kernel for serving prefill — forward-only).
    That path requires the contiguous-position layout every caller here
    uses (``q_pos``/``k_pos`` are aranges; the query block sits at offset
    ``q_pos[0] - k_pos[0]`` in the key sequence) and subsumes ``kv_chunk``:
    the kernel streams KV blocks internally.
    """
    b, sq, h, d = q.shape
    _, sk, kv, dv = v.shape
    groups = h // kv
    scale = d ** -0.5
    q = (q * scale).astype(q.dtype)
    # GQA: broadcast kv heads up to h.  The expanded form keeps one clean
    # head axis, which shards over the TP axis without the (kv, group)
    # factorization that forces GSPMD reshards (measured in §Perf iter 2).
    if groups > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (b, sk, kv, groups, d)).reshape(b, sk, h, d)
        v = jnp.broadcast_to(v[:, :, :, None, :], (b, sk, kv, groups, dv)).reshape(b, sk, h, dv)

    if backend is not None:
        q_f = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
        k_f = jnp.moveaxis(k, 2, 1).reshape(b * h, sk, d)
        v_f = jnp.moveaxis(v, 2, 1).reshape(b * h, sk, dv)
        out = api.attention(
            q_f, k_f, v_f, backend=backend, causal=True,
            q_offset=(q_pos[0] - k_pos[0]).astype(jnp.int32),
            kv_len=kv_valid_len, scale=1.0,  # q pre-scaled above
        )
        return jnp.moveaxis(out.reshape(b, h, sq, dv), 1, 2).astype(v.dtype)

    def dense(k, v, k_pos):
        scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), k.astype(jnp.float32))
        scores = scores + _causal_mask(q_pos, k_pos)[None, None]
        if kv_valid_len is not None:
            live = (k_pos < kv_valid_len)[None, None, None, :]
            scores = jnp.where(live, scores, NEG_INF)
        scores = constrain(scores, "scores")
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
        return out

    if kv_chunk <= 0 or sk <= kv_chunk:
        return dense(k, v, k_pos)

    # ---- online-softmax over KV chunks (flash-attention recurrence) ----
    n_chunks = sk // kv_chunk
    assert sk % kv_chunk == 0, "pad KV to chunk multiple"
    k_c = jnp.moveaxis(k.reshape(b, n_chunks, kv_chunk, h, d), 1, 0)
    v_c = jnp.moveaxis(v.reshape(b, n_chunks, kv_chunk, h, dv), 1, 0)
    kp_c = k_pos.reshape(n_chunks, kv_chunk)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, kpc = xs
        s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), kc.astype(jnp.float32))
        s = s + _causal_mask(q_pos, kpc)[None, None]
        if kv_valid_len is not None:
            live = (kpc < kv_valid_len)[None, None, None, :]
            s = jnp.where(live, s, NEG_INF)
        s = constrain(s, "scores")
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqs,bshd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k_c, v_c, kp_c),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)


# --------------------------------------------------------------------- GQA --
def init_gqa_cache(batch: int, kv_heads: int, max_seq: int, head_dim: int, dtype) -> Dict:
    return {
        "k": jnp.zeros((batch, max_seq, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, kv_heads, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def gqa_attention(
    x: jax.Array,
    p: Dict,                       # layer params: wq, wk, wv, wo (+ biases)
    cfg,
    *,
    positions: jax.Array,          # (S,) absolute positions of x's tokens
    cache: Optional[Dict] = None,
    kv_chunk: int = 0,
    plan=None,                     # repro.distributed.ShardingPlan
    constrain: Optional[Constrain] = None,  # legacy hook; plan wins
    unroll: bool = False,
    rope=None,                     # precomputed layers.rope_tables (hoisted)
    residual: Optional[jax.Array] = None,  # fused into the out-projection
    norm: Optional[jax.Array] = None,  # attn_norm gain fused as a prologue
    attn_backend: Optional[str] = None,  # api.attention backend (e.g. "flash")
) -> Tuple[jax.Array, Optional[Dict]]:
    """Full GQA block: projections + RoPE + cache update + attention + out.

    ``rope`` takes the per-forward cos/sin tables so layers stop recomputing
    them; ``residual`` fuses the block's ``x + attn(x)`` into the
    out-projection's flush-stage epilogue (the returned tensor then IS the
    updated residual stream).  QKV biases ride the projections' fused bias
    epilogue.  ``norm`` takes the pre-attention RMSNorm gain when the
    backend fuses prologues: ``x`` then arrives UN-normalized and each
    q/k/v projection normalizes it in its kernel's load stage (the normed
    (B, S, d) tensor never reaches HBM) — callers without fusion normalize
    first and pass ``norm=None``.
    """
    constrain = layers.resolve_constrain(plan, constrain)
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    lk = dict(backend=cfg.matmul_backend, compute_dtype=x.dtype)
    nk = dict(lk) if norm is None else dict(
        lk, prologue="rmsnorm", prologue_operands=(norm,),
        prologue_eps=cfg.norm_eps,
    )
    q = layers.linear(x, p["wq"], p.get("bq"), **nk).reshape(b, s, h, hd)
    k = layers.linear(x, p["wk"], p.get("bk"), **nk).reshape(b, s, kv, hd)
    v = layers.linear(x, p["wv"], p.get("bv"), **nk).reshape(b, s, kv, hd)

    q = layers.apply_rope(q, positions, cfg.rope_theta, tables=rope)
    k = layers.apply_rope(k, positions, cfg.rope_theta, tables=rope)
    q = constrain(q, "q_bthd")
    k = constrain(k, "kv_bthd")
    v = constrain(v, "kv_bthd")

    if cache is None:
        out = attention_core(
            q, k, v, positions, positions, kv_chunk=kv_chunk, constrain=constrain,
            unroll=unroll, backend=attn_backend,
        )
        new_cache = None
    else:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        ck = constrain(ck, "cache_bshd")
        cv = constrain(cv, "cache_bshd")
        max_seq = ck.shape[1]
        k_pos = jnp.arange(max_seq, dtype=jnp.int32)
        out = attention_core(
            q, ck, cv, positions, k_pos,
            kv_valid_len=pos + s, kv_chunk=kv_chunk, constrain=constrain,
            unroll=unroll, backend=attn_backend,
        )
        new_cache = {"k": ck, "v": cv, "pos": pos + s}

    out = out.reshape(b, s, h * hd)
    if residual is not None:
        # The fused sum IS the mid-block residual — left to propagation like
        # the explicit `x + a` was (constraining the sum forces an extra
        # scatter/gather pair per layer — §Perf iter 4, refuted).  The pin
        # the unfused path puts on the projection output alone is
        # unreachable once the add happens inside the kernel; propagation
        # stays bounded by the pins on `residual` (previous block end) and
        # on the block output downstream.
        out = layers.linear(out, p["wo"], epilogue="residual",
                            epilogue_operands=(residual,), **lk)
        return out, new_cache
    out = layers.linear(out, p["wo"], **lk)
    return constrain(out, "act_btd"), new_cache


# --------------------------------------------------------------------- MLA --
def init_mla_cache(batch: int, max_seq: int, cfg, dtype) -> Dict:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_attention(
    x: jax.Array,
    p: Dict,
    cfg,
    *,
    positions: jax.Array,
    cache: Optional[Dict] = None,
    kv_chunk: int = 0,
    plan=None,                     # repro.distributed.ShardingPlan
    constrain: Optional[Constrain] = None,  # legacy hook; plan wins
    unroll: bool = False,
    rope=None,                     # precomputed layers.rope_tables (hoisted)
    residual: Optional[jax.Array] = None,  # fused into the out-projection
    norm: Optional[jax.Array] = None,  # attn_norm gain fused as a prologue
    attn_backend: Optional[str] = None,  # api.attention backend (prefill only;
                                         # absorbed decode stays latent-space)
) -> Tuple[jax.Array, Optional[Dict]]:
    """DeepSeek-V2 multi-head latent attention.

    Params: wq -> (d, H*(nope+rope)); w_dkv -> (d, kv_lora); w_krope -> (d, rope);
    w_uk -> (kv_lora, H*nope); w_uv -> (kv_lora, H*v_dim); wo -> (H*v_dim, d).

    Prefill computes the naive (expanded) form; decode uses the *absorbed*
    form — scores against the latent cache directly, never materializing
    per-head K/V over the full context:

        score = q_nope @ W_uk (absorbed into q)  ·  c_kv   +   q_rope · k_rope
        out   = (probs @ c_kv) @ W_uv
    """
    constrain = layers.resolve_constrain(plan, constrain)
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv_ = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    lk = dict(backend=cfg.matmul_backend, compute_dtype=x.dtype)
    # fused attn_norm (see gqa_attention): every projection reading x
    # normalizes it in its kernel's load stage
    nk = dict(lk) if norm is None else dict(
        lk, prologue="rmsnorm", prologue_operands=(norm,),
        prologue_eps=cfg.norm_eps,
    )

    q = layers.linear(x, p["wq"], **nk).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta, tables=rope)

    c_kv = layers.linear(x, p["w_dkv"], **nk)                               # (B,S,r)
    k_rope = layers.linear(x, p["w_krope"], **nk)                           # (B,S,dr) shared
    k_rope = layers.apply_rope(
        k_rope[:, :, None, :], positions, cfg.rope_theta, tables=rope
    )[:, :, 0, :]

    # the absorbed form contracts these per-head — natural layout required
    w_uk = _natural(p["w_uk"]).astype(x.dtype).reshape(r, h, dn)
    w_uv = _natural(p["w_uv"]).astype(x.dtype).reshape(r, h, dv_)

    if cache is None:
        # naive/expanded prefill: materialize per-head K and V
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, w_uk)
        v = jnp.einsum("bsr,rhd->bshd", c_kv, w_uv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], -1)
        qc = jnp.concatenate([q_nope, q_rope], -1)
        qc, k, v = constrain(qc, "q_bthd"), constrain(k, "q_bthd"), constrain(v, "q_bthd")
        out = attention_core(qc, k, v, positions, positions, kv_chunk=kv_chunk,
                             constrain=constrain, unroll=unroll,
                             backend=attn_backend)
        new_cache = None
    else:
        pos = cache["pos"]
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, pos, 0))
        cc, cr = constrain(cc, "cache_bsr"), constrain(cr, "cache_bsr")
        max_seq = cc.shape[1]
        k_pos = jnp.arange(max_seq, dtype=jnp.int32)
        live = (k_pos < pos + s)[None, None, None, :]

        # absorbed decode
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)                  # (B,S,H,r)
        scale = (dn + dr) ** -0.5
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32), cr.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale + _causal_mask(positions, k_pos)[None, None]
        scores = jnp.where(live, scores, NEG_INF)
        scores = constrain(scores, "scores")
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(cc.dtype), cc)  # (B,S,H,r)
        out = jnp.einsum("bshr,rhd->bshd", out_lat, w_uv)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": pos + s}

    out = out.reshape(b, s, h * dv_)
    if residual is not None:
        # fused mid-block residual: left to propagation (see gqa_attention)
        out = layers.linear(out, p["wo"], epilogue="residual",
                            epilogue_operands=(residual,), **lk)
        return out, new_cache
    out = layers.linear(out, p["wo"], **lk)
    return constrain(out, "act_btd"), new_cache


# ------------------------------------------------------------------- paged --
# Block-table-indexed KV cache for the serving engine (repro.serving): K/V
# live in a pool of fixed-size blocks shared by every sequence; a per-slot
# block table maps logical token position p to physical storage
# (table[p // block_size], p % block_size).  All shapes are static — ONE
# compiled decode step serves the whole slot pool regardless of which slots
# are live or how long each sequence is — and storage is optionally int8
# (per-token/head symmetric scales via ``api.quant.quantize_rows``).
# The host-side allocator that hands out blocks lives in
# ``repro.serving.kv_cache``; see docs/serving.md §Paged KV layout.

def init_paged_gqa_cache(num_blocks: int, block_size: int, kv_heads: int,
                         head_dim: int, dtype, kv_quant: str = "none") -> Dict:
    """GQA block pool: k/v (num_blocks, block_size, kv_heads, head_dim);
    int8 mode adds per-token/head f32 scales (num_blocks, block_size, kv_heads)."""
    if kv_quant == "none":
        return {
            "k": jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
            "v": jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        }
    sdt = jnp.dtype(api.quant.scheme_info(kv_quant).storage_dtype)
    return {
        "k": jnp.zeros((num_blocks, block_size, kv_heads, head_dim), sdt),
        "v": jnp.zeros((num_blocks, block_size, kv_heads, head_dim), sdt),
        "k_scale": jnp.zeros((num_blocks, block_size, kv_heads), jnp.float32),
        "v_scale": jnp.zeros((num_blocks, block_size, kv_heads), jnp.float32),
    }


def init_paged_mla_cache(num_blocks: int, block_size: int, cfg, dtype,
                         kv_quant: str = "none") -> Dict:
    """MLA block pool: the latent c_kv and shared k_rope are paged the same
    way; int8 scales are per token (one row = the whole latent/rope vector)."""
    if kv_quant == "none":
        return {
            "c_kv": jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((num_blocks, block_size, cfg.qk_rope_head_dim), dtype),
        }
    sdt = jnp.dtype(api.quant.scheme_info(kv_quant).storage_dtype)
    return {
        "c_kv": jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank), sdt),
        "k_rope": jnp.zeros((num_blocks, block_size, cfg.qk_rope_head_dim), sdt),
        "c_kv_scale": jnp.zeros((num_blocks, block_size), jnp.float32),
        "k_rope_scale": jnp.zeros((num_blocks, block_size), jnp.float32),
    }


def paged_write(pool: jax.Array, phys: jax.Array, vals: jax.Array,
                *, scale_pool: Optional[jax.Array] = None,
                kv_quant: str = "none"):
    """Scatter per-token vectors into a block pool at flat physical indices.

    ``pool``: (num_blocks, block_size, ...); ``phys``: (N,) flat token indices
    (``num_blocks * block_size`` acts as a drop sentinel for padding / dead
    rows); ``vals``: (N, ...).  Returns ``(pool, scale_pool)`` updated; int8
    mode quantizes each row (last axis) and records its scale.
    """
    nb, bs = pool.shape[:2]
    flat = pool.reshape((nb * bs,) + pool.shape[2:])
    if kv_quant != "none":
        q, scale = api.quant.quantize_rows(vals, kv_quant)
        flat = flat.at[phys].set(q, mode="drop")
        sflat = scale_pool.reshape((nb * bs,) + scale_pool.shape[2:])
        sflat = sflat.at[phys].set(scale[..., 0], mode="drop")
        return flat.reshape(pool.shape), sflat.reshape(scale_pool.shape)
    flat = flat.at[phys].set(vals.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape), scale_pool


def paged_read(pool: jax.Array, idx: jax.Array,
               *, scale_pool: Optional[jax.Array] = None,
               dtype=jnp.float32) -> jax.Array:
    """Gather token vectors at flat physical indices ``idx`` (any shape),
    dequantizing against ``scale_pool`` when the pool is quantized."""
    nb, bs = pool.shape[:2]
    flat = pool.reshape((nb * bs,) + pool.shape[2:])
    vals = flat[idx]
    if scale_pool is not None:
        sflat = scale_pool.reshape((nb * bs,) + scale_pool.shape[2:])
        return api.quant.dequantize_rows(vals, sflat[idx][..., None], dtype)
    return vals.astype(dtype)


def _gather_indices(block_tables: jax.Array, block_size: int) -> jax.Array:
    """(B, n_blocks) block tables -> (B, n_blocks * block_size) flat token
    indices in logical order."""
    b, nblk = block_tables.shape
    idx = block_tables[:, :, None] * block_size + jnp.arange(
        block_size, dtype=block_tables.dtype
    )[None, None, :]
    return idx.reshape(b, nblk * block_size)


def paged_gqa_attention(
    x: jax.Array,                  # (B, 1, d) — one decode token per slot
    p: Dict,
    cfg,
    *,
    positions: jax.Array,          # (B,) per-slot absolute positions
    cache: Dict,                   # paged pool (init_paged_gqa_cache)
    block_tables: jax.Array,       # (B, n_blocks_per_seq) int32
    kv_quant: str = "none",
    constrain: Optional[Constrain] = None,
    rope=None,
    residual: Optional[jax.Array] = None,
    norm: Optional[jax.Array] = None,  # attn_norm gain fused as a prologue
) -> Tuple[jax.Array, Dict]:
    """GQA decode against the paged pool: write this token's K/V into its
    slot's block, gather the slot's whole context, attend with per-row valid
    lengths.  Rows whose slot is free write to the reserved null block 0 and
    their output is ignored by the engine."""
    constrain = constrain if constrain is not None else _id
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    bs = cache["k"].shape[1]
    lk = dict(backend=cfg.matmul_backend, compute_dtype=x.dtype)
    nk = dict(lk) if norm is None else dict(
        lk, prologue="rmsnorm", prologue_operands=(norm,),
        prologue_eps=cfg.norm_eps,
    )
    q = layers.linear(x, p["wq"], p.get("bq"), **nk).reshape(b, s, h, hd)
    k = layers.linear(x, p["wk"], p.get("bk"), **nk).reshape(b, s, kv, hd)
    v = layers.linear(x, p["wv"], p.get("bv"), **nk).reshape(b, s, kv, hd)

    pos2 = positions[:, None]                                   # (B, 1)
    q = layers.apply_rope(q, pos2, cfg.rope_theta, tables=rope)
    k = layers.apply_rope(k, pos2, cfg.rope_theta, tables=rope)
    q = constrain(q, "q_bthd")

    phys = block_tables[jnp.arange(b), positions // bs] * bs + positions % bs
    ck, cks = paged_write(cache["k"], phys, k[:, 0],
                          scale_pool=cache.get("k_scale"), kv_quant=kv_quant)
    cv, cvs = paged_write(cache["v"], phys, v[:, 0],
                          scale_pool=cache.get("v_scale"), kv_quant=kv_quant)
    new_cache = {"k": ck, "v": cv}
    if kv_quant != "none":
        new_cache.update(k_scale=cks, v_scale=cvs)

    idx = _gather_indices(block_tables, bs)                     # (B, Smax)
    k_all = paged_read(ck, idx, scale_pool=cks, dtype=x.dtype)  # (B, Smax, KV, hd)
    v_all = paged_read(cv, idx, scale_pool=cvs, dtype=x.dtype)

    smax = k_all.shape[1]
    groups = h // kv
    if groups > 1:
        k_all = jnp.broadcast_to(
            k_all[:, :, :, None, :], (b, smax, kv, groups, hd)
        ).reshape(b, smax, h, hd)
        v_all = jnp.broadcast_to(
            v_all[:, :, :, None, :], (b, smax, kv, groups, hd)
        ).reshape(b, smax, h, hd)

    scale = hd ** -0.5
    scores = jnp.einsum(
        "bqhd,bshd->bhqs",
        (q * scale).astype(jnp.float32), k_all.astype(jnp.float32),
    )
    # logical position t is live iff t <= pos (the current token, just
    # written, attends to itself and everything before it)
    live = (jnp.arange(smax, dtype=jnp.int32)[None, :] <= positions[:, None])
    scores = jnp.where(live[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v_all.dtype), v_all)

    out = out.reshape(b, s, h * hd)
    if residual is not None:
        out = layers.linear(out, p["wo"], epilogue="residual",
                            epilogue_operands=(residual,), **lk)
        return out, new_cache
    out = layers.linear(out, p["wo"], **lk)
    return constrain(out, "act_btd"), new_cache


def paged_mla_attention(
    x: jax.Array,                  # (B, 1, d)
    p: Dict,
    cfg,
    *,
    positions: jax.Array,          # (B,)
    cache: Dict,                   # paged pool (init_paged_mla_cache)
    block_tables: jax.Array,
    kv_quant: str = "none",
    constrain: Optional[Constrain] = None,
    rope=None,
    residual: Optional[jax.Array] = None,
    norm: Optional[jax.Array] = None,  # attn_norm gain fused as a prologue
) -> Tuple[jax.Array, Dict]:
    """Absorbed-form MLA decode against the paged latent pool (the compressed
    c_kv / shared k_rope page exactly like K/V — one row per token)."""
    constrain = constrain if constrain is not None else _id
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv_ = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    bs = cache["c_kv"].shape[1]
    lk = dict(backend=cfg.matmul_backend, compute_dtype=x.dtype)
    nk = dict(lk) if norm is None else dict(
        lk, prologue="rmsnorm", prologue_operands=(norm,),
        prologue_eps=cfg.norm_eps,
    )

    q = layers.linear(x, p["wq"], **nk).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos2 = positions[:, None]
    q_rope = layers.apply_rope(q_rope, pos2, cfg.rope_theta, tables=rope)

    c_kv = layers.linear(x, p["w_dkv"], **nk)                   # (B, 1, r)
    k_rope = layers.linear(x, p["w_krope"], **nk)               # (B, 1, dr)
    k_rope = layers.apply_rope(
        k_rope[:, :, None, :], pos2, cfg.rope_theta, tables=rope
    )[:, :, 0, :]

    phys = block_tables[jnp.arange(b), positions // bs] * bs + positions % bs
    cc, ccs = paged_write(cache["c_kv"], phys, c_kv[:, 0],
                          scale_pool=cache.get("c_kv_scale"), kv_quant=kv_quant)
    cr, crs = paged_write(cache["k_rope"], phys, k_rope[:, 0],
                          scale_pool=cache.get("k_rope_scale"), kv_quant=kv_quant)
    new_cache = {"c_kv": cc, "k_rope": cr}
    if kv_quant != "none":
        new_cache.update(c_kv_scale=ccs, k_rope_scale=crs)

    idx = _gather_indices(block_tables, bs)
    cc_all = paged_read(cc, idx, scale_pool=ccs, dtype=x.dtype)  # (B, Smax, r)
    cr_all = paged_read(cr, idx, scale_pool=crs, dtype=x.dtype)  # (B, Smax, dr)
    smax = cc_all.shape[1]

    w_uk = _natural(p["w_uk"]).astype(x.dtype).reshape(r, h, dn)
    w_uv = _natural(p["w_uv"]).astype(x.dtype).reshape(r, h, dv_)

    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scale = (dn + dr) ** -0.5
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                       cc_all.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                        cr_all.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    live = (jnp.arange(smax, dtype=jnp.int32)[None, :] <= positions[:, None])
    scores = jnp.where(live[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(cc_all.dtype), cc_all)
    out = jnp.einsum("bshr,rhd->bshd", out_lat, w_uv)

    out = out.reshape(b, s, h * dv_)
    if residual is not None:
        out = layers.linear(out, p["wo"], epilogue="residual",
                            epilogue_operands=(residual,), **lk)
        return out, new_cache
    out = layers.linear(out, p["wo"], **lk)
    return constrain(out, "act_btd"), new_cache
