"""Mamba2 SSD (state-space duality) block — chunked scan + O(1) decode.

Implements the SSD algorithm of Mamba2 (arXiv:2405.21060): the selective SSM
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)
    y_t = C_t · h_t + D * x_t
computed chunk-parallel: within a chunk of Q tokens the contribution is a
masked attention-like quadratic form (MXU-friendly — this is where the DiP
matmul applies); across chunks a sequential scan passes the (H, P, N) state.

Conventions (single B/C group, scalar A per head, as in Mamba2 defaults):
    d_inner = expand * d_model,  H = d_inner / headdim (P), state N
    in_proj -> [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (H)]
    causal depthwise conv (width ssm_conv) over [x | B | C]
    gated RMSNorm then out_proj

DiP applicability note (DESIGN.md §4): in_proj / out_proj / the chunked
quadratic forms are matmuls (DiP tiles apply); the elementwise state decay
has no systolic analogue and is executed on the VPU.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Constrain = Callable[[jax.Array, str], jax.Array]
_id: Constrain = lambda x, tag: x

__all__ = ["ssd_block", "init_ssm_cache", "ssm_dims"]


def ssm_dims(cfg) -> Dict[str, int]:
    di = cfg.d_inner
    h = cfg.n_ssm_heads
    n = cfg.ssm_state
    return dict(
        d_inner=di,
        heads=h,
        headdim=cfg.ssm_headdim,
        state=n,
        conv_dim=di + 2 * n,
        in_dim=2 * di + 2 * n + h,
    )


def init_ssm_cache(batch: int, cfg, dtype) -> Dict:
    dims = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, dims["conv_dim"]), dtype),
        "state": jnp.zeros((batch, dims["heads"], cfg.ssm_headdim, dims["state"]), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 history: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv, width K.  xbc: (B, L, C), w: (K, C), b: (C,)."""
    k = w.shape[0]
    if history is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = history.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)              # (B, L+K-1, C)
    out = sum(xp[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def ssd_block(
    x: jax.Array,
    p: Dict,
    cfg,
    *,
    cache: Optional[Dict] = None,
    plan=None,
    constrain: Optional[Constrain] = None,
    residual: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """One Mamba2 block.  Prefill/train: chunked SSD; decode: O(1) update.

    ``plan`` carries the distribution decisions (its constraints replace the
    legacy ``constrain`` callback).  ``residual`` fuses the block's skip
    connection into the out-projection's flush-stage epilogue (the returned
    tensor then IS the updated residual stream).
    """
    constrain = layers.resolve_constrain(plan, constrain)
    bsz, seqlen, _ = x.shape
    dims = ssm_dims(cfg)
    di, h, pdim, n = dims["d_inner"], dims["heads"], dims["headdim"], dims["state"]
    lk = dict(backend=cfg.matmul_backend, compute_dtype=x.dtype)

    zxbcdt = layers.linear(x, p["in_proj"], **lk)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )

    xbc = jnp.concatenate([xin, bmat, cmat], axis=-1)     # (B, L, conv_dim)
    if cache is not None:
        conv_hist = cache["conv"]
        new_conv = jnp.concatenate([conv_hist, xbc], axis=1)[:, -(cfg.ssm_conv - 1):, :]
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], history=conv_hist)
    else:
        new_conv = None
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,L,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                                     # (H,) < 0
    xh = xin.reshape(bsz, seqlen, h, pdim)

    if cache is not None and seqlen == 1:
        # ---- O(1) decode ----
        state = cache["state"]                                      # (B,H,P,N) f32
        da = jnp.exp(dt[:, 0] * a[None, :])                         # (B,H)
        dbx = jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0], bmat[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        state = state * da[:, :, None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", state, cmat[:, 0].astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(bsz, 1, di)
        new_cache = {"conv": new_conv, "state": state, "pos": cache["pos"] + 1}
    else:
        # ---- chunked SSD ----
        q = min(cfg.ssm_chunk, seqlen)
        pad = (-seqlen) % q
        if pad:
            # Pad to a chunk multiple with inert steps: dt=0 makes the state
            # update an exact identity (exp(0*A)=1, dB*x=0), so the carried
            # state and the real positions' outputs are unaffected.
            zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            dt, bmat, cmat, xh = zpad(dt), zpad(bmat), zpad(cmat), zpad(xh)
        padded_len = seqlen + pad
        nc = padded_len // q

        def r(t, shape):  # reshape (B, Lp, ...) -> (B, nc, Q, ...)
            return t.reshape((bsz, nc, q) + shape)

        dt_c = r(dt, (h,))
        b_c = r(bmat.astype(jnp.float32), (n,))
        c_c = r(cmat.astype(jnp.float32), (n,))
        x_c = r(xh.astype(jnp.float32), (h, pdim))

        da_c = dt_c * a[None, None, None, :]                        # (B,nc,Q,H) ≤ 0
        cum = jnp.cumsum(da_c, axis=2)                              # within-chunk decay
        total = cum[:, :, -1, :]                                    # (B,nc,H)

        # intra-chunk (masked quadratic form — MXU work)
        # L[t,s] = exp(cum[t] - cum[s]) for s <= t.  The mask must select
        # BEFORE the exp: for s > t the difference is positive and exp
        # overflows to inf, and where(mask, inf, 0) back-propagates
        # 0 * d(inf) = NaN (the standard where-grad trap).
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q,Q,H)
        mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
        decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
        cb = jnp.einsum("bcqn,bcsn->bcqs", c_c, b_c)                # (B,nc,Q,Q)
        att = cb[..., None] * decay * dt_c[:, :, None, :, :]        # (B,nc,Q,Q,H)
        y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", att, x_c)

        # per-chunk outgoing state: sum_s exp(total - cum[s]) dt_s B_s x_s
        state_decay = jnp.exp(total[:, :, None, :] - cum)           # (B,nc,Q,H)
        dbx = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                         dt_c * state_decay, b_c, x_c)              # (B,nc,H,P,N)

        # sequential scan across chunks (the only serial dependency)
        init = (
            cache["state"] if cache is not None
            else jnp.zeros((bsz, h, pdim, n), jnp.float32)
        )

        def chunk_step(hprev, xs):
            dbx_c, tot_c = xs                                       # (B,H,P,N), (B,H)
            hnew = hprev * jnp.exp(tot_c)[:, :, None, None] + dbx_c
            return hnew, hprev

        hlast, hprevs = jax.lax.scan(
            chunk_step,
            init,
            (jnp.moveaxis(dbx, 1, 0), jnp.moveaxis(total, 1, 0)),
        )
        hprevs = jnp.moveaxis(hprevs, 0, 1)                         # (B,nc,H,P,N)

        # inter-chunk contribution: C_t · exp(cum[t]) h_prev
        in_decay = jnp.exp(cum)                                     # (B,nc,Q,H)
        y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", c_c, hprevs, in_decay)

        y = (y_intra + y_inter).reshape(bsz, padded_len, h, pdim)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, padded_len, di)[:, :seqlen]
        if cache is not None:
            new_cache = {"conv": new_conv, "state": hlast, "pos": cache["pos"] + seqlen}
        else:
            new_cache = None

    # gated RMSNorm + out projection (skip connection fused into its flush)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = layers.rms_norm(y, p["norm"], cfg.norm_eps)
    y = constrain(y, "ssm_inner")
    if residual is not None:
        out = layers.linear(y, p["out_proj"], epilogue="residual",
                            epilogue_operands=(residual,), **lk)
    else:
        out = layers.linear(y, p["out_proj"], **lk)
    return constrain(out, "act_btd"), new_cache
