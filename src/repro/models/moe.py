"""Token-choice top-k Mixture-of-Experts with grouped capacity dispatch.

Dispatch is scatter/gather based and *grouped by sequence* (no (T, E, C)
one-hot einsum tensor — that would not fit HBM at 1M-token global batches,
and no global-token cumsum — that forces GSPMD to replicate dispatch state):
within each sequence, every (token, slot) computes its rank inside its
expert's per-group buffer via a batch-local cumsum, tokens are scatter-added
into a (B, E, C, d) buffer, the expert FFNs run as one batched einsum, and
results are gathered back and combined with renormalized gates.  Tokens past
an expert's per-group capacity C = ceil(S*k*cf / E) are dropped (standard
GShard/Switch semantics, applied per group) — the drop COUNT is returned so
callers can assert capacity is ample (``moe_ffn`` -> (out, aux, dropped)).

Sharding has two modes, decided by the :class:`~repro.distributed.plan.
ShardingPlan` threaded through ``plan=``:

* **Dense-style (default)**: groups (B) over the DP axes, experts (E) over
  the "model" axis for both buffers and weights; all routing math is
  shard-local and the token<->expert exchange is the batched scatter/gather
  GSPMD lowers to dispatch collectives.
* **Expert parallel** (``plan.expert_plan`` set, i.e. strategy "ep"): ONE
  explicit shard_map over the model axis per MoE layer.  Tokens shard over
  batch (or sequence), experts over the axis; the body routes locally,
  issues the dispatch ``all_to_all`` FIRST, then runs the shared-expert
  compute the transfer hides behind, then the local expert einsums, then
  the combine ``all_to_all`` — exactly TWO all-to-alls per layer, with the
  aux/drop stats folded into one psum.  See docs/distributed.md.

Aux losses: load-balance (Switch) + router z-loss, returned for the trainer.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Constrain = Callable[[jax.Array, str], jax.Array]
_id: Constrain = lambda x, tag: x

__all__ = ["moe_ffn", "dense_ffn", "moe_capacity"]


def dense_ffn(
    x: jax.Array, p: Dict, cfg, *, plan=None,
    constrain: Optional[Constrain] = None, residual: jax.Array = None,
    norm: Optional[jax.Array] = None, backend: Optional[str] = None,
) -> jax.Array:
    """SwiGLU MLP (dense archs and MoE shared experts).

    The gate and up projections run as ONE dual-weight ``swiglu`` dispatch:
    on fused backends that is a single kernel reading x once and writing
    only the activated product (no intermediate gate/up arrays in HBM); on
    other backends ``api.matmul`` decomposes with identical semantics.
    Under the explicit ``dip_tp`` backend this is the canonical Megatron
    pair: the column-parallel gate/up swiglu runs collective-free and the
    row-parallel down-projection pays the block's single psum.
    ``residual`` fuses the block's skip connection into the down-projection
    the same way.  ``norm`` takes the pre-FFN RMSNorm gain when the backend
    fuses prologues: x arrives UN-normalized and the swiglu dispatch
    normalizes it in its load stage — rmsnorm + gate + up + silu·mul in ONE
    kernel launch.  ``backend`` overrides ``cfg.matmul_backend`` (the EP
    shard_map body runs shared experts on the per-device inner backend).
    """
    constrain = layers.resolve_constrain(plan, constrain)
    lk = dict(backend=backend or cfg.matmul_backend, compute_dtype=x.dtype)
    gk = dict(lk) if norm is None else dict(
        lk, prologue="rmsnorm", prologue_operands=(norm,),
        prologue_eps=cfg.norm_eps,
    )
    h = layers.linear(x, (p["w_gate"], p["w_up"]), epilogue="swiglu", **gk)
    h = constrain(h, "ffn_hidden")
    if residual is not None:
        return layers.linear(h, p["w_down"], epilogue="residual",
                             epilogue_operands=(residual,), **lk)
    return layers.linear(h, p["w_down"], **lk)


def moe_capacity(tokens: int, cfg) -> int:
    cap = math.ceil(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-friendly shapes


# --------------------------------------------------------------------------
# routing / dispatch / combine building blocks — shared by the dense-style
# path (global arrays, GSPMD shards them) and the EP shard_map body (local
# shards, collectives placed by hand)
def _route(x: jax.Array, router: jax.Array, cfg, cap: int) -> Dict[str, jax.Array]:
    """Group-local routing state for ``x`` (G groups of S tokens each).

    fp32 router softmax + top-k with renormalized gates, the Switch
    load-balance + z-loss aux, and the sort-by-expert dispatch order
    (gather-only — GSPMD partitions batched take_along_axis gathers along
    the group dim, but replicates multi-index scatters; §Perf pair-2).
    ``dropped`` counts (token, slot) pairs past an expert's capacity."""
    g, sl, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cd = x.dtype
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), router.astype(jnp.float32)
    )                                                            # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                         # (G, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance loss (Switch): E * mean(frac_tokens_e * mean_prob_e)
    load = jax.nn.one_hot(ids[..., 0], e, dtype=jnp.float32).mean((0, 1))
    importance = probs.mean((0, 1))
    aux = cfg.router_aux_loss * e * jnp.sum(load * importance)
    aux = aux + 1e-4 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    flat_ids = ids.reshape(g, sl * k)                            # slot-major
    gates_flat = gates.reshape(g, sl * k).astype(cd)
    order = jnp.argsort(flat_ids, axis=1)                        # stable
    inv_order = jnp.argsort(order, axis=1)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=1)
    src = jnp.repeat(x, k, axis=1)                               # (G, S*k, d)
    sorted_src = jnp.take_along_axis(src, order[..., None], axis=1)

    # expert run boundaries within each group
    erange = jnp.arange(e, dtype=jnp.int32)
    start = jax.vmap(
        lambda row: jnp.searchsorted(row, erange, side="left")
    )(sorted_ids)
    end = jax.vmap(
        lambda row: jnp.searchsorted(row, erange, side="right")
    )(sorted_ids)
    counts = end - start                                         # (G, E)
    dropped = jnp.maximum(counts - cap, 0).sum().astype(jnp.int32)
    return dict(
        gates_flat=gates_flat, order=order, inv_order=inv_order,
        sorted_ids=sorted_ids, sorted_src=sorted_src, start=start,
        counts=counts, aux=aux, dropped=dropped,
    )


def _fill_buffer(r: Dict[str, jax.Array], cap: int) -> jax.Array:
    """Gather each expert's first C tokens into the (G, E, C, d) buffer."""
    sorted_src, start, counts = r["sorted_src"], r["start"], r["counts"]
    g, sk, d = sorted_src.shape
    e = counts.shape[1]
    c_iota = jnp.arange(cap, dtype=jnp.int32)
    gidx = start[:, :, None] + c_iota[None, None, :]             # (G, E, C)
    valid = c_iota[None, None, :] < jnp.minimum(counts, cap)[:, :, None]
    gidx = jnp.clip(gidx, 0, sk - 1).reshape(g, e * cap)
    buf = jnp.take_along_axis(
        sorted_src, gidx[..., None], axis=1
    ).reshape(g, e, cap, d)
    return buf * valid[..., None].astype(sorted_src.dtype)


def _combine(y: jax.Array, r: Dict[str, jax.Array], cap: int, k: int) -> jax.Array:
    """Gather expert outputs back per sorted slot, unsort, gate, sum k."""
    g, e, _, d = y.shape
    cd = y.dtype
    sk = r["sorted_ids"].shape[1]
    j_iota = jnp.arange(sk, dtype=jnp.int32)[None, :]
    pos_sorted = j_iota - jnp.take_along_axis(r["start"], r["sorted_ids"], axis=1)
    keep_sorted = pos_sorted < cap
    slot = r["sorted_ids"] * cap + jnp.where(keep_sorted, pos_sorted, 0)
    out_sorted = jnp.take_along_axis(
        y.reshape(g, e * cap, d), slot[..., None], axis=1
    ) * keep_sorted[..., None].astype(cd)
    out = jnp.take_along_axis(out_sorted, r["inv_order"][..., None], axis=1)
    return (out * r["gates_flat"][..., None]).reshape(g, sk // k, k, d).sum(axis=2)


def _shared_params(p: Dict) -> Optional[Dict]:
    return {
        "w_gate": p["shared_w_gate"],
        "w_up": p["shared_w_up"],
        "w_down": p["shared_w_down"],
    } if "shared_w_gate" in p else None


# --------------------------------------------------------------------------
def moe_ffn(
    x: jax.Array, p: Dict, cfg, *, plan=None,
    constrain: Optional[Constrain] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Routed expert FFN.  Returns (output, aux_loss, dropped_token_count).

    ``dropped`` is the number of (token, slot) pairs past capacity this
    layer (int32 scalar) — zero when ``capacity_factor`` is ample; the
    conformance tests assert it stays zero where exact parity is claimed.

    Dispatch is *grouped by sequence*: every routing tensor (one-hot, cumsum,
    scatter/gather indices) carries the batch dim, so under the sharding
    policy all routing math is shard-local (B over DP), the (B, E, C, d)
    expert buffers shard E over TP, and the only cross-device movement is
    the unavoidable token<->expert exchange GSPMD derives from the batched
    scatter/gather.  When the plan carries an :attr:`~repro.distributed.
    plan.ShardingPlan.expert_plan` (strategy "ep") the exchange is instead
    placed by hand: see :func:`_moe_ffn_ep`.
    """
    eplan = getattr(plan, "expert_plan", None)
    if eplan is not None and eplan.mesh is not None:
        t = eplan.mesh.shape[eplan.axis]
        b, s, _ = x.shape
        if t > 1 and cfg.n_experts % t == 0 and (b % t == 0 or s % t == 0):
            return _moe_ffn_ep(x, p, cfg, eplan, plan=plan,
                               constrain=constrain)

    constrain = layers.resolve_constrain(plan, constrain)
    b, s, d = x.shape
    cd = x.dtype
    cap = moe_capacity(s, cfg)                                   # per-group capacity

    r = _route(x, p["router"], cfg, cap)
    buf = constrain(_fill_buffer(r, cap), "expert_buf")

    # ---- batched per-expert SwiGLU: weights (E, d, ffe) / (E, ffe, d) ----
    gate_h = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cd))
    up_h = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cd))
    h = layers.swiglu(gate_h, up_h)
    h = constrain(h, "expert_hidden")
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))  # (B, E, C, d)
    y = constrain(y, "expert_buf")

    out = _combine(y, r, cap, cfg.moe_top_k)

    # shared experts (DeepSeek-style), computed densely for every token
    shared = _shared_params(p)
    if cfg.n_shared_experts and shared is not None:
        out = out + dense_ffn(x, shared, cfg, constrain=constrain)
    return constrain(out, "act_btd"), r["aux"], r["dropped"]


# --------------------------------------------------------------------------
# expert parallelism: explicit all-to-all dispatch/combine
def _ep_payload(w):
    """(storage, scale) payloads of a possibly-DiP/quantized shared-expert
    weight, so the shard_map body can rebuild it plan-FREE (an attached plan
    would re-enter the sharded dispatch from inside the per-device body)."""
    if hasattr(w, "data"):
        return w.data, getattr(w, "scale", None)
    return w, None


def _moe_ffn_ep(
    x: jax.Array, p: Dict, cfg, eplan, *, plan=None,
    constrain: Optional[Constrain] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Expert-parallel MoE layer: ONE shard_map over the expert axis.

    Tokens shard over batch when it divides the axis (groups stay whole, so
    capacity semantics match the dense-style path exactly), else over
    sequence.  Inside the body, per device:

        1. route the LOCAL tokens (router replicated — tiny),
        2. build the (G_loc, E, C, d) buffer and issue the dispatch
           ``all_to_all`` (experts split over the axis, tokens concatenated)
           — issued FIRST so the transfer runs while step 3 computes,
        3. shared-expert SwiGLU on the local tokens (the compute the
           dispatch hides behind; weights replicated, rebuilt plan-free),
        4. local expert-bank einsums over the E/T experts this device owns,
        5. combine ``all_to_all`` back, unsort, gate, add shared,
        6. ONE psum folding (aux, dropped) stats.

    Exactly TWO all-to-alls per MoE layer — the jaxpr contract the fleet
    validator and the multidevice suite assert.
    """
    from repro.kernels.dip_matmul_sharded import _inner_backend, _local_weight

    mesh, ax = eplan.mesh, eplan.axis
    t = mesh.shape[ax]
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    e_loc = e // t
    cd = x.dtype
    batch_split = b % t == 0
    sl = s if batch_split else s // t                 # tokens per local group
    cap = moe_capacity(sl, cfg)
    P = jax.sharding.PartitionSpec
    from repro.kernels.common import shard_map

    shared = _shared_params(p) if cfg.n_shared_experts else None
    if shared is not None:
        s_payloads = tuple(_ep_payload(shared[n])
                           for n in ("w_gate", "w_up", "w_down"))
        s_datas = tuple(pl[0] for pl in s_payloads)
        s_scales = tuple(pl[1] for pl in s_payloads if pl[1] is not None)
    else:
        s_datas, s_scales = (), ()

    banks = (p["w_gate"], p["w_up"], p["w_down"])     # (E, d, ffe) x2, (E, ffe, d)

    def body(xl, router, banks_l, s_datas_l, s_scales_l):
        g = xl.shape[0]
        r = _route(xl, router, cfg, cap)
        buf = _fill_buffer(r, cap)                    # (G_loc, E, C, d)
        # experts split over the axis, local tokens concatenated: every
        # device ends up holding ALL tokens destined for ITS E/T experts.
        # Issued before the shared-expert compute below — trace order is
        # dispatch order, so the transfer overlaps that compute.
        bufe = jnp.swapaxes(buf, 0, 1).reshape(e, g * cap, d)
        disp = jax.lax.all_to_all(
            bufe, ax, split_axis=0, concat_axis=1, tiled=True
        )                                             # (E/T, T*G_loc*C, d)

        # shared experts on the LOCAL tokens, hidden behind the dispatch
        if shared is not None:
            it = iter(s_scales_l)
            sw = {
                n: _local_weight(
                    shared[n], dat,
                    next(it) if _ep_payload(shared[n])[1] is not None else None,
                    getattr(shared[n], "d_in", dat.shape[-2]),
                    getattr(shared[n], "d_out", dat.shape[-1]),
                ) if hasattr(shared[n], "data") else dat
                for n, dat in zip(("w_gate", "w_up", "w_down"), s_datas_l)
            }
            inner = (
                _inner_backend(shared["w_gate"])
                if hasattr(shared["w_gate"], "data") else "xla"
            )
            shared_out = dense_ffn(xl, sw, cfg, constrain=_id, backend=inner)
        else:
            shared_out = None

        # local expert banks: this device's E/T experts over every token
        wg, wu, wd = (bl.astype(cd) for bl in banks_l)
        gate_h = jnp.einsum("etd,edf->etf", disp, wg)
        up_h = jnp.einsum("etd,edf->etf", disp, wu)
        y = jnp.einsum("etf,efd->etd", layers.swiglu(gate_h, up_h), wd)

        comb = jax.lax.all_to_all(
            y, ax, split_axis=1, concat_axis=0, tiled=True
        )                                             # (E, G_loc*C, d)
        yl = jnp.swapaxes(comb.reshape(e, g, cap, d), 0, 1)
        out = _combine(yl, r, cap, k)
        if shared_out is not None:
            out = out + shared_out
        # ONE psum for the stats pair: aux averages over devices (each saw
        # 1/T of the tokens), drops sum
        aux_sum, dropped = jax.lax.psum((r["aux"], r["dropped"]), ax)
        return out, aux_sum / t, dropped

    xspec = P(ax, None, None) if batch_split else P(None, ax, None)
    out, aux, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(
            xspec,
            P(None, None),                            # router: replicated
            tuple(P(ax, None, None) for _ in banks),  # expert dim split
            tuple(P(None, None) for _ in s_datas),    # shared: replicated
            tuple(P(None, None) for _ in s_scales),
        ),
        out_specs=(xspec, P(), P()),
        check_rep=False,
    )(x, p["router"], banks, s_datas, s_scales)
    constrain = layers.resolve_constrain(plan, constrain)
    return constrain(out, "act_btd"), aux, dropped
