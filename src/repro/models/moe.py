"""Token-choice top-k Mixture-of-Experts with grouped capacity dispatch.

Dispatch is scatter/gather based and *grouped by sequence* (no (T, E, C)
one-hot einsum tensor — that would not fit HBM at 1M-token global batches,
and no global-token cumsum — that forces GSPMD to replicate dispatch state):
within each sequence, every (token, slot) computes its rank inside its
expert's per-group buffer via a batch-local cumsum, tokens are scatter-added
into a (B, E, C, d) buffer, the expert FFNs run as one batched einsum, and
results are gathered back and combined with renormalized gates.  Tokens past
an expert's per-group capacity C = ceil(S*k*cf / E) are dropped (standard
GShard/Switch semantics, applied per group).

Expert-parallel sharding: groups (B) over the DP axes, experts (E) over the
"model" axis for both buffers and weights; all routing math is shard-local
and the token<->expert exchange is the batched scatter/gather GSPMD lowers
to dispatch collectives.

Aux losses: load-balance (Switch) + router z-loss, returned for the trainer.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Constrain = Callable[[jax.Array, str], jax.Array]
_id: Constrain = lambda x, tag: x

__all__ = ["moe_ffn", "dense_ffn", "moe_capacity"]


def dense_ffn(
    x: jax.Array, p: Dict, cfg, *, plan=None,
    constrain: Optional[Constrain] = None, residual: jax.Array = None,
    norm: Optional[jax.Array] = None,
) -> jax.Array:
    """SwiGLU MLP (dense archs and MoE shared experts).

    The gate and up projections run as ONE dual-weight ``swiglu`` dispatch:
    on fused backends that is a single kernel reading x once and writing
    only the activated product (no intermediate gate/up arrays in HBM); on
    other backends ``api.matmul`` decomposes with identical semantics.
    Under the explicit ``dip_tp`` backend this is the canonical Megatron
    pair: the column-parallel gate/up swiglu runs collective-free and the
    row-parallel down-projection pays the block's single psum.
    ``residual`` fuses the block's skip connection into the down-projection
    the same way.  ``norm`` takes the pre-FFN RMSNorm gain when the backend
    fuses prologues: x arrives UN-normalized and the swiglu dispatch
    normalizes it in its load stage — rmsnorm + gate + up + silu·mul in ONE
    kernel launch.
    """
    constrain = layers.resolve_constrain(plan, constrain)
    lk = dict(backend=cfg.matmul_backend, compute_dtype=x.dtype)
    gk = dict(lk) if norm is None else dict(
        lk, prologue="rmsnorm", prologue_operands=(norm,),
        prologue_eps=cfg.norm_eps,
    )
    h = layers.linear(x, (p["w_gate"], p["w_up"]), epilogue="swiglu", **gk)
    h = constrain(h, "ffn_hidden")
    if residual is not None:
        return layers.linear(h, p["w_down"], epilogue="residual",
                             epilogue_operands=(residual,), **lk)
    return layers.linear(h, p["w_down"], **lk)


def moe_capacity(tokens: int, cfg) -> int:
    cap = math.ceil(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_ffn(
    x: jax.Array, p: Dict, cfg, *, plan=None,
    constrain: Optional[Constrain] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Routed expert FFN.  Returns (output, aux_loss).

    Dispatch is *grouped by sequence*: every routing tensor (one-hot, cumsum,
    scatter/gather indices) carries the batch dim, so under the sharding
    policy all routing math is shard-local (B over DP), the (B, E, C, d)
    expert buffers shard E over TP, and the only cross-device movement is
    the unavoidable token<->expert exchange GSPMD derives from the batched
    scatter/gather (§Perf pair-2 log: the global-token formulation instead
    replicated multi-GB dispatch state per layer).
    """
    constrain = layers.resolve_constrain(plan, constrain)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = moe_capacity(s, cfg)                                   # per-group capacity
    cd = x.dtype

    # ---- router (fp32 for stable softmax) ----
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )                                                            # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                         # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance loss (Switch): E * mean(frac_tokens_e * mean_prob_e)
    load = jax.nn.one_hot(ids[..., 0], e, dtype=jnp.float32).mean((0, 1))
    importance = probs.mean((0, 1))
    aux = cfg.router_aux_loss * e * jnp.sum(load * importance)
    aux = aux + 1e-4 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- dispatch: sort tokens by expert — gather-only, no scatter --------
    # (GSPMD partitions batched take_along_axis gathers along B, but
    # replicates multi-index scatters; §Perf pair-2 iter 7)
    flat_ids = ids.reshape(b, s * k)                             # (B, S*k) slot-major
    gates_flat = gates.reshape(b, s * k).astype(cd)
    order = jnp.argsort(flat_ids, axis=1)                        # stable
    inv_order = jnp.argsort(order, axis=1)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=1)
    src = jnp.repeat(x, k, axis=1)                               # (B, S*k, d)
    sorted_src = jnp.take_along_axis(src, order[..., None], axis=1)

    # expert run boundaries within each group
    erange = jnp.arange(e, dtype=jnp.int32)
    start = jax.vmap(lambda row: jnp.searchsorted(row, erange, side="left"))(sorted_ids)
    end = jax.vmap(lambda row: jnp.searchsorted(row, erange, side="right"))(sorted_ids)
    counts = end - start                                         # (B, E)

    # gather each expert's first C tokens into the (B, E, C, d) buffer
    c_iota = jnp.arange(cap, dtype=jnp.int32)
    gidx = start[:, :, None] + c_iota[None, None, :]             # (B, E, C)
    valid = c_iota[None, None, :] < jnp.minimum(counts, cap)[:, :, None]
    gidx = jnp.clip(gidx, 0, s * k - 1).reshape(b, e * cap)
    buf = jnp.take_along_axis(sorted_src, gidx[..., None], axis=1).reshape(b, e, cap, d)
    buf = buf * valid[..., None].astype(cd)
    buf = constrain(buf, "expert_buf")

    # ---- batched per-expert SwiGLU: weights (E, d, ffe) / (E, ffe, d) ----
    gate_h = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cd))
    up_h = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cd))
    h = layers.swiglu(gate_h, up_h)
    h = constrain(h, "expert_hidden")
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))  # (B, E, C, d)
    y = constrain(y, "expert_buf")

    # ---- combine: gather back (per sorted slot), unsort, gate, sum k ------
    j_iota = jnp.arange(s * k, dtype=jnp.int32)[None, :]
    pos_sorted = j_iota - jnp.take_along_axis(start, sorted_ids, axis=1)
    keep_sorted = pos_sorted < cap
    slot = sorted_ids * cap + jnp.where(keep_sorted, pos_sorted, 0)
    out_sorted = jnp.take_along_axis(
        y.reshape(b, e * cap, d), slot[..., None], axis=1
    ) * keep_sorted[..., None].astype(cd)
    out = jnp.take_along_axis(out_sorted, inv_order[..., None], axis=1)
    out = (out * gates_flat[..., None]).reshape(b, s, k, d).sum(axis=2)

    # shared experts (DeepSeek-style), computed densely for every token
    if cfg.n_shared_experts:
        shared = dense_ffn(
            x,
            {
                "w_gate": p["shared_w_gate"],
                "w_up": p["shared_w_up"],
                "w_down": p["shared_w_down"],
            },
            cfg,
            constrain=constrain,
        )
        out = out + shared
    return constrain(out, "act_btd"), aux
