"""Shared model layers: DiP-aware linear, RMSNorm, RoPE, SwiGLU MLP.

`linear` is the integration point of the paper's technique: every dense
projection in the zoo routes through it.  The weight is either a natural
``jax.Array`` or an ``api.DipWeight`` (permutated storage + logical-shape
metadata), and the kernel choice is a registered backend name
(``cfg.matmul_backend``) resolved by ``repro.api.matmul`` — no stringly-typed
format flags or hand-threaded ``d_out`` here.
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import api

__all__ = [
    "linear",
    "rms_norm",
    "swiglu",
    "rope_frequencies",
    "apply_rope",
    "cross_entropy_loss",
]


def linear(
    x: jax.Array,
    w: Union[jax.Array, api.DipWeight, api.QuantizedDipWeight],
    b: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """``x @ W (+ b)`` through the registered matmul backend.

    The output width comes from the weight itself (``DipWeight.d_out`` for
    permutated storage — the padding bookkeeping lives in the type).  A
    ``QuantizedDipWeight`` keeps its reduced-precision storage + scales as-is
    (only the activations take the compute dtype); with ``backend=None`` it
    dispatches straight to its scheme's quantized kernel.
    """
    x = x.astype(compute_dtype)
    if not isinstance(w, api.QuantizedDipWeight):
        w = w.astype(compute_dtype)
    out = api.matmul(x, w, backend=backend)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    """Inverse frequencies for rotary embeddings (host constant)."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs of channels; x: (..., seq, n_heads, head_dim)."""
    head_dim = x.shape[-1]
    inv_freq = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, *, z_loss: float = 1e-4
) -> jax.Array:
    """Token-mean cross entropy with an optional z-loss stabilizer."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - label_logits
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    return jnp.mean(loss)
