"""Shared model layers: DiP-aware linear, RMSNorm, RoPE, SwiGLU MLP.

`linear` is the integration point of the paper's technique: every dense
projection in the zoo routes through it.  The weight is either a natural
``jax.Array`` or an ``api.DipWeight`` (permutated storage + logical-shape
metadata), and the kernel choice is a registered backend name
(``cfg.matmul_backend``) resolved by ``repro.api.matmul`` — no stringly-typed
format flags or hand-threaded ``d_out`` here.
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import api

__all__ = [
    "linear",
    "resolve_constrain",
    "rms_norm",
    "swiglu",
    "rope_frequencies",
    "rope_tables",
    "apply_rope",
    "cross_entropy_loss",
]


def resolve_constrain(plan, constrain=None):
    """The one plan -> activation-constraint resolution the model stack uses.

    Models take ``plan=`` (a ``repro.distributed.ShardingPlan``) as the
    first-class way to express distribution; the legacy bare ``constrain``
    callback is still honoured when no plan is given (identity when neither
    is).  Duck-typed (anything with ``.constrain(x, tag)`` works) so the
    model layer needs no import of the distributed package.
    """
    if plan is not None:
        return plan.constrain
    return constrain if constrain is not None else (lambda x, tag: x)

_BIAS_EPILOGUES = ("bias", "bias_gelu", "bias_silu")


def linear(
    x: jax.Array,
    w: Union[jax.Array, api.DipWeight, api.QuantizedDipWeight, tuple, list],
    b: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    compute_dtype=jnp.bfloat16,
    epilogue: Optional[str] = None,
    epilogue_operands=(),
    prologue: Optional[str] = None,
    prologue_operands=(),
    prologue_eps: float = 1e-5,
) -> jax.Array:
    """``epilogue(prologue(x) @ W)`` through the registered matmul backend.

    The output width comes from the weight itself (``DipWeight.d_out`` for
    permutated storage — the padding bookkeeping lives in the type).  A
    ``QuantizedDipWeight`` keeps its reduced-precision storage + scales as-is
    (only the activations take the compute dtype); with ``backend=None`` it
    dispatches straight to its scheme's quantized kernel.

    ``epilogue`` selects a fused flush-stage epilogue (``api.EPILOGUES``):
    ``"swiglu"`` takes a ``(w_gate, w_up)`` weight pair, ``"residual"``
    takes the residual through ``epilogue_operands``.  A bias ``b`` always
    rides the epilogue path — fused into the kernel flush on backends that
    support it, applied in the same f32 epilogue arithmetic otherwise — so
    there is no per-call output-sized ``b.astype`` copy on either path.

    ``prologue="rmsnorm"`` fuses the pre-projection RMSNorm into the
    kernel's x-block load (``prologue_operands=(gain,)``, ``prologue_eps``
    the norm epsilon) — same arithmetic as ``rms_norm(x, gain) @ W``, one
    kernel launch on backends that fuse it, decomposed elsewhere.
    """
    x = x.astype(compute_dtype)

    def adapt(wi):
        return wi if isinstance(wi, api.QuantizedDipWeight) else wi.astype(compute_dtype)

    w = tuple(adapt(wi) for wi in w) if isinstance(w, (tuple, list)) else adapt(w)
    operands = tuple(epilogue_operands)
    if b is not None:
        if epilogue is None:
            epilogue = "bias"
        elif epilogue not in _BIAS_EPILOGUES:
            raise ValueError(
                f"a bias only composes with the bias epilogues "
                f"{_BIAS_EPILOGUES}, got epilogue={epilogue!r}"
            )
        operands = (b,) + operands
    return api.matmul(
        x, w, backend=backend, epilogue=epilogue, epilogue_operands=operands,
        prologue=prologue, prologue_operands=tuple(prologue_operands),
        prologue_eps=prologue_eps,
    )


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    """Inverse frequencies for rotary embeddings (host constant)."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """``(cos, sin)`` rotation tables for the given absolute positions.

    Computed ONCE per forward and threaded through every layer — the angle
    table and its cos/sin are position-only, so recomputing them per layer
    (the historical ``apply_rope`` behavior) was n_layers-1 redundant
    transcendental sweeps per step.  Shapes broadcast over heads:
    (..., seq, 1, head_dim/2), float32.
    """
    inv_freq = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., seq, hd/2)
    return jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float, *, tables=None
) -> jax.Array:
    """Rotate pairs of channels; x: (..., seq, n_heads, head_dim).

    ``tables`` takes precomputed :func:`rope_tables` (the hoisted per-forward
    path); without it the tables are derived from ``positions`` on the fly —
    the original signature, kept as a thin wrapper for direct callers.
    """
    if tables is None:
        tables = rope_tables(positions, x.shape[-1], theta)
    cos, sin = tables
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array,
    labels: jax.Array,
    *,
    z_loss: float = 1e-4,
    mask: Optional[jax.Array] = None,
    ignore_index: int = -100,
) -> jax.Array:
    """Valid-token-mean cross entropy with an optional z-loss stabilizer.

    Tokens whose label equals ``ignore_index`` (the -100 convention, used
    for padding and prompt tokens) and tokens zeroed by ``mask`` are
    excluded from both the mean and the gradient; the divisor is the count
    of valid tokens, not the batch size.  With every token valid this is
    exactly the historical unmasked mean.  ``kernels.lm_head_ce`` honours
    the same contract without materializing the logits.
    """
    logits = logits.astype(jnp.float32)
    valid = labels != ignore_index
    if mask is not None:
        valid = valid & (mask != 0)
    safe = jnp.where(valid, labels, 0)  # ignore_index would be a bad gather
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = logz - label_logits
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    loss = jnp.where(valid, loss, 0.0)
    return jnp.sum(loss) / jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
