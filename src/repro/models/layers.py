"""Shared model layers: DiP-aware linear, RMSNorm, RoPE, SwiGLU MLP.

`linear` is the integration point of the paper's technique: every dense
projection in the zoo routes through it, and its behaviour is selected by two
config axes:

  weight_format = "natural" | "dip"
      "dip" stores the weight DiP-permutated (paper Fig. 3, applied per 64x64
      tile, padded) — the format checkpoints and HBM hold.
  matmul_impl   = "xla" | "pallas_dip" | "pallas_systolic"
      "xla" leaves the matmul to XLA/GSPMD (the distributed default; with
      dip-format weights the de-shear happens as a jnp gather before the dot).
      "pallas_dip" runs the fused de-shear+MXU kernel; "pallas_systolic" runs
      the wavefront-emulation kernel (validation path).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import permute
from repro.kernels import ops

__all__ = [
    "linear",
    "linear_param_shape",
    "store_weight",
    "rms_norm",
    "swiglu",
    "rope_frequencies",
    "apply_rope",
    "cross_entropy_loss",
]


def linear_param_shape(d_in: int, d_out: int, weight_format: str) -> tuple:
    """Storage shape of a (d_in, d_out) weight under the given format."""
    if weight_format == "dip":
        pad = lambda v: v + (-v) % ops.PERM_TILE
        return (pad(d_in), pad(d_out))
    return (d_in, d_out)


def store_weight(w: jax.Array, weight_format: str) -> jax.Array:
    """Convert a natural-layout weight into its storage format."""
    if weight_format == "dip":
        return ops.to_dip_format(w)
    return w


def linear(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    d_out: Optional[int] = None,
    weight_format: str = "natural",
    matmul_impl: str = "xla",
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """``x @ W (+ b)`` honouring the DiP storage format and kernel choice."""
    d_out = d_out if d_out is not None else (w.shape[-1] if weight_format == "natural" else None)
    x = x.astype(compute_dtype)
    w = w.astype(compute_dtype)

    if weight_format == "natural":
        if matmul_impl == "xla":
            # NOTE: no preferred_element_type=f32 here — the MXU accumulates
            # in f32 internally regardless, while a f32 *output* forces f32
            # TP all-reduces and f32 cotangents through the whole backward
            # (2x collective + activation bytes; §Perf iteration 3).
            out = jnp.matmul(x, w)
        elif matmul_impl == "pallas_dip":
            # natural weights on the fused kernel = WS baseline kernel
            out = ops.ws_matmul(x, w)
        elif matmul_impl == "pallas_systolic":
            out = ops.dip_matmul_systolic(x, ops.to_dip_format(w), out_features=w.shape[-1])
        else:
            raise ValueError(matmul_impl)
    elif weight_format == "dip":
        if d_out is None:
            raise ValueError("dip-format linear needs d_out (storage is padded)")
        if matmul_impl == "xla":
            wn = permute.unpermute_tiled(w, ops.PERM_TILE)
            xk = x
            if xk.shape[-1] != wn.shape[0]:  # padded K storage
                xk = jnp.pad(xk, [(0, 0)] * (x.ndim - 1) + [(0, wn.shape[0] - xk.shape[-1])])
            out = jnp.matmul(xk, wn)[..., :d_out]
        elif matmul_impl == "pallas_dip":
            out = ops.dip_matmul(x, w, out_features=d_out)
        elif matmul_impl == "pallas_systolic":
            out = ops.dip_matmul_systolic(x, w, out_features=d_out)
        else:
            raise ValueError(matmul_impl)
    else:
        raise ValueError(weight_format)

    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    """Inverse frequencies for rotary embeddings (host constant)."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs of channels; x: (..., seq, n_heads, head_dim)."""
    head_dim = x.shape[-1]
    inv_freq = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, *, z_loss: float = 1e-4
) -> jax.Array:
    """Token-mean cross entropy with an optional z-loss stabilizer."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - label_logits
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    return jnp.mean(loss)
