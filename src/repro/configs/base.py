"""Architecture configuration schema.

One frozen dataclass describes every architecture in the zoo (dense / MoE /
MLA / SSM / hybrid / stub-frontend).  Config files under ``repro/configs/``
instantiate it with the exact assigned hyper-parameters; smoke tests call
``.reduced()`` for a tiny same-family variant.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeCell", "SHAPE_CELLS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001
    # MLA (DeepSeek multi-head latent attention)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (Zamba2-style shared attention)
    attn_every: int = 0              # 0 = no shared attention blocks
    # modality frontend (stubbed per assignment: precomputed embeddings)
    frontend: str = "none"           # none | vision_stub | audio_stub
    # numerics / implementation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    matmul_backend: str = "xla"      # registered repro.api backend name
                                     # (xla | ws | pallas_dip | pallas_systolic
                                     #  | dip_int8w | dip_fp8 | plugins)
    dip_weights: bool = False        # force DiP permutated weight storage even
                                     # for natural-layout backends (e.g. dip
                                     # checkpoints served through XLA/GSPMD)
    quantization: str = "none"       # weight-quantization scheme for the DiP
                                     # projections: none | int8 | fp8_e4m3
                                     # (inference-only; see docs/quantization.md)
    kv_block_size: int = 16          # paged-KV block size (tokens per block)
                                     # for the serving engine (repro.serving);
                                     # see docs/serving.md §Paged KV layout
    kv_quant: str = "none"           # KV-cache storage for paged serving:
                                     #   none  compute-dtype (bf16) reference
                                     #   int8  per-token/head int8 + f32 scales
                                     #         (~2x more sequences per byte;
                                     #          bound in docs/serving.md)
    sharding: str = "gspmd"          # declared parallelism strategy consumed
                                     # by repro.distributed.plan.make_plan:
                                     #   gspmd  implicit XLA partitioning of
                                     #          the plain dot (default)
                                     #   tp     explicit column/row shard_map
                                     #          kernels (dip_tp backend)
                                     #   fsdp   explicit K-sharded
                                     #          all-gather-on-load (dip_fsdp)
                                     #   sp     sequence-parallel: activations
                                     #          stay M-sharded, x blocks ring
                                     #          through the kernel's load
                                     #          stage (dip_sp backend)
                                     #   ep     expert-parallel MoE: expert
                                     #          banks sharded, all-to-all
                                     #          token dispatch (dip_ep)
                                     #   pp     pipeline stages over a "stage"
                                     #          mesh axis (GPipe microbatching
                                     #          via distributed.pipeline)
                                     # (see docs/distributed.md)
    remat: str = "block"             # none | block  (remat each scanned block)
    # notes for DESIGN.md §Arch-applicability
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab storage padded so logits/embeddings shard over any mesh axis
        (multiple of 2048 covers TP<=64 x FSDP<=32); padded lanes are masked
        to -inf in the loss and never indexed by token ids."""
        mult = 2048
        return -(-self.vocab_size // mult) * mult

    @property
    def quant_scheme(self) -> Optional[str]:
        """Validated quantization scheme name, or None when unquantized."""
        if self.quantization == "none":
            return None
        from repro.api import quant  # deferred: keep config import light

        return quant.scheme_info(self.quantization).name

    @property
    def uses_dip_storage(self) -> bool:
        """Whether linear weights are held as permutated-storage pytree nodes
        (``api.DipWeight`` / ``api.QuantizedDipWeight``): forced
        (``dip_weights``), implied by quantization (quantized storage is
        permutated by construction), or required by the backend's declared
        layout (the dip-consuming Pallas kernels)."""
        if self.dip_weights or self.quantization != "none":
            return True
        from repro import api  # deferred: keep config import light

        # sharded backends consume DipWeight storage too (the shard_map
        # bodies run the dip-layout kernels on the local shards)
        return api.backend_layout(self.matmul_backend) in ("dip", "dip_q", "sharded")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and self.attn_every == 0 and self.n_heads == 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.attn_every > 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid families)."""
        return self.ssm_state > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + stacked blocks)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.resolved_head_dim if self.n_heads else 0
        per_layer = 0
        if self.n_heads and not self.use_mla:
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.use_mla:
            per_layer += d * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            per_layer += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            per_layer += self.n_heads * self.v_head_dim * d
        if self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.d_ff_expert
            per_layer += self.n_shared_experts * 3 * d * self.d_ff_expert
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        if self.ssm_state:
            di = self.d_inner
            ssm = d * (2 * di + 2 * self.ssm_state + self.n_ssm_heads) + di * d
            per_layer = ssm if not self.is_hybrid else per_layer  # hybrid counts ssm below
            if self.is_hybrid:
                # mamba blocks every layer + one shared attention block
                return total + self.n_layers * ssm + (
                    d * self.n_heads * hd * 2 + 2 * d * self.n_kv_heads * hd + 3 * d * self.d_ff
                )
        return total + self.n_layers * per_layer

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        dense = self.param_count()
        expert = 3 * self.d_model * self.d_ff_expert
        inactive = (self.n_experts - self.moe_top_k) * expert * self.n_layers
        return dense - inactive

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else 2 * max(1, self.attn_every)),
            d_model=128,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else None,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            d_ff_expert=64 if self.d_ff_expert else 0,
            kv_lora_rank=64 if self.kv_lora_rank else 0,
            qk_nope_head_dim=32 if self.use_mla else self.qk_nope_head_dim,
            qk_rope_head_dim=16 if self.use_mla else self.qk_rope_head_dim,
            v_head_dim=32 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            remat="none",
        )
        if self.attn_every:
            small["n_layers"] = 4
            small["attn_every"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)
