"""Assigned-architecture registry: ``get_config(name)`` / ``ALL_ARCHS``.

One module per architecture (exact hyper-parameters from the assignment,
sources noted per file).  ``--arch <id>`` in the launchers resolves here.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPE_CELLS, ArchConfig, ShapeCell
from repro.configs.shapes import MatmulShape, linear_dims, matmul_shapes

ALL_ARCHS: List[str] = [
    "deepseek_v2_lite_16b",
    "qwen3_moe_235b_a22b",
    "mamba2_370m",
    "llama3_8b",
    "codeqwen15_7b",
    "yi_9b",
    "qwen2_72b",
    "phi3_vision_4_2b",
    "musicgen_medium",
    "zamba2_2_7b",
]

# assignment ids (with dashes/dots) -> module names
_ALIASES: Dict[str, str] = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-370m": "mamba2_370m",
    "llama3-8b": "llama3_8b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "yi-9b": "yi_9b",
    "qwen2-72b": "qwen2_72b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "musicgen-medium": "musicgen_medium",
    "zamba2-2.7b": "zamba2_2_7b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def shape_cells_for(cfg: ArchConfig) -> List[ShapeCell]:
    """The assigned shape set, honouring the long_500k sub-quadratic gate."""
    cells = []
    for cell in SHAPE_CELLS:
        if cell.name == "long_500k" and not cfg.sub_quadratic:
            continue  # skip recorded in DESIGN.md §4 / docs/benchmarks.md §Dry-run
        cells.append(cell)
    return cells


__all__ = [
    "ALL_ARCHS",
    "ArchConfig",
    "ShapeCell",
    "SHAPE_CELLS",
    "MatmulShape",
    "get_config",
    "shape_cells_for",
    "linear_dims",
    "matmul_shapes",
]
