"""DeepSeek-V2-Lite 16B [moe] — arXiv:2405.04434 (hf-verified tier).

Assignment line: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6 — MLA kv_lora=512, 2 shared+160 routed top-6.

The assignment's "64e top-6" and "160 routed" conflict; we follow the
explicit config fields (64 routed experts, top-6, 2 shared) — recorded in
DESIGN.md §4.  All layers are MoE (the real model's dense first layer is
folded into the uniform scanned stack).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=102_400,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_ff_expert=1408,
    rope_theta=10_000.0,
    notes="MLA latent cache (512+64 per token); 2 shared + 64 routed experts top-6.",
)
