"""Zamba2-2.7B [hybrid] — arXiv:2411.15242 (hf tier).

Assignment line: 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 + shared attn blocks.  The single shared
attention+FFN block is applied after every 6th Mamba2 block (9 call sites),
following Zamba2's shared-block pattern (its per-application LoRA deltas and
input concatenation are simplified to direct reuse; DESIGN.md §4).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    attn_every=6,
    notes="54 mamba2 blocks + shared GQA block every 6 layers.",
)
