"""Mamba2-370M [ssm] — arXiv:2405.21060 (unverified tier).

Assignment line: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
    notes="Attention-free SSD; DiP applies to in/out projections and the "
          "chunked quadratic forms; recurrent decay is VPU work.",
)
