"""Llama-3-8B [dense] — arXiv:2407.21783 (unverified tier).

Assignment line: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    notes="GQA kv=8, 128k vocab.",
)
