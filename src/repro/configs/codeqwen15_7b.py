"""CodeQwen1.5-7B [dense] — hf:Qwen/CodeQwen1.5-7B (hf tier).

Assignment line: 32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416.
Qwen1.5 architecture: QKV bias, MHA (kv == heads).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13_440,
    vocab_size=92_416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    notes="qwen1.5 arch: QKV bias, full MHA.",
)
