"""Qwen3-MoE 235B-A22B [moe] — hf:Qwen/Qwen3-30B-A3B family (hf tier).

Assignment line: 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936,
MoE 128e top-8.  head_dim=128 per the Qwen3 family (explicit head_dim).
Qwen3's qk-norm is omitted (uniform attention path), noted in DESIGN.md.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    n_experts=128,
    n_shared_experts=0,
    moe_top_k=8,
    d_ff_expert=1536,
    rope_theta=1_000_000.0,
    notes="128 routed experts top-8, no shared experts; GQA kv=4.",
)
