"""MusicGen-medium [audio] — arXiv:2306.05284 (hf tier).

Assignment line: 48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048 —
decoder-only over EnCodec tokens.  The EnCodec frontend (4 codebooks,
delay-pattern interleaving) is a STUB: input_specs() provides precomputed
frame embeddings; the decoder predicts one 2048-way codebook stream.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio_stub",
    rope_theta=10_000.0,
    notes="24 heads (not divisible by 16-way TP) — attention uses "
          "sequence sharding instead of head sharding; see docs/benchmarks.md §Perf.",
)
