"""Phi-3-Vision 4.2B [vlm] — hf:microsoft/Phi-3-vision-128k-instruct (hf tier).

Assignment line: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064 —
phi3-mini backbone + CLIP frontend.  Per the assignment, the modality
frontend is a STUB: input_specs() provides precomputed patch embeddings
(batch, seq, d_model); only the transformer backbone is modeled.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    frontend="vision_stub",
    rope_theta=10_000.0,
    notes="Backbone only; CLIP patch embeddings stubbed via input_specs().",
)
