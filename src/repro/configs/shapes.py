"""Matmul workload shapes implied by an architecture config.

The autotuner (``repro.api.autotune``) and the launchers' ``--autotune``
flag need the concrete (m, k, n) problems a model dispatches so they can be
measured on the live device.  This module enumerates the distinct linear
projections of an :class:`~repro.configs.base.ArchConfig` — the same set
``models.transformer.param_template`` materializes as weights — with the M
dimension supplied by the caller (tokens per dispatch: ``batch * seq`` for
training/prefill, the slot count for decode).
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

from repro.configs.base import ArchConfig

__all__ = ["MatmulShape", "linear_dims", "matmul_shapes", "stage_matmul_shapes"]


class MatmulShape(NamedTuple):
    name: str
    m: int
    k: int
    n: int


def linear_dims(cfg: ArchConfig) -> List[Tuple[str, int, int]]:
    """Distinct (name, d_in, d_out) pairs of every dense projection.

    Mirrors the weight layout of ``models.transformer.param_template``
    (attention / MLA / MoE / SSM / hybrid families); the embedding table is
    excluded (a gather, not a matmul) but the untied LM head is included.
    """
    d = cfg.d_model
    dims: List[Tuple[str, int, int]] = []

    def add(name: str, d_in: int, d_out: int) -> None:
        if d_in > 0 and d_out > 0:
            dims.append((name, d_in, d_out))

    if cfg.ssm_state:
        from repro.models.ssm import ssm_dims

        sd = ssm_dims(cfg)
        add("in_proj", d, sd["in_dim"])
        add("out_proj", sd["d_inner"], d)
    if cfg.n_heads and not cfg.use_mla and (not cfg.ssm_state or cfg.is_hybrid):
        hd = cfg.resolved_head_dim
        add("wq", d, cfg.n_heads * hd)
        add("wk", d, cfg.n_kv_heads * hd)
        add("wv", d, cfg.n_kv_heads * hd)
        add("wo", cfg.n_heads * hd, d)
    if cfg.use_mla:
        add("wq", d, cfg.n_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim))
        add("w_dkv", d, cfg.kv_lora_rank)
        add("w_krope", d, cfg.qk_rope_head_dim)
        add("w_uk", cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_head_dim)
        add("w_uv", cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim)
        add("wo", cfg.n_heads * cfg.v_head_dim, d)
    if cfg.is_moe:
        add("router", d, cfg.n_experts)
        add("expert_gate_up", d, cfg.d_ff_expert)
        add("expert_down", cfg.d_ff_expert, d)
        if cfg.n_shared_experts:
            sff = cfg.n_shared_experts * cfg.d_ff_expert
            add("shared_gate_up", d, sff)
            add("shared_down", sff, d)
    elif cfg.d_ff and (not cfg.ssm_state or cfg.is_hybrid):
        add("mlp_gate_up", d, cfg.d_ff)
        add("mlp_down", cfg.d_ff, d)
    if not cfg.tie_embeddings:
        add("lm_head", d, cfg.padded_vocab)
    return dims


def matmul_shapes(cfg: ArchConfig, *, tokens: int = 256) -> List[MatmulShape]:
    """Deduplicated (m, k, n) workloads for ``tokens`` rows per dispatch.

    Projections sharing a (d_in, d_out) signature (e.g. gate and up in a
    SwiGLU MLP) collapse into one entry — tuning measures problems, not
    parameter names.
    """
    if tokens <= 0:
        raise ValueError(f"tokens must be positive, got {tokens}")
    out: List[MatmulShape] = []
    seen = set()
    for name, d_in, d_out in linear_dims(cfg):
        key = (tokens, d_in, d_out)
        if key in seen:
            continue
        seen.add(key)
        out.append(MatmulShape(name, tokens, d_in, d_out))
    return out


def stage_matmul_shapes(
    cfg: ArchConfig, *, train_tokens: int, prefill_tokens: int, decode_slots: int
) -> Dict[str, List[MatmulShape]]:
    """The per-stage matmul workload matrix of one fleet cell.

    A train step and a prefill chunk dispatch ``batch * seq`` rows per
    projection; a paged decode step dispatches one row per slot.  The fleet
    driver (``benchmarks/fleet.py``) records these under each cell so the
    BENCH_fleet.json baseline documents *which problems* a cell timed — the
    same (m, k, n) set the autotuner would measure for that stage.
    """
    return {
        "train": matmul_shapes(cfg, tokens=train_tokens),
        "prefill": matmul_shapes(cfg, tokens=prefill_tokens),
        "decode": matmul_shapes(cfg, tokens=decode_slots),
    }
