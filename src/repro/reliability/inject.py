"""Deterministic fault injection for chaos tests.

Every primitive here is a pure function of ``(value, seed, target)`` —
same seed + same target ⇒ **bit-identical corruption** (a property the
test suite pins down), so a chaos test that detects-and-recovers today
reproduces byte-for-byte in CI tomorrow.

Device-data faults:

* :func:`bitflip` — XOR seeded bit positions into an array's raw storage
  (any dtype, ml_dtypes included, via a same-width unsigned view).  The
  hardware-faithful model for SDC in weights/activations/collective
  buffers.
* :func:`plant_nan` — overwrite seeded elements with NaN (float arrays
  only); the model for a poisoned accumulator.
* :func:`corrupt_pytree` — address a leaf of a params/state pytree by
  key-path substring and apply either of the above.
* :func:`corrupt_kv_block` — poison one physical block of a serving
  ``PagedKVCache`` (bf16 pools directly; int8 pools through their float32
  scale rows, since integer storage cannot hold a NaN).

Host-code faults (crash injection):

* :func:`failpoint` — a context manager arming a named fail-point;
  :func:`maybe_fail` raises at matching sites.  ``checkpoint.manager``
  and ``serving.kv_cache`` expose sites so tests can prove atomic saves
  and allocator invariants under mid-operation crashes.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "bitflip",
    "plant_nan",
    "corrupt_pytree",
    "corrupt_kv_block",
    "failpoint",
    "maybe_fail",
    "InjectedFault",
]


class InjectedFault(RuntimeError):
    """Raised by an armed fail-point (distinguishable from real bugs)."""


# --------------------------------------------------------------------------
# device-data corruption
def _host(arr) -> np.ndarray:
    return np.array(jax.device_get(arr), copy=True)


def bitflip(
    arr: Any,
    *,
    seed: int,
    n_flips: int = 1,
    bit: Optional[int] = None,
) -> np.ndarray:
    """Flip ``n_flips`` seeded bits in ``arr``'s raw storage.

    ``bit`` pins the bit position within each element (e.g. 30 for a
    float32 exponent MSB, 14 for bfloat16, 6 for int8/fp8-e4m3 — the
    guaranteed-loud flips the chaos tests use); ``None`` draws it from the
    same seeded stream.  Returns a host array of the original dtype.
    """
    host = _host(arr)
    if host.size == 0:
        return host
    width = host.dtype.itemsize
    raw = host.view(np.dtype(f"u{width}")).reshape(-1)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, raw.size, size=n_flips)
    bits = (
        np.full(n_flips, bit, np.uint64) if bit is not None
        else rng.integers(0, 8 * width, size=n_flips).astype(np.uint64)
    )
    for i, b in zip(idx, bits):
        raw[i] ^= raw.dtype.type(1) << raw.dtype.type(b)
    return raw.view(host.dtype).reshape(host.shape)


def plant_nan(arr: Any, *, seed: int, n: int = 1) -> np.ndarray:
    """Overwrite ``n`` seeded elements of a float array with NaN."""
    host = _host(arr)
    flat = host.reshape(-1)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, flat.size, size=n)
    flat[idx] = np.nan
    return host


def corrupt_pytree(
    tree: Any,
    target: str,
    *,
    seed: int,
    mode: str = "bitflip",
    bit: Optional[int] = None,
    n: int = 1,
) -> Tuple[Any, str]:
    """Corrupt the first array leaf whose key-path contains ``target``.

    Returns ``(new_tree, hit_path)``; raises ``KeyError`` if no leaf
    matches.  Leaf order (and therefore which leaf a substring hits) is
    the deterministic ``tree_flatten_with_path`` order.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    hit = None
    leaves = []
    for path, leaf in flat:
        path_s = "/".join(str(k) for k in path)
        if hit is None and target in path_s and hasattr(leaf, "dtype"):
            hit = path_s
            if mode == "bitflip":
                leaf = bitflip(leaf, seed=seed, n_flips=n, bit=bit)
            elif mode == "nan":
                leaf = plant_nan(leaf, seed=seed, n=n)
            else:
                raise ValueError(f"mode must be 'bitflip'|'nan', got {mode!r}")
        leaves.append(leaf)
    if hit is None:
        raise KeyError(f"no array leaf path contains {target!r}")
    return jax.tree_util.tree_unflatten(treedef, leaves), hit


def corrupt_kv_block(kv, block: int, *, seed: int = 0, mode: str = "nan") -> str:
    """Poison physical block ``block`` of a ``PagedKVCache`` in place.

    Pools are block-indexed ``(layers, num_blocks, block_size, ...)``; every
    layer's rows of the target block are corrupted in the first float pool
    found (for quantized KV the int8 payload cannot hold a NaN, so its
    float32 scale rows take the hit — the dequantized read is poisoned all
    the same).  Returns the name of the pool that was corrupted.
    """
    layers = kv.pools["layers"]

    def try_corrupt(pool_dict) -> Optional[str]:
        for name in sorted(pool_dict):
            leaf = pool_dict[name]
            if not hasattr(leaf, "dtype"):
                continue
            import jax.numpy as jnp

            if not jnp.issubdtype(jnp.dtype(leaf.dtype), jnp.floating):
                continue
            host = _host(leaf)
            if host.ndim < 3 or host.shape[1] <= block:
                continue
            if mode == "nan":
                host[:, block] = np.nan
            else:
                host[:, block] = bitflip(
                    host[:, block], seed=seed,
                    n_flips=max(1, host[:, block].size // 2), bit=None,
                ).reshape(host[:, block].shape)
            pool_dict[name] = jax.device_put(host).astype(leaf.dtype)
            return name
        return None

    target = layers["attn"] if isinstance(layers.get("attn"), dict) else layers
    name = try_corrupt(target)
    if name is None:
        raise ValueError(
            f"no corruptible float pool for block {block} "
            f"(block_size={kv.block_size})"
        )
    return name


# --------------------------------------------------------------------------
# host fail-points (crash injection)
_ARMED: Dict[str, Callable[[], None]] = {}


@contextlib.contextmanager
def failpoint(
    name: str,
    *,
    exc: Any = InjectedFault,
    count: int = 1,
) -> Iterator[None]:
    """Arm fail-point ``name`` for the duration of the ``with`` block.

    The first ``count`` calls to ``maybe_fail(name)`` raise; later calls
    pass.  ``exc`` may be an exception *instance* (raised as-is), an
    exception *class*, or a zero-arg factory.  Fail-points nest per-name;
    re-arming an armed name raises (ambiguous intent).
    """
    if name in _ARMED:
        raise ValueError(f"fail-point {name!r} is already armed")
    remaining = [count]

    def trip() -> None:
        if remaining[0] <= 0:
            return
        remaining[0] -= 1
        if isinstance(exc, BaseException):
            raise exc
        if isinstance(exc, type) and issubclass(exc, BaseException):
            raise exc(f"injected fault at {name!r}")
        e = exc()
        raise e if isinstance(e, BaseException) else e(
            f"injected fault at {name!r}"
        )

    _ARMED[name] = trip
    try:
        yield
    finally:
        _ARMED.pop(name, None)


def maybe_fail(name: str) -> None:
    """Call at an injection site; no-op unless ``name`` is armed."""
    trip = _ARMED.get(name)
    if trip is not None:
        trip()
