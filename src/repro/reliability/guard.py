"""Fail-safe training: screen every step, skip the poisoned ones.

Two fault classes, two detectors, one jitted wrapper:

* **Transient numerics** (a NaN/Inf loss or gradient from a poisoned
  batch or a compute fault): screened by finiteness checks on the loss
  and global grad norm.  The update is discarded, the step counter still
  advances (so the loop cannot wedge on one batch), ``skipped`` counts it.
* **Weight-storage corruption** (a flipped bit in a parameter between
  steps): detected by the **fingerprint side-car** — one float32
  ``Σ|leaf|`` per parameter leaf, recomputed at the top of every step and
  compared against the reference carried in ``state["fingerprint"]``.
  The reference is refreshed from the *applied* update when a step
  commits and frozen when one is skipped, so persistent corruption keeps
  tripping ``weight_faults`` every step until the host recovers (the
  ``Trainer`` restores the latest checkpoint — docs/reliability.md
  §Degradation ladder).

The fingerprint is deliberately a side-car, NOT the per-weight
:class:`~repro.reliability.abft.AbftChecksum` child: an attached checksum
would be an optimizer leaf, and weight decay would corrupt the reference
itself.  Side-car state never meets the optimizer.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "fingerprint",
    "fingerprint_paths",
    "guarded_step_fn",
    "locate_fingerprint_fault",
    "GUARD_KEYS",
]

# state keys the guard adds next to params/opt_state/step
GUARD_KEYS = ("fingerprint", "skipped", "weight_faults")

# |Σ|leaf|| drift tolerated between the stored reference and a recompute
# (different jit programs may reduce in different orders); loud faults —
# exponent/sign flips, NaNs — move the sum by ~the element magnitude
_FP_RTOL = 1e-5
_FP_ATOL = 1e-6


def fingerprint(params: Any) -> jax.Array:
    """(n_leaves,) float32 vector of per-leaf ``Σ|leaf|`` checksums, in
    deterministic ``tree_flatten`` order.  NaN anywhere in a leaf makes
    its entry NaN — which never compares equal, so planted NaNs trip the
    guard too."""
    leaves = jax.tree_util.tree_leaves(params)
    return jnp.stack(
        [jnp.sum(jnp.abs(l.astype(jnp.float32))) for l in leaves]
    )


def fingerprint_paths(params: Any) -> List[str]:
    """Leaf path strings aligned with :func:`fingerprint`'s entries."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return ["/".join(str(k) for k in path) for path, _ in flat]


def locate_fingerprint_fault(params: Any, reference) -> List[str]:
    """Host-side diagnosis: names the param leaves whose recomputed
    fingerprint disagrees with ``reference`` (the corrupt-leaf diagnostic
    the Trainer prints before recovering)."""
    import numpy as np

    now = np.asarray(jax.device_get(fingerprint(params)), np.float64)
    ref = np.asarray(jax.device_get(reference), np.float64)
    tol = _FP_ATOL + _FP_RTOL * np.abs(ref)
    bad = ~(np.abs(now - ref) <= tol)  # NaN compares unequal -> flagged
    paths = fingerprint_paths(params)
    return [p for p, b in zip(paths, bad) if b]


def _fp_ok(now: jax.Array, ref: jax.Array) -> jax.Array:
    return jnp.all(jnp.abs(now - ref) <= _FP_ATOL + _FP_RTOL * jnp.abs(ref))


def guarded_step_fn(step_fn: Callable) -> Callable:
    """Wrap a ``step(state, batch) -> (state, metrics)`` with the guard.

    The guarded state carries :data:`GUARD_KEYS` next to the inner keys;
    metrics gain ``skipped`` / ``weight_fault`` (0/1 for this step) and
    ``skipped_total`` / ``weight_faults_total`` counters.  Pure and
    jit-compatible: the skip is a ``jnp.where`` select between the
    applied and the incoming state (the gradients were already computed
    to be screened — discarding them costs nothing extra)."""

    def gstep(state: Dict[str, Any], batch) -> Tuple[Dict[str, Any], Dict]:
        inner = {k: v for k, v in state.items() if k not in GUARD_KEYS}
        fp_ref = state["fingerprint"]

        # weight integrity first: were the params tampered with since the
        # last committed step?
        fp_now = fingerprint(inner["params"])
        weights_ok = _fp_ok(fp_now, fp_ref)

        new_inner, metrics = step_fn(inner, batch)
        loss_ok = jnp.isfinite(metrics["loss"]) & jnp.isfinite(
            metrics["grad_norm"]
        )
        ok = weights_ok & loss_ok

        committed = {
            k: jax.tree_util.tree_map(
                lambda n, o: jnp.where(ok, n, o), new_inner[k], inner[k]
            )
            for k in ("params", "opt_state")
        }
        # the step counter always advances — a skipped batch must not
        # wedge the loop — and the fingerprint reference only moves when
        # the update actually committed (a frozen reference keeps
        # persistent corruption visible every step until recovery)
        committed["step"] = new_inner["step"]
        fp_next = jnp.where(ok, fingerprint(committed["params"]), fp_ref)

        skipped = jnp.where(ok, 0, 1).astype(jnp.int32)
        wfault = jnp.where(weights_ok, 0, 1).astype(jnp.int32)
        new_state = dict(
            committed,
            fingerprint=fp_next,
            skipped=state["skipped"] + skipped,
            weight_faults=state["weight_faults"] + wfault,
        )
        metrics = dict(
            metrics,
            skipped=skipped,
            weight_fault=wfault,
            skipped_total=new_state["skipped"],
            weight_faults_total=new_state["weight_faults"],
        )
        return new_state, metrics

    return gstep


def init_guard_state(state: Dict[str, Any]) -> Dict[str, Any]:
    """Add the guard side-car keys to a fresh train state."""
    return dict(
        state,
        fingerprint=fingerprint(state["params"]),
        skipped=jnp.zeros((), jnp.int32),
        weight_faults=jnp.zeros((), jnp.int32),
    )
