"""ABFT checksum verification for the matmul surface.

Huang & Abraham's algorithm-based fault tolerance encodes a matmul's
operands with checksum rows/columns so the *result* can be audited in
O(M·N) instead of recomputed in O(M·K·N).  For ``out = x @ W`` the weight
side precomputes two natural-domain vectors (once, next to the weight's
quantization scales):

    row      r[k]  = Σ_n W[k, n]        the row-checksum column, so
                                        Σ_n out[m, n] == x[m, :] @ r
    row_abs  a[k]  = Σ_n |W[k, n]|      its magnitude twin — the scale the
                                        tolerance model is relative to

plus one storage-domain vector:

    col      c[j]  = Σ_k P[k, j]        the column sums of the *permutated*
                                        storage ``P`` (raw int codes for
                                        quantized weights, so the reference
                                        is integer-exact)

``col`` commutes with the DiP permutation for free — the permutation
rotates rows *within* a column (paper Fig. 3), so every storage column
holds exactly the elements of one logical output channel and its sum is
layout-invariant.  Conceptually the probe is just one more row streaming
through the array diagonally like any other input (docs/architecture.md
§Reliability maps it onto the paper's dataflow); this implementation
evaluates it post-hoc in the dispatch wrapper so the verified output is
**bit-identical** to the unverified one — a property the conformance
suite pins down across every backend × epilogue × dtype.

Two verification modes (the degradation ladder, docs/reliability.md):

* ``probe``   — full output audit: ``rowsum(out)`` vs ``x @ row`` under the
  dtype-aware tolerance below.  Valid whenever the epilogue is *linear*
  (``none`` / ``bias`` / ``residual`` — the probe shifts by ``Σ b`` /
  ``rowsum(residual)``), no fused prologue rewrites x, and the backend
  declares ``abft=True`` (its kernel computes an exact matmul).
* ``storage`` — weight-integrity audit: recompute ``col`` (and the scale
  column sums for quantized weights) against the stored reference, plus a
  nonfinite screen of the output.  Catches storage corruption under any
  epilogue; it is what nonlinear epilogues (``bias_gelu`` / ``bias_silu``
  / ``swiglu``), fused prologues, and ``abft=False`` backends degrade to.

Tolerance model: backends differ in accumulation order and activation
handling, so the probe cannot demand equality.  Row ``m`` passes iff

    |rowsum(out)[m] - expected[m]| <= ATOL + rtol(dtypes) * (|x[m]| @ a + s)

where ``s`` collects the epilogue operands' magnitudes and, for the W8A8
int8 kernel, the dynamic activation-quantization term
``amax(|x[m]|)/(2·127) * Σ a`` (per-element rounding of x is at most half
a quantization step; the probe sees its worst-case dot with ``|W|``).
``rtol`` is keyed on the widest-error dtype in play — see :data:`RTOL`.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.api.quant import QuantizedDipWeight
from repro.api.weights import DipWeight
from repro.kernels import epilogue as epilogue_lib

__all__ = [
    "ATOL",
    "RTOL",
    "AbftChecksum",
    "ReliabilityError",
    "attach_checksums",
    "raise_on_fault",
    "verify_matmul",
    "weight_checksum",
]


class ReliabilityError(RuntimeError):
    """A checksum/finiteness audit failed (or an integrity check at restore)."""


class AbftChecksum(NamedTuple):
    """Precomputed per-weight checksums (rides the pytree like scales do).

    ``col``/``scale_col`` live in the permutated storage domain; ``row`` /
    ``row_abs`` in the natural domain (length ``d_in``).  All float32.
    """

    col: Any                  # (..., Np) storage column sums
    row: Any                  # (..., d_in) natural row-checksum column W @ 1
    row_abs: Any              # (..., d_in) |W| @ 1
    scale_col: Any = None     # (..., Np) quantized-scale column sums


# Probe tolerances, keyed by the coarsest dtype in play.  Deliberately
# generous: a false positive poisons a healthy serving/training step, while
# the faults worth catching (flipped exponent/sign bits, planted NaNs) sit
# orders of magnitude above any rounding cloud.
RTOL: Dict[str, float] = {
    "float32": 1e-4,
    "bfloat16": 2e-2,
    "float16": 5e-3,
    "int8": 5e-2,        # W8A8: weight rounding; activations add an amax term
    "fp8_e4m3": 8e-2,
}
ATOL = 1e-3


def _f32(t) -> jax.Array:
    return jnp.asarray(t, jnp.float32)


def _natural32(w: Union[DipWeight, QuantizedDipWeight, jax.Array]) -> jax.Array:
    if isinstance(w, QuantizedDipWeight):
        return w.to_natural(jnp.float32)
    if isinstance(w, DipWeight):
        return _f32(w.to_natural())
    return _f32(w)


def weight_checksum(
    w: Union[DipWeight, QuantizedDipWeight, jax.Array]
) -> AbftChecksum:
    """Compute the checksum set for any weight type (one O(K·N) pass).

    For quantized weights ``col`` sums the raw integer codes — sums of
    |q| <= 127 over any realistic K are exact in float32, so the reference
    admits a zero-tolerance compare — and ``scale_col`` additionally pins
    the dequantization scales.
    """
    wn32 = _natural32(w)
    row = wn32.sum(axis=-1)
    row_abs = jnp.abs(wn32).sum(axis=-1)
    if isinstance(w, (DipWeight, QuantizedDipWeight)):
        col = _f32(w.data).sum(axis=-2)
    else:
        col = wn32.sum(axis=-2)
    scale_col = None
    if isinstance(w, QuantizedDipWeight):
        scale_col = _f32(w.scale).sum(axis=-2)
    return AbftChecksum(col=col, row=row, row_abs=row_abs, scale_col=scale_col)


def attach_checksums(tree: Any) -> Any:
    """Stamp :class:`AbftChecksum` onto every ``DipWeight`` /
    ``QuantizedDipWeight`` node of a pytree (idempotent).

    The checksum rides as an optional pytree *child* — exactly like the
    quantization scales — so it survives jit, device placement, and
    checkpoint round-trips.  Attach AFTER optimizer-state creation and
    plan placement: checksums are frozen verification artifacts, not
    trainable state (the training guard uses the fingerprint side-car in
    :mod:`repro.reliability.guard` instead, precisely so weight decay can
    never touch a reference).
    """

    def stamp(node):
        if isinstance(node, (DipWeight, QuantizedDipWeight)):
            if node.checksum is not None:
                return node
            return node.with_checksum(weight_checksum(node))
        return node

    return jax.tree_util.tree_map(
        stamp, tree,
        is_leaf=lambda x: isinstance(x, (DipWeight, QuantizedDipWeight)),
    )


# --------------------------------------------------------------------------
# verification
def _checksum_of(w) -> AbftChecksum:
    if isinstance(w, (DipWeight, QuantizedDipWeight)) and w.checksum is not None:
        return w.checksum
    return weight_checksum(w)


def _rtol_for(x_dtype, weights) -> float:
    names = [str(jnp.dtype(x_dtype))]
    for w in weights:
        if isinstance(w, QuantizedDipWeight):
            names.append(w.scheme)
        else:
            names.append(str(jnp.dtype(w.dtype)))
    return max(RTOL.get(n, RTOL["float32"]) for n in names)


def _storage_ok(w, ref: AbftChecksum) -> jax.Array:
    """Recomputed column sums vs the stored reference.

    The reference and the recompute run the identical reduction on the
    identical storage, so agreement is deterministic; the tolerance only
    absorbs reference checksums that crossed a dtype/device boundary
    (e.g. a checkpoint round-trip)."""
    if isinstance(w, (DipWeight, QuantizedDipWeight)):
        col_now = _f32(w.data).sum(axis=-2)
    else:
        col_now = _f32(w).sum(axis=-2)
    tol = 1e-5 * (1.0 + jnp.abs(ref.col))
    ok = jnp.all(jnp.abs(col_now - ref.col) <= tol)
    if isinstance(w, QuantizedDipWeight) and ref.scale_col is not None:
        s_now = _f32(w.scale).sum(axis=-2)
        s_tol = 1e-5 * (1.0 + jnp.abs(ref.scale_col))
        ok = ok & jnp.all(jnp.abs(s_now - ref.scale_col) <= s_tol)
    return ok


_LINEAR_EPILOGUES = frozenset({"none", "bias", "residual"})


def probe_applicable(
    epilogue: str = "none",
    prologue: str = "none",
    backend_abft: bool = True,
    n_weights: int = 1,
) -> bool:
    """Whether the full row-sum probe is mathematically valid for this
    dispatch (the top rung of the degradation ladder)."""
    return (
        backend_abft
        and n_weights == 1
        and epilogue in _LINEAR_EPILOGUES
        and prologue == "none"
    )


def verify_matmul(
    x: jax.Array,
    weights: Sequence[Any],
    out: jax.Array,
    *,
    epilogue: str = "none",
    operands: Sequence[jax.Array] = (),
    prologue: str = "none",
    backend_abft: bool = True,
    mode: str = "auto",
) -> Dict[str, Any]:
    """Audit ``out`` as the claimed result of ``epilogue(x @ w, ...)``.

    Pure and jit-compatible; returns a report dict of scalars —
    ``mode`` (static str), ``ok`` / ``finite`` / ``checksum_ok`` (bool),
    ``rows_flagged`` (int32), ``max_excess`` (float32: worst row's error
    beyond its tolerance; <= 0 when clean, probe mode only).

    ``mode="auto"`` picks the strongest applicable rung; requesting
    ``"probe"`` where it is invalid raises (the caller asked for math
    that does not hold)."""
    weights = tuple(weights)
    can_probe = probe_applicable(
        epilogue, prologue, backend_abft, len(weights)
    )
    if mode == "auto":
        mode = "probe" if can_probe else "storage"
    elif mode == "probe" and not can_probe:
        raise ValueError(
            f"probe verification is invalid here (epilogue={epilogue!r}, "
            f"prologue={prologue!r}, abft={backend_abft}, "
            f"{len(weights)} weights): the row-sum identity only holds for "
            "a single weight under a linear epilogue on an abft-capable "
            "backend — use mode='storage' or 'auto'"
        )
    elif mode not in ("probe", "storage"):
        raise ValueError(f"mode must be 'auto'|'probe'|'storage', got {mode!r}")

    finite = jnp.all(jnp.isfinite(_f32(out)))

    if mode == "storage":
        ok = finite
        for w in weights:
            ok = ok & _storage_ok(w, _checksum_of(w))
        return {
            "mode": "storage",
            "ok": ok,
            "finite": finite,
            "checksum_ok": ok | ~finite,  # isolates the weight-side verdict
            "rows_flagged": jnp.where(ok, 0, 1).astype(jnp.int32),
            "max_excess": jnp.where(ok, -jnp.inf, jnp.inf).astype(jnp.float32),
        }

    ref = _checksum_of(weights[0])
    # A *stored* reference also enables the integer-exact storage compare —
    # strictly stronger than the analog probe for small quantized-code flips
    # that hide inside the W8A8 tolerance.  (Without a stored checksum the
    # compare is vacuous: the reference would be recomputed from the same
    # storage it checks.)
    storage_ok = jnp.asarray(True)
    for w in weights:
        if isinstance(w, (DipWeight, QuantizedDipWeight)) and w.checksum is not None:
            storage_ok = storage_ok & _storage_ok(w, w.checksum)
    x32 = _f32(x)
    out32 = _f32(out)
    rowsum = out32.sum(axis=-1)                       # (...,)
    expected = x32 @ ref.row
    magnitude = jnp.abs(x32) @ ref.row_abs
    spec = epilogue_lib.spec(epilogue)
    if spec.bias:
        b32 = _f32(operands[0]).reshape(-1)
        expected = expected + b32.sum()
        magnitude = magnitude + jnp.abs(b32).sum()
    if spec.residual:
        r32 = _f32(operands[0])
        expected = expected + r32.sum(axis=-1)
        magnitude = magnitude + jnp.abs(r32).sum(axis=-1)
    rtol = _rtol_for(x.dtype, weights)
    tol = ATOL + rtol * magnitude
    if isinstance(weights[0], QuantizedDipWeight) and weights[0].scheme == "int8":
        # W8A8: the kernel quantizes x per-row on the fly; worst-case probe
        # drift is half an activation step dotted against |W| summed over N
        amax = jnp.max(jnp.abs(x32), axis=-1)
        tol = tol + amax / 254.0 * ref.row_abs.sum()
    err = jnp.abs(rowsum - expected)
    # NaN/Inf rows never satisfy err <= tol, so the probe subsumes the screen
    row_ok = err <= tol
    ok = jnp.all(row_ok) & finite & storage_ok
    return {
        "mode": "probe",
        "ok": ok,
        "finite": finite,
        "checksum_ok": jnp.all(row_ok) & storage_ok,
        "rows_flagged": jnp.sum(~row_ok).astype(jnp.int32),
        "max_excess": jnp.max(err - tol).astype(jnp.float32),
    }


def raise_on_fault(report: Dict[str, Any], context: str = "matmul") -> None:
    """Host-side convenience: raise :class:`ReliabilityError` on a failed
    audit (call outside jit, after the report's scalars are concrete)."""
    if bool(report["ok"]):
        return
    raise ReliabilityError(
        f"ABFT verification failed in {context}: mode={report['mode']} "
        f"finite={bool(report['finite'])} "
        f"rows_flagged={int(report['rows_flagged'])} "
        f"max_excess={float(report['max_excess']):.3e}"
    )
