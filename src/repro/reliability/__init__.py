"""Reliability layer: ABFT-verified matmuls, fault injection, fail-safe loops.

At the paper's density (a 64x64 array at 8.192 TOPS with zero FIFO slack)
and at fleet scale, silent data corruption is a *when*, not an *if*.  This
package is the system wrapped around the accelerator that notices:

* :mod:`repro.reliability.abft` — Huang–Abraham-style checksums for every
  weight type (``DipWeight`` / ``QuantizedDipWeight`` / natural arrays),
  the dtype-aware tolerance model, and the post-hoc verifier behind
  ``api.matmul(..., verify=...)``.
* :mod:`repro.reliability.inject` — deterministic fault injection (seeded
  bit flips, planted NaNs, host fail-points) so chaos tests *prove*
  detection and recovery instead of asserting their absence.
* :mod:`repro.reliability.guard` — the fail-safe training step wrapper:
  nonfinite loss/grad screening plus a parameter-fingerprint check, with
  skip-and-count semantics consumed by ``repro.runtime.Trainer``.

See ``docs/reliability.md`` for the math, the fault model, and the
degradation ladder.
"""

from repro.reliability.abft import (
    ATOL,
    RTOL,
    AbftChecksum,
    ReliabilityError,
    attach_checksums,
    raise_on_fault,
    verify_matmul,
    weight_checksum,
)
from repro.reliability.guard import (
    GUARD_KEYS,
    fingerprint,
    fingerprint_paths,
    guarded_step_fn,
    init_guard_state,
    locate_fingerprint_fault,
)
from repro.reliability.inject import (
    InjectedFault,
    bitflip,
    corrupt_kv_block,
    corrupt_pytree,
    failpoint,
    maybe_fail,
    plant_nan,
)

__all__ = [
    "ATOL",
    "RTOL",
    "AbftChecksum",
    "ReliabilityError",
    "attach_checksums",
    "raise_on_fault",
    "verify_matmul",
    "weight_checksum",
    "GUARD_KEYS",
    "fingerprint",
    "fingerprint_paths",
    "guarded_step_fn",
    "init_guard_state",
    "locate_fingerprint_fault",
    "InjectedFault",
    "bitflip",
    "corrupt_kv_block",
    "corrupt_pytree",
    "failpoint",
    "maybe_fail",
    "plant_nan",
]
