"""Fault-tolerant checkpointing: atomic manifests, async writes, elastic
re-mesh restore."""

from repro.checkpoint.manager import CheckpointManager, restore_pytree, save_pytree

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]
