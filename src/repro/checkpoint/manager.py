"""Checkpointing built for failure: atomic, async, mesh-independent.

Layout (one directory per step)::

    <dir>/step_000123.tmp-<nonce>/     while writing
        leaf_00000.npy ...             one file per pytree leaf
        manifest.json                  tree structure + leaf index + meta
    <dir>/step_000123/                 atomically renamed when complete

Guarantees:
  * **Atomicity** — a checkpoint directory either has a complete manifest or
    is a ``.tmp-*`` orphan (ignored + garbage-collected); a crash mid-write
    never corrupts the latest good step.
  * **Async** — ``save(..., blocking=False)`` snapshots device arrays to host
    then writes on a background thread; the train loop continues.  At most
    one in-flight save (back-pressure via join).
  * **Elastic re-mesh restore** — leaves are stored unsharded; ``restore``
    accepts a ``shardings`` pytree and ``jax.device_put``s each leaf to the
    *new* topology, so restoring a 256-chip checkpoint onto 512 chips (or a
    differently-shaped mesh) is the same code path.  (At real multi-pod
    scale the .npy writes would be per-shard + a gather-free format; the
    manifest/atomic-rename/async structure is what this layer demonstrates.)
  * **Retention** — keeps the newest ``keep`` steps, deletes the rest.
"""

from __future__ import annotations

import json
import os
import secrets
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.api import DipWeight, QuantizedDipWeight
from repro.reliability.inject import maybe_fail

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _dip_index(tree) -> Dict[str, Dict]:
    """path -> logical-shape metadata for every ``DipWeight`` /
    ``QuantizedDipWeight`` node.

    Both are pytree nodes, so their storage (and, for quantized weights, the
    per-output-channel scales) serializes through the ordinary leaf paths
    (``.../wq/.data``, ``.../wq/.scale``); this records the metadata
    alongside so manifests are self-describing and restore can verify the
    logical shape — and the quantization scheme — survive (padding and
    scheme are part of the type, not a convention the reader must
    re-derive).  A ``WeightPlan`` attached to the weight serializes as its
    JSON ``describe()`` form (mesh reduced to axis sizes) and is validated
    on restore against the target's live plan/mesh.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, (DipWeight, QuantizedDipWeight))
    )
    out: Dict[str, Dict] = {}
    for path, node in flat:
        if isinstance(node, QuantizedDipWeight):
            entry = {
                "d_in": node.d_in, "d_out": node.d_out,
                "perm_tile": node.perm_tile, "scheme": node.scheme,
            }
        elif isinstance(node, DipWeight):
            entry = {
                "d_in": node.d_in, "d_out": node.d_out, "perm_tile": node.perm_tile,
            }
        else:
            continue
        plan = getattr(node, "plan", None)
        if plan is not None and hasattr(plan, "describe"):
            entry["plan"] = plan.describe()
        out["/".join(str(k) for k in path)] = entry
    return out


_DIP_CORE_KEYS = ("d_in", "d_out", "perm_tile", "scheme")


def _check_dip_entry(path: str, saved: Dict, live: Dict) -> None:
    """Restore-time validation of one DipWeight manifest entry.

    Core metadata (logical dims, perm tile, quantization scheme) must match
    exactly.  Partition plans are validated for *compatibility* with the
    live target, not identity: the saved kind/axes must agree when both
    sides carry a plan, and the saved plan's axes must exist in the live
    mesh (checkpoints are mesh-independent — elastic re-mesh only changes
    axis sizes, never the axes a weight's role shards over)."""
    if any(saved.get(k) != live.get(k) for k in _DIP_CORE_KEYS):
        raise ValueError(
            f"DipWeight metadata mismatch at {path}: checkpoint {saved}, "
            f"restore target {live}"
        )
    sp, lp = saved.get("plan"), live.get("plan")
    if not sp or not lp:
        return  # plan-free on either side: nothing to validate against
    if (sp.get("kind"), sp.get("axis"), sp.get("fsdp")) != (
        lp.get("kind"), lp.get("axis"), lp.get("fsdp")
    ):
        raise ValueError(
            f"ShardingPlan mismatch at {path}: checkpoint plan {sp}, "
            f"restore target plan {lp}"
        )
    live_axes = lp.get("mesh_axes") or {}
    for a in (sp.get("axis"), sp.get("fsdp")):
        if a and a not in live_axes:
            raise ValueError(
                f"ShardingPlan mismatch at {path}: saved plan shards over "
                f"axis {a!r} which the live mesh (axes {sorted(live_axes)}) "
                "does not have"
            )


def _npy_safe(arr: np.ndarray) -> np.ndarray:
    """``np.save`` round-trips only builtin numpy dtypes; ml_dtypes payloads
    (bfloat16 params, fp8 quantized storage) silently degrade to raw void
    records.  Write those as same-width unsigned views — the manifest keeps
    the real dtype and :func:`restore_pytree` re-views on load."""
    if arr.dtype.isbuiltin:
        return arr
    return arr.view(np.dtype(f"u{arr.dtype.itemsize}"))


def _restore_dtype(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) == dtype_name:
        return arr
    import jax.numpy as jnp  # resolves ml_dtypes names (float8_*, bfloat16)

    return arr.view(np.dtype(jnp.dtype(dtype_name)))


def save_pytree(path: str, tree: Any, *, meta: Optional[Dict] = None) -> None:
    """Write one complete checkpoint directory atomically (blocking).

    Every leaf's manifest entry records a ``crc32`` of the exact bytes on
    disk; :func:`restore_pytree` re-hashes on load and names the corrupt
    leaf if storage rotted underneath the manifest.  The
    ``checkpoint.save.*`` fail-points let tests crash this function
    mid-write and prove the rename keeps the restore target atomic."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    tmp = f"{path}.tmp-{secrets.token_hex(4)}"
    os.makedirs(tmp, exist_ok=True)
    index: List[Dict] = []
    for i, (p, arr) in enumerate(zip(paths, host_leaves)):
        if i > 0:
            maybe_fail("checkpoint.save.mid_write")
        fname = f"leaf_{i:05d}.npy"
        safe = _npy_safe(arr)
        np.save(os.path.join(tmp, fname), safe)
        index.append({
            "path": p, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(safe).tobytes()),
        })
    manifest = {"leaves": index, "meta": meta or {}, "dip_weights": _dip_index(tree)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    maybe_fail("checkpoint.save.pre_rename")
    os.replace(tmp, path) if not os.path.exists(path) else shutil.rmtree(tmp)


def restore_pytree(path: str, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings for
    elastic placement on the *current* mesh (optional)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    saved_dip = manifest.get("dip_weights", {})
    live_dip = _dip_index(like)
    for p, info in saved_dip.items():
        live = live_dip.get(p)
        if live is not None:
            _check_dip_entry(p, info, live)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    if set(paths) != set(by_path):
        missing = set(paths) - set(by_path)
        extra = set(by_path) - set(paths)
        raise ValueError(f"checkpoint/tree mismatch; missing={missing} extra={extra}")
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
    )
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        arr = np.load(os.path.join(path, by_path[p]["file"]))
        want = by_path[p].get("crc32")  # absent in pre-reliability manifests
        if want is not None:
            got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got != want:
                raise ValueError(
                    f"checkpoint integrity failure at leaf {p!r} "
                    f"({by_path[p]['file']}): crc32 {got:#010x} != manifest "
                    f"{want:#010x} — the checkpoint bytes rotted after save"
                )
        arr = _restore_dtype(arr, by_path[p]["dtype"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def checkpoint_meta(path: str) -> Dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["meta"]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._inflight: Optional[threading.Thread] = None
        self._gc_orphans()

    # ----------------------------------------------------------- naming ----
    def _step_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _gc_orphans(self) -> None:
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, *, meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        self.wait()  # back-pressure: one in-flight save max
        meta = dict(meta or {}, step=step)
        # snapshot to host synchronously (device buffers may be donated next step)
        paths, leaves, treedef = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_pytree(self._step_path(step), snapshot, meta=meta)
            self._retain()

        if blocking:
            work()
        else:
            self._inflight = threading.Thread(target=work, daemon=True)
            self._inflight.start()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_path(s), ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def restore(self, like: Any, *, step: Optional[int] = None, shardings: Any = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = self._step_path(step)
        tree = restore_pytree(path, like, shardings=shardings)
        return tree, checkpoint_meta(path)
