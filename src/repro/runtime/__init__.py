"""Runtime: fault-tolerant training loop and batched serving loop."""

from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.server import Server, ServerConfig

__all__ = ["Trainer", "TrainerConfig", "Server", "ServerConfig"]
