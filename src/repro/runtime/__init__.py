"""Runtime: fault-tolerant training loop and serving entry points.

``Server`` wraps the continuous-batching engine (``repro.serving``);
``WaveServer`` is the pre-engine static-batch loop kept as the bench
baseline.
"""

from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.server import Request, Server, ServerConfig, WaveServer

__all__ = ["Trainer", "TrainerConfig", "Server", "ServerConfig", "Request",
           "WaveServer"]
