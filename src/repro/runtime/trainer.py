"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/test_runtime.py):

  * **Auto-resume** — on start, restores the latest complete checkpoint
    (params + optimizer state + data cursor) and continues bit-exactly; a
    SIGKILL mid-run loses at most ``ckpt_every`` steps.
  * **Async checkpointing** — device->host snapshot is synchronous (buffers
    are donated), the file write overlaps the next steps.
  * **Failure injection** — ``fail_at_step`` raises mid-loop to let tests
    prove the restart path (a stand-in for a node loss; at multi-pod scale
    the same checkpoint/restart contract is driven by the cluster manager).
  * **Straggler mitigation** — per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are counted and surfaced in metrics (the
    1000-node action — re-scheduling the slow host — is the launcher's job;
    the signal is produced here).
  * **Elastic re-mesh** — checkpoints are mesh-independent; `Trainer` takes
    whatever mesh/policy it is given and restores into it.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.checkpoint import CheckpointManager
from repro.data import DataState, SyntheticLM
from repro.models import transformer as tf_model
from repro.optim import AdamW

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    log_every: int = 10
    async_ckpt: bool = True
    fail_at_step: Optional[int] = None     # failure injection (tests)
    straggler_factor: float = 3.0
    metrics_path: Optional[str] = None     # JSONL
    # reliability guard (repro.reliability.guard; docs/reliability.md):
    # screen every step for nonfinite loss/grads and parameter-fingerprint
    # mismatches, skip poisoned updates, and surface counters in metrics
    guard: bool = False
    # on a detected weight fault: restore the latest checkpoint and keep
    # training (True) or raise ReliabilityError naming the corrupt leaf
    recover_on_fault: bool = True
    # GPipe microbatch count when the plan carries a stage axis
    # (plan.stages > 1); 0 = auto (2x the stage count keeps the overlapped
    # schedule's bubble fraction at 50% — see distributed/pipeline.py)
    pipeline_microbatches: int = 0


class Trainer:
    def __init__(
        self,
        cfg,                                # ArchConfig
        tcfg: TrainerConfig,
        *,
        optimizer: Optional[AdamW] = None,
        data: Optional[SyntheticLM] = None,
        mesh=None,
        plan=None,                          # repro.distributed.ShardingPlan
        policy=None,                        # deprecated alias for plan
        seq_len: int = 512,
        global_batch: int = 8,
        step_hook: Optional[Callable[[int, Dict[str, Any]], Dict[str, Any]]] = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        plan = plan if plan is not None else policy
        be = api.get_backend(cfg.matmul_backend)  # fail fast on unknown backends
        if be.layout == "sharded" and plan is None:
            raise ValueError(
                f"backend {be.name!r} dispatches on the weights' ShardingPlan "
                "metadata; pass plan= (repro.distributed.make_plan) or train "
                "through the implicit GSPMD path (matmul_backend='xla')"
            )
        if cfg.quant_scheme is not None:
            # quantized storage is a frozen inference artifact: its int8/fp8
            # payload has no usable cotangent, so training would silently
            # freeze every projection — reject up front
            raise ValueError(
                f"cfg.quantization={cfg.quantization!r} is inference-only; "
                "train in float and quantize the checkpoint for serving "
                "(models.transformer.quantize_params)"
            )
        self.opt = optimizer or AdamW(lr=3e-4)
        self.mesh = mesh
        self.plan = plan
        self.policy = plan  # deprecated alias
        self.data = data or SyntheticLM(
            vocab_size=cfg.vocab_size,
            seq_len=seq_len,
            global_batch=global_batch,
            emit_embeddings=cfg.d_model if cfg.frontend != "none" else None,
        )
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        if plan is not None and getattr(plan, "stages", 1) > 1:
            # plan carries a stage axis: run the layer stack through the
            # overlapped GPipe schedule instead of the flat scan
            from repro.distributed import pipeline as pp_lib

            self._step_fn = pp_lib.pipeline_train_step_fn(
                cfg, self.opt, plan,
                n_micro=tcfg.pipeline_microbatches or 2 * plan.stages,
                guard=tcfg.guard,
            )
        else:
            self._step_fn = tf_model.train_step_fn(
                cfg, self.opt, plan=plan, guard=tcfg.guard
            )
        self._jit_step = None
        self.metrics_log: list = []
        # chaos-testing injection point: called as state = step_hook(step_no,
        # state) before each step — how tests corrupt a live DipWeight
        # between steps without reaching into the loop
        self._step_hook = step_hook
        self.recoveries = 0

    # ----------------------------------------------------------- state -----
    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        params = tf_model.init_params(jax.random.PRNGKey(seed), self.cfg)
        if self.plan is not None:
            # stamp per-weight partition decisions, then place accordingly;
            # the plan metadata rides the pytree from here on (jit / scan /
            # checkpoint / optimizer moments)
            params = self.plan.attach_params(params)
            shardings = self.plan.param_shardings(params)
            params = jax.tree_util.tree_map(jax.device_put, params, shardings)
        state = {
            "params": params,
            "opt_state": self.opt.init(params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.tcfg.guard:
            from repro import reliability

            state = reliability.init_guard_state(state)
        return state

    def _compile(self, state):
        donate = (0,)
        if self.mesh is not None:
            self._jit_step = jax.jit(self._step_fn, donate_argnums=donate)
        else:
            self._jit_step = jax.jit(self._step_fn, donate_argnums=donate)

    # ------------------------------------------------------------ loop -----
    def run(self, seed: int = 0) -> Dict[str, Any]:
        state = self.init_state(seed)
        data_state = DataState(step=0)
        restored, meta = self.ckpt.restore(jax.eval_shape(lambda: state))
        if restored is not None:
            state = jax.tree_util.tree_map(jnp.asarray, restored)
            data_state = DataState.from_dict(meta["data"])
            print(f"[trainer] resumed from step {meta['step']}")
        self._compile(state)

        self.data.start(data_state)
        it = iter(self.data)
        ewma = None
        stragglers = 0
        t_loop = time.monotonic()
        try:
            while int(state["step"]) < self.tcfg.steps:
                step_no, host_batch = next(it)
                batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
                if (
                    self.tcfg.fail_at_step is not None
                    and step_no == self.tcfg.fail_at_step
                ):
                    raise RuntimeError(f"injected failure at step {step_no}")
                if self._step_hook is not None:
                    state = self._step_hook(step_no, state)
                t0 = time.monotonic()
                state, metrics = self._jit_step(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                if self.tcfg.guard and metrics.get("weight_fault"):
                    state = self._recover(state)
                dt = time.monotonic() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > self.tcfg.straggler_factor * ewma and step_no > 3:
                    stragglers += 1
                metrics.update(step_time_s=dt, stragglers=stragglers)
                self.metrics_log.append(metrics)
                if self.tcfg.metrics_path:
                    with open(self.tcfg.metrics_path, "a") as f:
                        f.write(json.dumps(metrics) + "\n")
                if int(metrics["step"]) % self.tcfg.log_every == 0:
                    print(
                        f"[trainer] step {int(metrics['step'])} "
                        f"loss {metrics['loss']:.4f} ({dt*1e3:.0f} ms)"
                    )
                if int(metrics["step"]) % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(
                        int(metrics["step"]),
                        state,
                        meta={"data": DataState(step=step_no + 1).to_dict()},
                        blocking=not self.tcfg.async_ckpt,
                    )
        finally:
            self.data.stop()
            self.ckpt.wait()
        total = time.monotonic() - t_loop
        out = {"state": state, "wall_s": total, "metrics": self.metrics_log}
        if self.tcfg.guard:
            # summed host-side from per-step flags: the in-state counters
            # rewind with every checkpoint restore, the record must not
            out.update(
                skipped=sum(int(m.get("skipped", 0)) for m in self.metrics_log),
                weight_faults=sum(
                    int(m.get("weight_fault", 0)) for m in self.metrics_log
                ),
                recoveries=self.recoveries,
            )
        return out

    # ------------------------------------------------------------ faults ----
    def _recover(self, state: Dict[str, Any]) -> Dict[str, Any]:
        """Weight corruption detected mid-run: name the corrupt leaf, then
        restore the latest checkpoint (or raise if there is none / recovery
        is disabled).  The data stream keeps advancing — replaying exact
        batches is the auto-resume path's job; this one's is survival."""
        from repro import reliability

        bad = reliability.locate_fingerprint_fault(
            state["params"], state["fingerprint"]
        )
        leaves = ", ".join(bad) if bad else "<fingerprint mismatch>"
        restored, meta = (
            self.ckpt.restore(jax.eval_shape(lambda: state))
            if self.tcfg.recover_on_fault else (None, None)
        )
        if restored is None:
            raise reliability.ReliabilityError(
                f"weight corruption detected in [{leaves}] and no recovery "
                "path (recover_on_fault=False or no checkpoint yet)"
            )
        self.recoveries += 1
        print(
            f"[trainer] weight fault in [{leaves}]; "
            f"restored checkpoint step {meta['step']}"
        )
        return jax.tree_util.tree_map(jnp.asarray, restored)
