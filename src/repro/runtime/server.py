"""Batched serving loop: prefill + decode with continuous slot reuse.

A fixed pool of ``batch`` decode slots; finished sequences free their slot,
queued requests claim it (their prompt is prefilled into the shared cache at
the slot's row).  This is the standard continuous-batching shape (vLLM-lite)
expressed with static shapes so a single compiled decode step serves the
whole pool.

Sampling: temperature + top-k on the host (logits are tiny at batch x vocab).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.models import transformer as tf_model

__all__ = ["Server", "ServerConfig", "Request"]


@dataclasses.dataclass
class ServerConfig:
    batch_slots: int = 4
    max_seq: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.8
    top_k: int = 50
    eos_id: int = 1


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg, scfg: ServerConfig, params, *, plan=None, policy=None):
        self.cfg = cfg
        self.scfg = scfg
        plan = plan if plan is not None else policy
        be = api.get_backend(cfg.matmul_backend)  # fail fast on unknown backends
        if be.layout == "dip_q" and cfg.quant_scheme != be.scheme:
            raise ValueError(
                f"backend {be.name!r} consumes {be.scheme!r}-quantized weights "
                f"but cfg.quantization={cfg.quantization!r}"
            )
        if be.layout == "sharded" and plan is None:
            raise ValueError(
                f"backend {be.name!r} dispatches on the weights' ShardingPlan "
                "metadata; pass plan= (repro.distributed.make_plan) or serve "
                "through the implicit GSPMD path (matmul_backend='xla')"
            )
        self.plan = plan
        if plan is not None:
            # stamp per-weight partition decisions AND place the storage
            # accordingly — dip_fsdp's premise (1/N of every weight's bytes
            # per device) only holds if the K-shards actually live sharded
            params = plan.attach_params(params)
            shardings = plan.param_shardings(params)
            params = jax.tree_util.tree_map(jax.device_put, params, shardings)
        self.params = params
        self._decode = jax.jit(tf_model.decode_step_fn(cfg, plan=plan))
        self.rng = np.random.default_rng(0)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        """(B, V) -> (B,) ints; temperature + top-k."""
        t = max(self.scfg.temperature, 1e-4)
        logits = logits / t
        if self.scfg.top_k:
            kth = np.partition(logits, -self.scfg.top_k, axis=-1)[:, -self.scfg.top_k][:, None]
            logits = np.where(logits < kth, -np.inf, logits)
        logits = logits - logits.max(-1, keepdims=True)
        p = np.exp(logits)
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(len(row), p=row) for row in p], np.int32)

    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion through the slot pool."""
        scfg = self.scfg
        queue = list(requests)
        slots: List[Optional[Request]] = [None] * scfg.batch_slots
        cache = tf_model.init_cache(self.cfg, scfg.batch_slots, scfg.max_seq)
        cur = np.zeros((scfg.batch_slots, 1), np.int32)
        t0 = time.monotonic()
        steps = 0

        # NOTE: per-slot positions differ; for static-shape simplicity, this
        # reference server admits waves: slots are (re)filled only when all
        # are free.  Throughput-optimal per-slot admission needs per-row
        # cache positions — an extension hook, not needed for the examples.
        results: Dict[int, List[int]] = {}
        while queue or any(s is not None for s in slots):
            if all(s is None for s in slots) and queue:
                wave = [queue.pop(0) for _ in range(min(len(queue), scfg.batch_slots))]
                maxp = max(len(r.prompt) for r in wave)
                toks = np.zeros((scfg.batch_slots, maxp), np.int32)
                for i, r in enumerate(wave):
                    toks[i, maxp - len(r.prompt):] = r.prompt  # left-pad
                    slots[i] = r
                cache = tf_model.init_cache(self.cfg, scfg.batch_slots, scfg.max_seq)
                logits, cache = self._decode(self.params, cache, jnp.asarray(toks))
                nxt = self._sample(np.asarray(logits[:, -1]))
                cur = nxt[:, None]
                for i, r in enumerate(wave):
                    r.out_tokens.append(int(nxt[i]))
            logits, cache = self._decode(self.params, cache, jnp.asarray(cur))
            nxt = self._sample(np.asarray(logits[:, -1]))
            cur = nxt[:, None]
            steps += 1
            for i, r in enumerate(list(slots)):
                if r is None:
                    continue
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                if tok == scfg.eos_id or len(r.out_tokens) >= scfg.max_new_tokens:
                    r.done = True
                    results[r.rid] = r.out_tokens
                    slots[i] = None
        wall = time.monotonic() - t0
        self.last_stats = {
            "decode_steps": steps,
            "wall_s": wall,
            "tok_per_s": steps * scfg.batch_slots / max(wall, 1e-9),
        }
        return results
