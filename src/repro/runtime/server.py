"""Serving entry points.

``Server`` is now a thin compatibility wrapper over the real engine
(``repro.serving.Engine``): continuous in-flight batching over a paged KV
pool, chunked prefill, per-request seeded sampling.  The wrapper keeps the
original surface — ``ServerConfig`` / ``Request`` / ``serve()`` /
``last_stats`` — so existing callers and tests are untouched; new code
should use the engine directly (streaming callbacks, per-request params,
preemption hooks — see docs/serving.md).

``WaveServer`` preserves the pre-engine reference loop (wave admission:
slots refill only when ALL are free) as the baseline
``benchmarks/serving_bench.py`` measures the engine against.  Its sampler is
the vectorized Gumbel-max (``serving.sampling``) — the per-row
``rng.choice`` Python loop it shipped with was O(batch * vocab) Python work
per token.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.models import transformer as tf_model
from repro.serving import sampling
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import SamplingParams

__all__ = ["Server", "ServerConfig", "Request", "WaveServer"]


@dataclasses.dataclass
class ServerConfig:
    batch_slots: int = 4
    max_seq: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.8
    top_k: int = 50
    eos_id: int = 1
    # engine knobs (None -> ArchConfig defaults); ignored by WaveServer
    prefill_chunk: int = 32
    block_size: Optional[int] = None
    kv_quant: Optional[str] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    max_new: Optional[int] = None      # per-request cap (None -> ServerConfig)


class Server:
    """Compatibility wrapper: the legacy batch API served by the engine."""

    def __init__(self, cfg, scfg: ServerConfig, params, *, plan=None, policy=None):
        self.cfg = cfg
        self.scfg = scfg
        plan = plan if plan is not None else policy
        self.plan = plan
        self.engine = Engine(
            cfg, params,
            engine_cfg=EngineConfig(
                slots=scfg.batch_slots,
                max_seq=scfg.max_seq,
                prefill_chunk=scfg.prefill_chunk,
                block_size=scfg.block_size,
                kv_quant=scfg.kv_quant,
                eos_id=scfg.eos_id,
            ),
            plan=plan,
        )
        self.params = self.engine.params

    def _sampling_for(self, req: Request) -> SamplingParams:
        return SamplingParams(
            temperature=self.scfg.temperature,
            top_k=self.scfg.top_k,
            max_new_tokens=req.max_new or self.scfg.max_new_tokens,
            seed=req.rid,
        )

    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion through the engine's slot pool."""
        for r in requests:
            self.engine.add_request(r.prompt, self._sampling_for(r), rid=r.rid)
        results = self.engine.run()
        for r in requests:
            r.out_tokens = list(results.get(r.rid, []))
            r.done = r.rid in results
        self.last_stats = dict(self.engine.last_stats)
        return results


class WaveServer:
    """The pre-engine reference loop: wave admission with left-padded
    prompts and a shared positionless cache — kept as the serving bench's
    static-batch baseline.  Slots are (re)filled only when ALL are free, so
    every wave decodes for its *longest* member while finished slots idle."""

    def __init__(self, cfg, scfg: ServerConfig, params, *, plan=None):
        self.cfg = cfg
        self.scfg = scfg
        be = api.get_backend(cfg.matmul_backend)  # fail fast on unknown backends
        if be.layout == "sharded" and plan is None:
            raise ValueError(
                f"backend {be.name!r} dispatches on the weights' ShardingPlan "
                "metadata; pass plan= (repro.distributed.make_plan)"
            )
        self.plan = plan
        if plan is not None:
            params = plan.attach_params(params)
            shardings = plan.param_shardings(params)
            params = jax.tree_util.tree_map(jax.device_put, params, shardings)
        self.params = params
        self._decode = jax.jit(tf_model.decode_step_fn(cfg, plan=plan))
        self.rng = np.random.default_rng(0)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        """(B, V) -> (B,) ints; vectorized Gumbel-max (temperature + top-k)."""
        b, v = logits.shape
        scfg = self.scfg
        return sampling.sample_tokens(
            logits,
            temperature=np.full(b, scfg.temperature, np.float32),
            top_k=np.full(b, scfg.top_k, np.int64),
            top_p=np.ones(b, np.float32),
            uniforms=self.rng.random((b, v)),
        )

    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Run all requests to completion through the slot pool."""
        scfg = self.scfg
        queue = list(requests)
        slots: List[Optional[Request]] = [None] * scfg.batch_slots
        cache = tf_model.init_cache(self.cfg, scfg.batch_slots, scfg.max_seq)
        cur = np.zeros((scfg.batch_slots, 1), np.int32)
        t0 = time.monotonic()
        steps = 0

        results: Dict[int, List[int]] = {}
        while queue or any(s is not None for s in slots):
            if all(s is None for s in slots) and queue:
                wave = [queue.pop(0) for _ in range(min(len(queue), scfg.batch_slots))]
                maxp = max(len(r.prompt) for r in wave)
                toks = np.zeros((scfg.batch_slots, maxp), np.int32)
                for i, r in enumerate(wave):
                    toks[i, maxp - len(r.prompt):] = r.prompt  # left-pad
                    slots[i] = r
                cache = tf_model.init_cache(self.cfg, scfg.batch_slots, scfg.max_seq)
                logits, cache = self._decode(self.params, cache, jnp.asarray(toks))
                nxt = self._sample(np.asarray(logits[:, -1]))
                cur = nxt[:, None]
                for i, r in enumerate(wave):
                    r.out_tokens.append(int(nxt[i]))
            logits, cache = self._decode(self.params, cache, jnp.asarray(cur))
            nxt = self._sample(np.asarray(logits[:, -1]))
            cur = nxt[:, None]
            steps += 1
            for i, r in enumerate(list(slots)):
                if r is None:
                    continue
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                limit = r.max_new or scfg.max_new_tokens
                if tok == scfg.eos_id or len(r.out_tokens) >= limit:
                    r.done = True
                    results[r.rid] = r.out_tokens
                    slots[i] = None
        wall = time.monotonic() - t0
        self.last_stats = {
            "decode_steps": steps,
            "wall_s": wall,
            "tok_per_s": steps * scfg.batch_slots / max(wall, 1e-9),
        }
        return results
