"""Shared in-kernel helpers for the DiP Pallas kernels.

TPU adaptation note (DESIGN.md §2): the DiP permutation shifts each column of
a 64x64 tile up by its column index.  A per-column variable rotate has no
single TPU vector op, but it decomposes into log2(tile) *static* sublane
rolls combined with column-mask selects — a classic barrel shifter.  Static
rolls and selects are cheap Mosaic ops, so the de-shear costs
O(log2(tile) * tile * bn) vector work per weight block, amortized against
O(bm * tile * bn) MXU work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["VMEM", "SMEM", "CompilerParams", "shard_map", "deshear_block",
           "shear_block", "rotate_left_dynamic"]

# jax renamed these between releases (MemorySpace.VMEM <-> VMEM,
# CompilerParams <-> TPUCompilerParams); resolve whichever spelling exists so
# the kernels compile against any toolchain the container bakes in.
VMEM = getattr(pltpu, "VMEM", None) or pltpu.MemorySpace.VMEM
SMEM = getattr(pltpu, "SMEM", None) or pltpu.MemorySpace.SMEM
CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams

# shard_map graduated from jax.experimental to the top level; every sharded
# module imports THIS alias so the repo tracks the move in one place.
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pre-graduation toolchains
    from jax.experimental.shard_map import shard_map  # noqa: F811


def _barrel_shear(block: jax.Array, tile: int, *, inverse: bool) -> jax.Array:
    """Apply the DiP (un)permutation to every ``tile x tile`` sub-block.

    ``block``: (bk, bn) with bk % tile == 0 and bn % tile == 0.
    Forward (``inverse=False``):  out[j, i] = in[(j + i%tile) % tile, i]
    Inverse (``inverse=True``):   out[j, i] = in[(j - i%tile) % tile, i]

    Implemented as log2(tile) static rolls + masked selects per 64-row group.
    """
    bk, bn = block.shape
    if bk % tile or bn % tile:
        raise ValueError(f"block {block.shape} not a multiple of permutation tile {tile}")
    col = jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1) % tile
    groups = []
    for g in range(bk // tile):
        w = block[g * tile:(g + 1) * tile, :]
        bit = 1
        while bit < tile:
            # inverse: roll column i DOWN by i  -> positive (down) shifts
            # forward: roll column i UP by i    -> negative (up) shifts
            shift = bit if inverse else tile - bit
            rolled = pltpu.roll(w, shift, axis=0)
            w = jnp.where((col & bit) != 0, rolled, w)
            bit *= 2
        groups.append(w)
    return groups[0] if len(groups) == 1 else jnp.concatenate(groups, axis=0)


def deshear_block(p_block: jax.Array, tile: int = 64) -> jax.Array:
    """Undo the per-tile DiP permutation inside a kernel (VMEM resident)."""
    return _barrel_shear(p_block, tile, inverse=True)


def shear_block(w_block: jax.Array, tile: int = 64) -> jax.Array:
    """Apply the per-tile DiP permutation inside a kernel."""
    return _barrel_shear(w_block, tile, inverse=False)


def rotate_left_dynamic(x: jax.Array, r: jax.Array, width: int) -> jax.Array:
    """Rotate the trailing axis left by a *traced* amount ``r`` (mod width).

    ``out[..., i] = x[..., (i + r) % width]`` — the diagonal input movement of
    the DiP array after r hops.  Uses pltpu.roll with a dynamic shift
    (tpu.DynamicRotate); the left-rotate is expressed as a down-roll by
    ``width - r``.
    """
    shift = (width - r) % width
    return pltpu.roll(x, shift, axis=x.ndim - 1)
