"""Explicit multi-chip DiP matmul backends: `shard_map` over the tiled kernels.

The paper's scaling section (Sec. V) tiles the array from 4x4 to 64x64 with
per-array efficiency intact but stops at one array; the system question for
many chips is *who owns the partitioning and when the collectives fire*.
The implicit answer so far was GSPMD: a plain dot over sharded operands and
XLA chooses.  This module is the explicit answer — two backends that consume
the :class:`~repro.distributed.plan.WeightPlan` carried on a weight's
metadata and place every collective by hand, as ``shard_map`` wrappers over
the *existing* tiled kernels (nothing here re-implements a matmul):

``dip_tp`` (tensor parallel, Megatron-style):
    column  (weight plan kind "column", e.g. wq/wk/wv/w_gate/w_up)
            storage N sharded over the TP axis; x replicated; every shard
            runs ONE fused kernel launch (epilogue included — bias /
            activation / residual operands shard with N).  **Zero
            collectives**: the output stays N-sharded for the next
            row-parallel projection.
    row     (kind "row", e.g. wo/w_down) storage K sharded; x arrives
            K-sharded (exactly what a column-parallel predecessor + local
            elementwise produces); each shard runs ONE kernel launch with
            ``epilogue="none"``, the partial accumulators are combined with
            **exactly one psum** (a single equation even for the dual-weight
            swiglu pair), and the epilogue is applied to the *reduced* f32
            value — the bias/residual is added once, not once per shard, and
            XLA fuses the epilogue arithmetic into the psum's consumer.

``dip_sp`` (sequence parallel, Megatron-SP-style):
    column  x arrives **sequence(M)-sharded** (what the norm/dropout region
            of an SP transformer produces) and the gather of the other
            shards' rows happens *inside* the dispatch as a T-step ring:
            each step first forwards the currently-held x block to the next
            device (``ppermute`` — the transfer the NEXT launch overlaps
            with) and then runs ONE fused launch multiplying that block by
            the local N shard.  **Zero all_gathers, zero psums** — T
            launches, T-1 ppermutes, output N-sharded with full M (the SP
            gather point).  On TPU hardware the ppermute lowers to the ICI
            async remote copy of the ring all-gather pattern; the schedule
            here is that pattern expressed at the shard_map level.
    row     like ``dip_tp`` row, but the combine is ``psum_scatter`` (ONE
            reduce_scatter per weight) so the output returns sequence(M)-
            sharded — the SP scatter point.  The epilogue runs post-
            reduction on the local rows only.

``dip_fsdp`` (ZeRO-3, all-gather-on-load):
    storage K sharded over the FSDP ("data") axis — each device holds
    1/N of every weight's bytes (quantized storage gathers at int8/fp8
    width) — and x is batch(M)-sharded over the same axis.  The body
    all-gathers the weight (**exactly one all_gather per weight**, the
    "on-load" gather ZeRO-3 pays), then runs ONE fused kernel launch over
    the local M rows.  Zero psums.

Block sizes for the per-shard kernel launches come from the ordinary tuning
table, keyed on the *local shard shapes* (N/tp or K/tp, M/fsdp) — the shard
is the shape the hardware actually sees, so measured entries transfer.

Dispatch contract (see ``repro.api.registry``): ``api.matmul`` routes here
when ``backend`` is ``dip_tp``/``dip_sp``/``dip_fsdp`` AND the weight
carries a plan
with a mesh; with no plan attached it decomposes to the implicit GSPMD
path.  See ``docs/distributed.md`` for the collective-placement diagrams.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import epilogue as epilogue_lib
from repro.kernels import prologue as prologue_lib
from repro.kernels.common import shard_map

__all__ = ["dip_tp_matmul", "dip_fsdp_matmul", "dip_sp_matmul",
           "count_collectives", "collective_schedule"]

_COLLECTIVES = ("psum", "all_gather", "all_to_all", "ppermute",
                "reduce_scatter")


# --------------------------------------------------------------------------
# structural evidence: collective/launch counts straight from the jaxpr
def count_collectives(fn, *args) -> Dict[str, int]:
    """Count collective and pallas_call equations a traced call would issue
    (recursing through shard_map/pjit/custom_vjp/scan sub-jaxprs).  The
    conformance tests assert the placement contract with this: one psum for
    row-parallel, zero collectives for column-parallel, one all_gather per
    weight for fsdp."""
    closed = jax.make_jaxpr(fn)(*args)
    counts = {name: 0 for name in _COLLECTIVES + ("pallas_call",)}

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in counts:
                counts[eqn.primitive.name] += 1
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub)

    walk(closed.jaxpr)
    return counts


def collective_schedule(fn, *args) -> List[str]:
    """The collective/launch equations a traced call would issue, in program
    order (depth-first through sub-jaxprs — trace order, which is the order
    the runtime dispatches them).  The overlap tests assert *placement* with
    this where counts alone cannot: ``dip_sp`` must interleave each ring
    ppermute BEFORE the launch it overlaps with, and ``dip_ep`` must issue
    the dispatch all-to-all before the shared-expert launches it hides
    behind."""
    closed = jax.make_jaxpr(fn)(*args)
    watched = set(_COLLECTIVES + ("pallas_call",))
    order: List[str] = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in watched:
                order.append(eqn.primitive.name)
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub)

    walk(closed.jaxpr)
    return order


# --------------------------------------------------------------------------
# shared plumbing
def _pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_cols_to(a: jax.Array, width: int) -> jax.Array:
    pad = width - a.shape[-1]
    if pad == 0:
        return a
    return jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])


def _local_weight(w, data, scale, d_in: int, d_out: int):
    """Rebuild a (plan-free) weight object around local shard payloads —
    plan-free so the inner ``api.matmul`` dispatch cannot recurse back here."""
    from repro import api

    if isinstance(w, api.QuantizedDipWeight):
        return api.QuantizedDipWeight(data, scale, d_in, d_out, w.perm_tile,
                                      w.scheme)
    return api.DipWeight(data, d_in, d_out, w.perm_tile)


def _inner_backend(w) -> Optional[str]:
    """Backend for the per-shard launch: the paper fast path for float DiP
    storage, the scheme's quantized kernel (backend=None dispatch) for
    quantized storage."""
    from repro import api

    return None if isinstance(w, api.QuantizedDipWeight) else "pallas_dip"


def _payloads(weights) -> Tuple[Tuple[jax.Array, ...], Tuple[jax.Array, ...]]:
    """(storages, scales) — scales empty for float weights."""
    from repro import api

    datas = tuple(w.data for w in weights)
    if isinstance(weights[0], api.QuantizedDipWeight):
        return datas, tuple(w.scale for w in weights)
    return datas, ()


def _validate(weights, plan, backend: str) -> None:
    if any(type(w) is not type(weights[0]) for w in weights):
        raise ValueError(f"{backend}: weight pair must share a type")
    if any(getattr(w, "plan", None) != plan for w in weights):
        raise ValueError(
            f"{backend}: weight pair must share one WeightPlan, got "
            f"{[getattr(w, 'plan', None) for w in weights]}"
        )
    if plan is None or plan.mesh is None:
        raise ValueError(
            f"{backend} needs a WeightPlan with a mesh on the weight "
            "(ShardingPlan.attach_params); plan-free weights decompose to "
            "GSPMD through api.matmul"
        )


def _epilogue_out_dtype(x: jax.Array) -> jnp.dtype:
    # same rule as the fused kernels: epilogue arithmetic is f32, so the
    # output is float even when the matmul accumulates in int32
    return x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32


def _resolve_prologue(prologue, pro_operands, prologue_eps, x, w0,
                      full_k_local: bool):
    """Decide where the prologue runs.  Full-K shards with perm-tile-aligned
    d_in fuse it into the per-shard kernel launch (inner ``api.matmul`` —
    the normalized block never round-trips HBM).  Row plans split K across
    shards (no shard sees a whole row to normalize) and unaligned d_in
    would lose the logical sum-of-squares divisor inside the shard, so
    those normalize ONCE here before the shard_map — same arithmetic,
    unfused.  Returns (normalized-or-original x, fuse flag)."""
    if prologue == "none":
        return x, False
    if full_k_local and w0.d_in == w0.data.shape[-2]:
        return x, True
    xn = prologue_lib.apply(
        prologue, x, *(g.reshape(-1) for g in pro_operands), eps=prologue_eps
    )
    return xn, False


# --------------------------------------------------------------------------
def dip_tp_matmul(
    x: jax.Array,
    weights: Sequence,
    operands: Sequence[jax.Array],
    *,
    plan,
    epilogue: str = "none",
    prologue: str = "none",
    prologue_operands: Sequence[jax.Array] = (),
    prologue_eps: float = prologue_lib.DEFAULT_EPS,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Tensor-parallel dispatch of ``epilogue(prologue(x) @ w ...)`` per the
    weight's plan kind (column / row) — see the module doc for collective
    placement.  Column plans keep the full contraction on every shard, so
    the prologue fuses into the per-shard launch; row plans split K, so the
    prologue runs once before the shard_map (``_resolve_prologue``)."""
    from repro import api

    _validate(weights, plan, "dip_tp")
    if plan.kind not in ("column", "row"):
        raise ValueError(
            f"dip_tp consumes column/row WeightPlans, got kind={plan.kind!r} "
            "(replicated weights decompose to GSPMD through api.matmul)"
        )
    mesh, ax = plan.mesh, plan.axis
    tp = mesh.shape[ax]
    w0 = weights[0]
    if w0.data.ndim != 2:
        raise ValueError(
            f"sharded matmul weight must be 2-D (got storage "
            f"{w0.data.shape}); index the stacked axis first"
        )
    kp, np_ = w0.data.shape
    if x.shape[-1] != w0.d_in:
        raise ValueError(
            f"x contraction {x.shape[-1]} does not match {type(w0).__name__} "
            f"d_in={w0.d_in} (storage {w0.data.shape})"
        )
    spec = epilogue_lib.spec(epilogue)
    blocks = dict(block_m=block_m, block_n=block_n, block_k=block_k,
                  interpret=interpret)
    x, fuse_pro = _resolve_prologue(
        prologue, prologue_operands, prologue_eps, x, w0,
        full_k_local=plan.kind == "column",
    )
    pops = (
        tuple(g.reshape(1, -1) for g in prologue_operands) if fuse_pro else ()
    )

    lead = x.shape[:-1]
    x2 = _pad_dim(x.reshape((-1, x.shape[-1])), 1, w0.perm_tile)
    m2 = x2.shape[0]
    datas, scales = _payloads(weights)

    if plan.kind == "column":
        if np_ % tp or (np_ // tp) % w0.perm_tile:
            raise ValueError(
                f"dip_tp column: storage N={np_} must split into "
                f"perm-tile-aligned shards over {ax!r}={tp}"
            )
        n_loc = np_ // tp
        # epilogue operands shard with N: bias rides as a (1, Np) row,
        # residual as the (m2, Np) output-aligned block
        if spec.bias:
            eops = (_pad_cols_to(operands[0].reshape(1, w0.d_out), np_),)
            eop_specs = (P(None, ax),)
        elif spec.residual:
            r2 = operands[0].reshape(-1, w0.d_out)
            eops = (_pad_cols_to(r2, np_),)
            eop_specs = (P(None, ax),)
        else:
            eops = ()
            eop_specs = ()

        def body(xl, datas_l, scales_l, pops_l, eops_l):
            # local d_in = Kp (x arrives already padded): the shard storage
            # keeps the full contraction, only N is split
            wl = tuple(
                _local_weight(w, d, s, kp, n_loc)
                for w, d, s in zip(
                    weights, datas_l, scales_l or (None,) * len(datas_l)
                )
            )
            wl = wl[0] if not spec.dual_weight else wl
            # ONE fused launch per shard: disjoint output columns, so the
            # prologue (gain replicated, full K local) and epilogue
            # (bias/activation/residual shards included) fuse fully
            return api.matmul(
                xl, wl, backend=_inner_backend(w0),
                epilogue=epilogue if epilogue != "none" else None,
                epilogue_operands=eops_l,
                prologue=prologue if fuse_pro else None,
                prologue_operands=pops_l, prologue_eps=prologue_eps,
                **blocks,
            )

        out2 = shard_map(
            body, mesh=mesh,
            in_specs=(
                P(None, None),
                tuple(P(None, ax) for _ in datas),
                tuple(P(None, ax) for _ in scales),
                tuple(P(None, None) for _ in pops),
                tuple(eop_specs),
            ),
            out_specs=P(None, ax),
            check_rep=False,
        )(x2, datas, scales, pops, eops)
        return out2[:m2, : w0.d_out].reshape(lead + (w0.d_out,))

    # ---- row-parallel: K sharded, ONE psum, epilogue post-reduction -------
    if kp % tp or (kp // tp) % w0.perm_tile:
        raise ValueError(
            f"dip_tp row: storage K={kp} must split into perm-tile-aligned "
            f"shards over {ax!r}={tp}"
        )
    k_loc = kp // tp
    if spec.bias:
        eops = (_pad_cols_to(operands[0].reshape(1, w0.d_out), np_),)
    elif spec.residual:
        eops = (_pad_cols_to(operands[0].reshape(-1, w0.d_out), np_),)
    else:
        eops = ()

    def body(xl, datas_l, scales_l, eops_l):
        wl = tuple(
            _local_weight(w, d, s, k_loc, np_)
            for w, d, s in zip(
                weights, datas_l, scales_l or (None,) * len(datas_l)
            )
        )
        # Low-precision activations widen to f32 for the PARTIAL launches:
        # bf16 values embed exactly in f32, so the products are unchanged
        # while the per-shard output skips the bf16 round-trip a kernel
        # flush would apply BEFORE the reduction — the psummed value then
        # matches the single-device kernel's f32 accumulator, with the one
        # cast to the activation dtype applied after the reduction.
        floating = jnp.issubdtype(xl.dtype, jnp.floating)
        xl_in = (
            xl.astype(jnp.float32)
            if floating and xl.dtype != jnp.float32 else xl
        )
        # one launch per weight, epilogue deferred past the reduction
        partials = tuple(
            api.matmul(xl_in, w, backend=_inner_backend(w0), **blocks)
            for w in wl
        )
        if epilogue == "none" and not jnp.issubdtype(
            partials[0].dtype, jnp.floating
        ):
            return jax.lax.psum(partials[0], ax)  # exact int32 reduction
        # ONE psum equation, even for the dual-weight swiglu pair
        zs = jax.lax.psum(
            tuple(p.astype(jnp.float32) for p in partials), ax
        )
        if epilogue == "none":
            return zs[0].astype(xl.dtype if floating else partials[0].dtype)
        aux = (zs[1],) if spec.dual_weight else tuple(
            e.astype(jnp.float32) for e in eops_l
        )
        out = epilogue_lib.apply(epilogue, zs[0], *aux)
        return out.astype(_epilogue_out_dtype(xl))

    out2 = shard_map(
        body, mesh=mesh,
        in_specs=(
            P(None, ax),
            tuple(P(ax, None) for _ in datas),
            # per-output-channel scales span full N; every K shard needs them
            tuple(P(None, None) for _ in scales),
            tuple(P(None, None) for _ in eops),
        ),
        out_specs=P(None, None),
        check_rep=False,
    )(x2, datas, scales, eops)
    return out2[:m2, : w0.d_out].reshape(lead + (w0.d_out,))


def dip_fsdp_matmul(
    x: jax.Array,
    weights: Sequence,
    operands: Sequence[jax.Array],
    *,
    plan,
    epilogue: str = "none",
    prologue: str = "none",
    prologue_operands: Sequence[jax.Array] = (),
    prologue_eps: float = prologue_lib.DEFAULT_EPS,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """ZeRO-3 dispatch: K-sharded storage, all-gather-on-load, batch-sharded
    compute — see the module doc for collective placement.  Each shard owns
    whole x rows (M split, K whole), so the prologue fuses into the local
    launch with the gain replicated (``_resolve_prologue``)."""
    from repro import api

    _validate(weights, plan, "dip_fsdp")
    if plan.fsdp is None:
        raise ValueError(
            "dip_fsdp needs a WeightPlan with an fsdp axis "
            "(ShardingPlan.attach_params on a mesh with a 'data' axis)"
        )
    mesh, ax = plan.mesh, plan.fsdp
    n_sh = mesh.shape[ax]
    w0 = weights[0]
    if w0.data.ndim != 2:
        raise ValueError(
            f"sharded matmul weight must be 2-D (got storage "
            f"{w0.data.shape}); index the stacked axis first"
        )
    kp, np_ = w0.data.shape
    if x.shape[-1] != w0.d_in:
        raise ValueError(
            f"x contraction {x.shape[-1]} does not match {type(w0).__name__} "
            f"d_in={w0.d_in} (storage {w0.data.shape})"
        )
    if kp % n_sh:
        raise ValueError(
            f"dip_fsdp: storage K={kp} must divide the fsdp axis {ax!r}={n_sh}"
        )
    spec = epilogue_lib.spec(epilogue)
    blocks = dict(block_m=block_m, block_n=block_n, block_k=block_k,
                  interpret=interpret)
    x, fuse_pro = _resolve_prologue(
        prologue, prologue_operands, prologue_eps, x, w0, full_k_local=True
    )
    pops = (
        tuple(g.reshape(1, -1) for g in prologue_operands) if fuse_pro else ()
    )

    lead = x.shape[:-1]
    x2 = _pad_dim(x.reshape((-1, x.shape[-1])), 1, w0.perm_tile)
    m2 = x2.shape[0]
    x2p = _pad_dim(x2, 0, n_sh)  # M rows split over the fsdp axis
    datas, scales = _payloads(weights)

    if spec.bias:
        eops = (operands[0].reshape(1, w0.d_out),)
        eop_specs = (P(None, None),)
    elif spec.residual:
        # rides with x's M rows (padding rows compute a discarded epilogue)
        eops = (_pad_dim(operands[0].reshape(-1, w0.d_out), 0, n_sh),)
        eop_specs = (P(ax, None),)
    else:
        eops = ()
        eop_specs = ()

    def body(xl, datas_l, scales_l, pops_l, eops_l):
        # the ZeRO-3 "on-load" gather: one all_gather per weight, at the
        # storage width (int8/fp8 bytes for quantized weights)
        full = tuple(
            jax.lax.all_gather(d, ax, axis=0, tiled=True) for d in datas_l
        )
        # local d_in = Kp: x arrives padded, the gathered storage is whole
        wl = tuple(
            _local_weight(w, d, s, kp, w0.d_out)
            for w, d, s in zip(
                weights, full, scales_l or (None,) * len(full)
            )
        )
        wl = wl[0] if not spec.dual_weight else wl
        # ONE fused launch over the local M rows, prologue and epilogue
        # included (x rows are whole per shard, so the per-row norm is local)
        return api.matmul(
            xl, wl, backend=_inner_backend(w0),
            epilogue=epilogue if epilogue != "none" else None,
            epilogue_operands=eops_l,
            prologue=prologue if fuse_pro else None,
            prologue_operands=pops_l, prologue_eps=prologue_eps,
            **blocks,
        )

    out2 = shard_map(
        body, mesh=mesh,
        in_specs=(
            P(ax, None),
            tuple(P(ax, None) for _ in datas),
            tuple(P(None, None) for _ in scales),
            tuple(P(None, None) for _ in pops),
            tuple(eop_specs),
        ),
        out_specs=P(ax, None),
        check_rep=False,
    )(x2p, datas, scales, pops, eops)
    return out2[:m2, : w0.d_out].reshape(lead + (w0.d_out,))


def dip_sp_matmul(
    x: jax.Array,
    weights: Sequence,
    operands: Sequence[jax.Array],
    *,
    plan,
    epilogue: str = "none",
    prologue: str = "none",
    prologue_operands: Sequence[jax.Array] = (),
    prologue_eps: float = prologue_lib.DEFAULT_EPS,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Sequence-parallel dispatch: the column path streams the M-sharded x
    around a ring *inside* the dispatch (ppermute issued before each launch
    so the transfer overlaps the multiply), the row path combines with
    psum_scatter so the output returns sequence-sharded — see the module doc
    for collective placement."""
    from repro import api

    _validate(weights, plan, "dip_sp")
    if plan.kind not in ("column", "row"):
        raise ValueError(
            f"dip_sp consumes column/row WeightPlans, got kind={plan.kind!r} "
            "(replicated weights decompose to GSPMD through api.matmul)"
        )
    mesh, ax = plan.mesh, plan.axis
    tp = mesh.shape[ax]
    w0 = weights[0]
    if w0.data.ndim != 2:
        raise ValueError(
            f"sharded matmul weight must be 2-D (got storage "
            f"{w0.data.shape}); index the stacked axis first"
        )
    kp, np_ = w0.data.shape
    if x.shape[-1] != w0.d_in:
        raise ValueError(
            f"x contraction {x.shape[-1]} does not match {type(w0).__name__} "
            f"d_in={w0.d_in} (storage {w0.data.shape})"
        )
    spec = epilogue_lib.spec(epilogue)
    blocks = dict(block_m=block_m, block_n=block_n, block_k=block_k,
                  interpret=interpret)
    x, fuse_pro = _resolve_prologue(
        prologue, prologue_operands, prologue_eps, x, w0,
        full_k_local=plan.kind == "column",
    )
    pops = (
        tuple(g.reshape(1, -1) for g in prologue_operands) if fuse_pro else ()
    )

    lead = x.shape[:-1]
    x2 = _pad_dim(x.reshape((-1, x.shape[-1])), 1, w0.perm_tile)
    m2 = x2.shape[0]
    datas, scales = _payloads(weights)
    perm = [(j, (j + 1) % tp) for j in range(tp)]

    if plan.kind == "column":
        if np_ % tp or (np_ // tp) % w0.perm_tile:
            raise ValueError(
                f"dip_sp column: storage N={np_} must split into "
                f"perm-tile-aligned shards over {ax!r}={tp}"
            )
        x2p = _pad_dim(x2, 0, tp)  # sequence(M) rows split over the TP axis
        m_pad = x2p.shape[0]
        m_loc = m_pad // tp
        n_loc = np_ // tp
        if spec.bias:
            eops = (_pad_cols_to(operands[0].reshape(1, w0.d_out), np_),)
            eop_specs = (P(None, ax),)
        elif spec.residual:
            r2 = _pad_dim(
                _pad_cols_to(operands[0].reshape(-1, w0.d_out), np_), 0, tp
            )
            eops = (r2,)
            eop_specs = (P(None, ax),)
        else:
            eops = ()
            eop_specs = ()

        def body(xl, datas_l, scales_l, pops_l, eops_l):
            wl = tuple(
                _local_weight(w, d, s, kp, n_loc)
                for w, d, s in zip(
                    weights, datas_l, scales_l or (None,) * len(datas_l)
                )
            )
            wl = wl[0] if not spec.dual_weight else wl
            me = jax.lax.axis_index(ax)
            out = None  # allocated from the first launch's dtype
            # the ring: at step s this device holds the x block that
            # originated on device (me - s) mod tp.  The FORWARD of that
            # block to the next device is issued FIRST — data-independent of
            # the multiply, so it overlaps the launch that follows it (on
            # TPU, the ICI remote copy of the ring all-gather pattern).
            cur = xl
            for s in range(tp):
                nxt = jax.lax.ppermute(cur, ax, perm) if s < tp - 1 else None
                src = jax.lax.rem(me - s + tp, tp)
                if spec.residual:
                    step_eops = tuple(
                        jax.lax.dynamic_slice_in_dim(e, src * m_loc, m_loc, 0)
                        for e in eops_l
                    )
                else:
                    step_eops = eops_l
                # ONE fused launch per ring step: this block's complete
                # output rows for the local N columns, prologue (full K
                # local, gain replicated) and epilogue included
                y = api.matmul(
                    cur, wl, backend=_inner_backend(w0),
                    epilogue=epilogue if epilogue != "none" else None,
                    epilogue_operands=step_eops,
                    prologue=prologue if fuse_pro else None,
                    prologue_operands=pops_l, prologue_eps=prologue_eps,
                    **blocks,
                )
                if out is None:
                    out = jnp.zeros((m_pad, n_loc), y.dtype)
                out = jax.lax.dynamic_update_slice_in_dim(
                    out, y, src * m_loc, 0
                )
                cur = nxt
            return out

        out2 = shard_map(
            body, mesh=mesh,
            in_specs=(
                P(ax, None),
                tuple(P(None, ax) for _ in datas),
                tuple(P(None, ax) for _ in scales),
                tuple(P(None, None) for _ in pops),
                tuple(eop_specs),
            ),
            out_specs=P(None, ax),
            check_rep=False,
        )(x2p, datas, scales, pops, eops)
        return out2[:m2, : w0.d_out].reshape(lead + (w0.d_out,))

    # ---- row-parallel: K sharded, psum_scatter, output sequence-sharded ----
    if kp % tp or (kp // tp) % w0.perm_tile:
        raise ValueError(
            f"dip_sp row: storage K={kp} must split into perm-tile-aligned "
            f"shards over {ax!r}={tp}"
        )
    k_loc = kp // tp
    x2p = _pad_dim(x2, 0, tp)  # output rows must split over the axis
    m_pad = x2p.shape[0]
    m_loc = m_pad // tp
    if spec.bias:
        eops = (_pad_cols_to(operands[0].reshape(1, w0.d_out), np_),)
        eop_specs = (P(None, None),)
    elif spec.residual:
        # rides with the SCATTERED output rows: sequence-sharded like them
        r2 = _pad_dim(
            _pad_cols_to(operands[0].reshape(-1, w0.d_out), np_), 0, tp
        )
        eops = (r2,)
        eop_specs = (P(ax, None),)
    else:
        eops = ()
        eop_specs = ()

    def body(xl, datas_l, scales_l, eops_l):
        wl = tuple(
            _local_weight(w, d, s, k_loc, np_)
            for w, d, s in zip(
                weights, datas_l, scales_l or (None,) * len(datas_l)
            )
        )
        # same f32-widening rule as dip_tp row: the reduce must see the
        # un-rounded f32 partials (see that body's comment)
        floating = jnp.issubdtype(xl.dtype, jnp.floating)
        xl_in = (
            xl.astype(jnp.float32)
            if floating and xl.dtype != jnp.float32 else xl
        )
        partials = tuple(
            api.matmul(xl_in, w, backend=_inner_backend(w0), **blocks)
            for w in wl
        )
        if epilogue == "none" and not jnp.issubdtype(
            partials[0].dtype, jnp.floating
        ):
            return jax.lax.psum_scatter(
                partials[0], ax, scatter_dimension=0, tiled=True
            )  # exact int32 reduction
        # ONE reduce_scatter per weight: each device keeps only its M rows
        # of the reduced value (the SP scatter point)
        zs = tuple(
            jax.lax.psum_scatter(
                p.astype(jnp.float32), ax, scatter_dimension=0, tiled=True
            )
            for p in partials
        )
        if epilogue == "none":
            return zs[0].astype(xl.dtype if floating else partials[0].dtype)
        aux = (zs[1],) if spec.dual_weight else tuple(
            e.astype(jnp.float32) for e in eops_l
        )
        out = epilogue_lib.apply(epilogue, zs[0], *aux)
        return out.astype(_epilogue_out_dtype(xl))

    out2 = shard_map(
        body, mesh=mesh,
        in_specs=(
            P(None, ax),
            tuple(P(ax, None) for _ in datas),
            tuple(P(None, None) for _ in scales),
            tuple(eop_specs),
        ),
        out_specs=P(ax, None),
        check_rep=False,
    )(x2p, datas, scales, eops)
    return out2[:m2, : w0.d_out].reshape(lead + (w0.d_out,))
