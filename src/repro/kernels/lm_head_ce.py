"""Fused lm_head + cross-entropy: the (B, S, V) logits never reach HBM.

The training loss is the one place the model materializes a vocab-wide
tensor: an unfused ``hidden @ head`` writes (B*S, V) float32 logits to HBM
only for the loss to immediately reduce them to one scalar per token.  At
production vocabularies that single intermediate dwarfs every activation in
the network (V >> d_model), and it is pure synchronization tax in the
paper's sense — a producer/consumer hand-off buffer, the FIFO the DiP
dataflow exists to delete.

This kernel streams the head matmul through an online-logsumexp reduction
instead (same recurrence as flash attention's running softmax): the grid
walks vocab chunks innermost, each chunk's (block_t, block_v) logit tile
lives only in VMEM, and per token just two scalars survive to HBM —

    logz_t  = logsumexp_v(x_t @ W)        (the softmax normalizer)
    lab_t   = (x_t @ W)[labels_t]         (the label's raw logit)

from which the caller assembles ``loss_t = logz - lab + z_loss * logz^2``.

The backward pass never materializes the logits either: ``d z = g_logz *
softmax(z) + g_lab * onehot(labels)`` is recomputed chunk-by-chunk in a
pure-XLA scan over the vocab (``dx += dz_c @ W_c^T``, ``dW_c = x^T @
dz_c``), so peak memory is one (T, block_v) tile plus the weight-sized
gradient that must exist anyway.

Masking contract (shared with ``layers.cross_entropy_loss``): tokens whose
label equals ``ignore_index`` (default -100) and tokens zeroed by ``mask``
contribute neither to the mean nor to gradients; the mean divides by the
valid-token count.  Vocab padding columns (``col >= vocab_size``) are
masked to -inf inside the kernel, mirroring the -1e30 lane mask the
unfused ``transformer.forward`` applies to its logits.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = [
    "DEFAULT_BLOCK_T",
    "DEFAULT_BLOCK_V",
    "IGNORE_INDEX",
    "lm_head_ce_pallas",
    "fused_cross_entropy_loss",
    "reference_lm_head_ce",
]

NEG_INF = -1e30
IGNORE_INDEX = -100
DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_V = 512


def _kernel(x_ref, w_ref, lab_ref, logz_ref, labl_ref, m_ref, l_ref, a_ref,
            *, block_v: int, vocab_size: int):
    """One (block_t, block_v) logit tile: fold into the online logsumexp.

    Grid is (T / block_t, Vp / block_v) with the vocab dim innermost and
    "arbitrary" (sequential), so the m/l/label scratch carries across vocab
    chunks exactly like the matmul kernels' accumulator carries across K.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    col = j * block_v + jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    z = jnp.where(col < vocab_size, z, NEG_INF)

    # online logsumexp: every block holds >= 1 real column (the padding
    # Vp - V is < block_v), so m_new stays finite and the masked lanes'
    # exp(NEG_INF - m_new) underflows to exactly 0 — no exp(0) hazard.
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(z, axis=-1, keepdims=True))
    l_ref[...] = (l_ref[...] * jnp.exp(m_prev - m_new)
                  + jnp.sum(jnp.exp(z - m_new), axis=-1, keepdims=True))
    m_ref[...] = m_new

    # label logit: compare absolute column ids, so ignore_index (< 0) simply
    # never matches and its accumulator stays 0 (masked out by the caller)
    hit = col == lab_ref[...]
    a_ref[...] += jnp.sum(jnp.where(hit, z, 0.0), axis=-1, keepdims=True)

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        logz_ref[...] = m_ref[...] + jnp.log(l_ref[...])
        labl_ref[...] = a_ref[...]


def lm_head_ce_pallas(
    x: jax.Array,          # (T, D) hidden states
    w: jax.Array,          # (D, Vp) natural head weight
    labels: jax.Array,     # (T,) int32 token ids (or ignore_index)
    *,
    vocab_size: Optional[int] = None,
    block_t: int = DEFAULT_BLOCK_T,
    block_v: int = DEFAULT_BLOCK_V,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Forward kernel: returns per-token ``(logz, label_logit)`` float32 (T,).

    Pads T up to ``block_t`` (labels with ``ignore_index``) and Vp up to
    ``block_v`` (zero columns — masked inside the kernel together with any
    vocab padding already present in ``w``), then crops.
    """
    t, d = x.shape
    d2, vp = w.shape
    if d != d2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    if labels.shape != (t,):
        raise ValueError(f"labels {labels.shape} do not match x rows {t}")
    vocab = vp if vocab_size is None else vocab_size
    if vocab > vp:
        raise ValueError(f"vocab_size {vocab} exceeds head width {vp}")

    bt = max(8, min(block_t, -(-t // 8) * 8))
    bv = max(128, min(block_v, -(-vp // 128) * 128))
    tp = -(-t // bt) * bt
    vpp = -(-vp // bv) * bv
    if tp != t:
        x = jnp.pad(x, ((0, tp - t), (0, 0)))
        labels = jnp.pad(labels, (0, tp - t), constant_values=IGNORE_INDEX)
    if vpp != vp:
        w = jnp.pad(w, ((0, 0), (0, vpp - vp)))

    lab2 = labels.astype(jnp.int32).reshape(tp, 1)
    logz, labl = pl.pallas_call(
        functools.partial(_kernel, block_v=bv, vocab_size=vocab),
        grid=(tp // bt, vpp // bv),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, 1), jnp.float32),
            jax.ShapeDtypeStruct((tp, 1), jnp.float32),
        ],
        scratch_shapes=[
            common.VMEM((bt, 1), jnp.float32),
            common.VMEM((bt, 1), jnp.float32),
            common.VMEM((bt, 1), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, lab2)
    return logz[:t, 0], labl[:t, 0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _logz_and_label(x, w, labels, opts):
    vocab, block_t, block_v, interpret = opts
    return lm_head_ce_pallas(
        x, w, labels, vocab_size=vocab,
        block_t=block_t, block_v=block_v, interpret=interpret,
    )


def _logz_and_label_fwd(x, w, labels, opts):
    out = _logz_and_label(x, w, labels, opts)
    return out, (x, w, labels, out[0])


def _logz_and_label_bwd(opts, res, g):
    """Chunked recompute backward — the (T, V) logits never materialize.

    ``dz = g_logz * softmax(z) + g_lab * onehot(labels)`` per vocab chunk;
    dx accumulates across chunks, dW is stacked chunk-wise and reassembled
    (weight-sized, which the optimizer materializes anyway).
    """
    vocab, _, block_v, _ = opts
    x, w, labels, logz = res
    g_logz, g_lab = g
    t, d = x.shape
    vp = w.shape[1]
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    bv = max(128, min(block_v, -(-vp // 128) * 128))
    vpp = -(-vp // bv) * bv
    if vpp != vp:
        w32 = jnp.pad(w32, ((0, 0), (0, vpp - vp)))
    gz = g_logz[:, None]
    gl = g_lab[:, None]
    lab = labels.astype(jnp.int32)[:, None]
    logz_col = logz[:, None]

    def body(dx, c):
        w_c = jax.lax.dynamic_slice_in_dim(w32, c * bv, bv, axis=1)
        z_c = x32 @ w_c
        col = c * bv + jnp.arange(bv, dtype=jnp.int32)[None, :]
        p_c = jnp.where(col < vocab, jnp.exp(z_c - logz_col), 0.0)
        dz_c = gz * p_c + gl * (col == lab).astype(jnp.float32)
        dw_c = x32.T @ dz_c
        return dx + dz_c @ w_c.T, dw_c

    dx, dw_chunks = jax.lax.scan(
        body, jnp.zeros((t, d), jnp.float32),
        jnp.arange(vpp // bv, dtype=jnp.int32),
    )
    dw = jnp.moveaxis(dw_chunks, 0, 1).reshape(d, vpp)[:, :vp]
    dlab = np.zeros(labels.shape, dtype=jax.dtypes.float0)
    return dx.astype(x.dtype), dw.astype(w.dtype), dlab


_logz_and_label.defvjp(_logz_and_label_fwd, _logz_and_label_bwd)


def fused_cross_entropy_loss(
    x: jax.Array,                     # (..., D) hidden states
    w: jax.Array,                     # (D, Vp) natural head weight
    labels: jax.Array,                # (...) int32
    *,
    z_loss: float = 1e-4,
    mask: Optional[jax.Array] = None,
    ignore_index: int = IGNORE_INDEX,
    vocab_size: Optional[int] = None,
    block_t: int = DEFAULT_BLOCK_T,
    block_v: int = DEFAULT_BLOCK_V,
    interpret: bool = False,
) -> jax.Array:
    """Mean token cross entropy straight from hidden states — no logits.

    Same value and masking contract as ``layers.cross_entropy_loss(x @ w,
    labels, ...)`` with padding lanes masked, but the (..., V) logits exist
    only as VMEM tiles (forward) / scan chunks (backward).  Differentiable
    in ``x`` and ``w`` via the chunked-recompute VJP.
    """
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    lab2 = labels.reshape(-1).astype(jnp.int32)
    vocab = int(w.shape[1]) if vocab_size is None else int(vocab_size)
    opts = (vocab, int(block_t), int(block_v), bool(interpret))
    logz, lab_logit = _logz_and_label(x2, w, lab2, opts)

    valid = lab2 != ignore_index
    if mask is not None:
        valid = valid & (mask.reshape(-1) != 0)
    loss_t = logz - lab_logit
    if z_loss:
        loss_t = loss_t + z_loss * jnp.square(logz)
    loss_t = jnp.where(valid, loss_t, 0.0)
    return jnp.sum(loss_t) / jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)


def reference_lm_head_ce(
    x: jax.Array,
    w: jax.Array,
    labels: jax.Array,
    *,
    z_loss: float = 1e-4,
    mask: Optional[jax.Array] = None,
    ignore_index: int = IGNORE_INDEX,
    vocab_size: Optional[int] = None,
) -> jax.Array:
    """Unfused oracle: materializes the logits, same arithmetic contract."""
    vocab = int(w.shape[1]) if vocab_size is None else int(vocab_size)
    logits = jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    logits = jnp.where(lane < vocab, logits, NEG_INF)

    lab = labels.astype(jnp.int32)
    valid = lab != ignore_index
    if mask is not None:
        valid = valid & (mask != 0)
    safe = jnp.where(valid, lab, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = logz - label_logits
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    loss = jnp.where(valid, loss, 0.0)
    return jnp.sum(loss) / jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
