"""Fused flash-attention kernel (forward) — the §Perf pair-3 lever.

The 32k-prefill cells are memory-bound because the unfused online-softmax
streams (b, h, Sq, chunk) score tensors through HBM ~10x per layer
(EXPERIMENTS.md §Perf).  This kernel keeps the running max / denominator /
accumulator in VMEM scratch across the KV-block grid dimension, so scores
never leave VMEM — the canonical flash-attention structure, and the same
lesson as DiP one level down: keep the hot tile resident in the fast tier.

Grid: (batch*heads, Sq/block_q, Sk/block_k), KV innermost ("arbitrary").
Blocks: q (block_q, d), k/v (block_k, d), out (block_q, d);
scratch: m/l (block_q, 1) f32, acc (block_q, d) f32 — all VMEM.

Causal masking via absolute positions (q_offset lets a decode/cache caller
place the query block anywhere in the sequence).  Serving-oriented:
forward-only (prefill/decode have no backward); training attention keeps the
XLA online-softmax path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale              # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    if causal:
        q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "causal", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,    # (BH, Sq, D) — batch*heads flattened
    k: jax.Array,    # (BH, Sk, D)
    v: jax.Array,    # (BH, Sk, D)
    *,
    block_q: int = 512,
    block_k: int = 512,
    causal: bool = True,
    interpret: bool = False,
):
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    if sq % block_q or sk % block_k:
        raise ValueError(f"pad seq dims to blocks: {q.shape} {k.shape}")
    scale = d ** -0.5
    grid = (bh, sq // block_q, sk // block_k)

    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_q=block_q, block_k=block_k, causal=causal
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            common.VMEM((block_q, 1), jnp.float32),
            common.VMEM((block_q, 1), jnp.float32),
            common.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
