"""Fused flash-attention kernel (forward) — the §Perf pair-3 lever.

The 32k-prefill cells are memory-bound because the unfused online-softmax
streams (b, h, Sq, chunk) score tensors through HBM ~10x per layer
(docs/benchmarks.md).  This kernel keeps the running max / denominator /
accumulator in VMEM scratch across the KV-block grid dimension, so scores
never leave VMEM — the canonical flash-attention structure, and the same
lesson as DiP one level down: keep the hot tile resident in the fast tier.

Grid: (batch*heads, Sq/block_q, Sk/block_k), KV innermost ("arbitrary").
Blocks: q (block_q, d), k (block_k, d), v (block_k, dv), out (block_q, dv);
scratch: m/l (block_q, 1) f32, acc (block_q, dv) f32 — all VMEM.

Causal masking via absolute positions: ``q_offset`` (per batch*head row,
*traced* — one compile serves every prefill offset) places the query block
anywhere in the key sequence, which is exactly the serving chunked-prefill
shape: Sq new tokens attending a cache of ``q_offset`` earlier keys.
``kv_len`` bounds the live keys per row (cache capacity / Sk padding).
Both ride as scalar-per-row SMEM inputs.  KV blocks entirely above the
causal diagonal or past ``kv_len`` are skipped (no MXU work, no VMEM
traffic for masked tiles — the block-diagonal savings that make causal
flash ~2x the throughput of the masked-dense form).

Serving-oriented: forward-only (prefill/decode have no backward); training
attention keeps the XLA online-softmax path.  Registered behind
``repro.api.attention`` (backend "flash") with tuning-table block sizes;
use that entry point unless you are benchmarking the raw kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(qo_ref, kvl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, block_q: int, block_k: int, causal: bool):
    q_idx = pl.program_id(1)
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qo = qo_ref[0, 0]
    kvl = kvl_ref[0, 0]
    kv_start = kv_idx * block_k

    # block skipping: a KV block entirely above the causal diagonal (its
    # first key is newer than this q block's newest query) or entirely past
    # the live keys contributes nothing — skip the matmuls outright.  The
    # init/flush stay outside the predicate so scratch and output are
    # always well-defined.
    relevant = kv_start < kvl
    if causal:
        relevant = jnp.logical_and(
            relevant, kv_start <= qo + (q_idx + 1) * block_q - 1
        )

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale              # (bq, d)
        k = k_ref[0].astype(jnp.float32)                      # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        live = k_pos < kvl
        if causal:
            q_pos = qo + q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0
            )
            live = jnp.logical_and(live, q_pos >= k_pos)
        s = jnp.where(live, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # a fully-masked row keeps m_new == NEG_INF, where exp(s - m_new)
        # would be exp(0) = 1 lane-wide — zero those lanes explicitly so the
        # row's denominator stays 0 and the flush emits 0, not garbage
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _per_row_i32(val, bh: int, default: int) -> jax.Array:
    """Broadcast a None / scalar / (BH,) value to the (BH, 1) SMEM layout."""
    if val is None:
        val = default
    arr = jnp.asarray(val, jnp.int32)
    if arr.ndim == 0:
        arr = jnp.broadcast_to(arr, (bh,))
    return arr.reshape(bh, 1)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "causal", "interpret", "scale")
)
def flash_attention_pallas(
    q: jax.Array,    # (BH, Sq, D) — batch*heads flattened
    k: jax.Array,    # (BH, Sk, D)
    v: jax.Array,    # (BH, Sk, Dv)
    *,
    q_offset=None,   # None | int | (BH,) — absolute key position of q row 0
    kv_len=None,     # None | int | (BH,) — live keys per row (defaults to Sk)
    block_q: int = 512,
    block_k: int = 512,
    causal: bool = True,
    scale: float = None,   # None -> D ** -0.5 (pass 1.0 for pre-scaled q)
    interpret: bool = False,
):
    """Pads Sq/Sk up to the block sizes and crops; padded keys are masked
    through ``kv_len``, padded query rows are cropped from the output."""
    bh, sq, d = q.shape
    _, sk, dv = v.shape
    if k.shape != (bh, sk, d):
        raise ValueError(f"k {k.shape} does not match q {q.shape} / v {v.shape}")
    scale = d ** -0.5 if scale is None else scale

    bq = max(8, min(block_q, sq + (-sq) % 8))
    bk = max(128, min(block_k, sk + (-sk) % 128))
    sqp = sq + (-sq) % bq
    skp = sk + (-sk) % bk
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0)))
    if skp != sk:
        k = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0)))

    qo = _per_row_i32(q_offset, bh, 0)
    kvl = _per_row_i32(kv_len, bh, sk)
    grid = (bh, sqp // bq, skp // bk)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_q=bq, block_k=bk, causal=causal
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0),
                         memory_space=common.SMEM),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0),
                         memory_space=common.SMEM),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, dv), q.dtype),
        scratch_shapes=[
            common.VMEM((bq, 1), jnp.float32),
            common.VMEM((bq, 1), jnp.float32),
            common.VMEM((bq, dv), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qo, kvl, q, k, v)
    return out[:, :sq]
