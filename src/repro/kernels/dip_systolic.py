"""DiP wavefront-emulation kernel: the array's dataflow, cycle for cycle.

This kernel executes the *literal* DiP dataflow on the TPU vector unit: one
inner step per systolic wavefront.  PE row ``r`` holds permutated weight row
``p[r, :]``; the input row arrives rotated left by ``r`` (diagonal movement,
paper Fig. 2a); each step performs one rolled vector MAC:

    acc[m, i] += x[m, (i + r) % 64] * p[r, i]        r = 0..63

It is deliberately VPU-bound — it exists to demonstrate and validate the
dataflow end-to-end on real tensors (and to measure the exact vector-op cost
of diagonal movement), not to beat the MXU fast path.  Arithmetic intensity
is the same as a matmul but issued as 64 vector MACs per weight tile, so the
roofline sits at the VPU, exactly like the physical DiP array sits at its PE
throughput.

Grid: (M/bm, N/64, K/64) — one 64-wide array column-block per grid step, one
64-deep weight tile per K step (the array is 64x64; matrix tiling as in
paper Sec. IV-C).

Fused epilogues (kernels/epilogue.py) apply at the accumulator flush exactly
as in the fast-path kernel; ``swiglu`` streams the up-projection's weight
tile through a second wavefront loop over the same x block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common
from repro.kernels import epilogue as epi
from repro.kernels import prologue as pro
from repro.kernels.ref import acc_dtype_for

__all__ = ["dip_systolic_pallas"]


def _kernel(x_ref, p_ref, *rest, array_n: int, epilogue: str, prologue: str):
    spec = epi.spec(epilogue)
    n_pro = 2 * pro.n_operands(prologue)
    pro_refs = rest[:n_pro]
    rest = rest[n_pro:]
    extra = rest[: spec.n_operands]
    o_ref = rest[spec.n_operands]
    acc_refs = rest[spec.n_operands + 1:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        for acc in acc_refs:
            acc[...] = jnp.zeros_like(acc)

    x = pro.kernel_load(prologue, x_ref, pro_refs)

    def sweep(p, acc0):
        def wavefront(r, acc):
            # diagonal input movement: input row rotated left by r at PE row r
            xr = common.rotate_left_dynamic(x, r, array_n)
            p_row = jax.lax.dynamic_slice_in_dim(p, r, 1, axis=0)  # stationary weights of PE row r
            return acc + xr.astype(acc.dtype) * p_row.astype(acc.dtype)

        return jax.lax.fori_loop(0, array_n, wavefront, acc0)

    acc_refs[0][...] = sweep(p_ref[...], acc_refs[0][...])
    if spec.dual_weight:  # up projection: second wavefront sweep, same x
        acc_refs[1][...] = sweep(extra[0][...], acc_refs[1][...])

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        epi.kernel_flush(epilogue, o_ref, acc_refs, extra)


@functools.partial(
    jax.jit, static_argnames=("block_m", "array_n", "interpret", "out_dtype",
                              "epilogue", "prologue", "prologue_k",
                              "prologue_eps")
)
def dip_systolic_pallas(
    x: jax.Array,
    p: jax.Array,
    *epilogue_operands: jax.Array,
    block_m: int = 128,
    array_n: int = 64,
    interpret: bool = False,
    out_dtype=None,
    epilogue: str = "none",
    prologue: str = "none",
    prologue_operands=(),
    prologue_k=None,
    prologue_eps: float = pro.DEFAULT_EPS,
):
    """``epilogue(prologue(x) @ unpermute_tiled(p))`` via explicit wavefront
    emulation.

    ``p`` is the (K, N) DiP-permutated weight with K, N multiples of
    ``array_n`` (the physical array dimension, 64 in the paper).
    ``epilogue_operands`` follow the kernels/epilogue.py contract: a second
    (K, N) weight for ``swiglu``, a (1, N) bias row, or an (M, N) residual;
    ``prologue_operands`` is the (1, K) norm gain for ``rmsnorm``.
    """
    m, kdim = x.shape
    k2, n = p.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {p.shape}")
    if m % block_m or kdim % array_n or n % array_n:
        raise ValueError(f"unpadded shapes {x.shape} @ {p.shape}")
    spec = epi.spec(epilogue)
    epi.validate_operands(
        epilogue, epilogue_operands, m=m, n=n, w_shape=p.shape, w_dtype=p.dtype
    )
    pro_in = []
    if pro.spec(prologue).normalize:
        (gain,) = prologue_operands
        gain = gain.reshape(1, kdim)
        inv = pro.inv_rms(x, k_true=prologue_k, eps=prologue_eps)
        pro_in = [inv, gain]
        pro.validate_operands(prologue, pro_in, m=m, k=kdim)

    acc_dtype = acc_dtype_for(x, p)
    if epilogue == "none":
        out_dtype = out_dtype or (x.dtype if acc_dtype == jnp.float32 else acc_dtype)
    else:
        out_dtype = out_dtype or (
            x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
        )
    grid = (m // block_m, n // array_n, kdim // array_n)

    extra_in = list(epilogue_operands)
    pro_specs = pro.operand_block_specs(prologue, block_m=block_m, block_k=array_n)
    extra_specs = epi.operand_block_specs(
        epilogue, block_m=block_m, block_n=array_n, block_k=array_n
    )

    scratch = [common.VMEM((block_m, array_n), acc_dtype)]
    if spec.dual_weight:
        scratch.append(common.VMEM((block_m, array_n), acc_dtype))

    return pl.pallas_call(
        functools.partial(
            _kernel, array_n=array_n, epilogue=epilogue, prologue=prologue
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, array_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((array_n, array_n), lambda i, j, k: (k, j)),
            *pro_specs,
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((block_m, array_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, p, *pro_in, *extra_in)
