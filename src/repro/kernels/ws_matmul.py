"""Weight-stationary baseline matmul kernel (TPU-like reference).

Identical block structure to kernels/dip_matmul.py minus the de-shear: this
is the conventional WS tiled matmul the paper compares against.  Kept as a
separate entry point so benchmarks can ablate the de-shear cost precisely
(dip_matmul_pallas(fuse_deshear=False) and ws_matmul_pallas must generate
identical HLO modulo the input tensor).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.dip_matmul import dip_matmul_pallas

__all__ = ["ws_matmul_pallas"]


@functools.wraps(dip_matmul_pallas)
def ws_matmul_pallas(x: jax.Array, w: jax.Array, *epilogue_operands, **kwargs):
    """Plain tiled matmul ``x @ w`` (weights in natural layout).

    Fused epilogues pass through unchanged (``epilogue_operands`` carries the
    up-projection weight / bias row / residual block, kernels/epilogue.py) —
    the flush-stage fusion is orthogonal to the de-shear ablation.
    """
    kwargs.setdefault("fuse_deshear", False)
    return dip_matmul_pallas(x, w, *epilogue_operands, **kwargs)
