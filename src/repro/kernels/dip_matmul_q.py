"""Quantized DiP matmul kernels: reduced-precision permutated weights.

Two precisions over the same block structure as kernels/dip_matmul.py
(grid = (M/bm, N/bn, K/bk), K innermost, de-shear fused in VMEM):

``int8`` (the paper's own PE datatype, DiP Table 3; ADiP's headline regime)
    W8A8-dynamic: activations are quantized per-row to int8 on the way in
    (``ref.quantize_acts_int8`` — one cheap jnp reduction over K), weights
    arrive as per-column-scaled int8 permutated storage.  The MXU loop
    accumulates **exactly** in int32; the epilogue applies the rank-1 scale
    ``x_scale[m] * w_scale[n]`` once per output block — so the only
    approximation in the whole pipeline is the two quantization roundings.

``fp8`` (e4m3 storage)
    Weight-only: fp8 storage is upcast at block load, de-sheared, and fed to
    the MXU with f32 accumulation; the per-column scale is fused on output.
    The upcast width is gated on device support (:func:`fp8_compute_dtype`):
    bf16 on hardware with native fp8/bf16 MXU paths, f32 as the emulated
    fallback everywhere else (CPU interpret mode, older TPUs).

Scale operands ride through the grid as (M, 1) / (1, N) blocks so the
epilogue reads one sublane/lane vector — no extra VMEM pressure.

Fused epilogues (kernels/epilogue.py) compose AFTER the scale-on-output: the
flush computes ``z = acc * x_scale * w_scale`` in f32 and applies bias /
activation / residual to ``z`` before the single output cast.  ``swiglu``
streams a second quantized weight (its own per-column scales) over the same
activation block — both gate and up consume the SAME quantized-activation
block, so the int8 path quantizes x exactly once for the pair.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common
from repro.kernels import epilogue as epi
from repro.kernels import prologue as pro
from repro.kernels.ref import quantize_acts_int8

__all__ = ["dip_matmul_q_pallas", "fp8_compute_dtype", "fp8_native_supported"]


def fp8_native_supported() -> bool:
    """Whether this device has a native reduced-precision MXU path for fp8
    operands (TPU v5+ / GPU).  CPU interpret mode always emulates."""
    try:
        backend = jax.default_backend()
        if backend == "gpu":
            return True
        if backend == "tpu":
            kind = jax.devices()[0].device_kind.lower()
            return any(tag in kind for tag in ("v5", "v6", "v7"))
    except Exception:
        pass
    return False


def fp8_compute_dtype():
    """Width fp8 storage is upcast to inside the kernel: bf16 where the MXU
    consumes it natively at reduced cost, f32 for the emulated fallback."""
    return jnp.bfloat16 if fp8_native_supported() else jnp.float32


def _kernel(x_ref, p_ref, xs_ref, ws_ref, *rest, perm_tile: int,
            upcast_dtype, epilogue: str):
    spec = epi.spec(epilogue)
    n_extra = 2 if spec.dual_weight else spec.n_operands
    extra = rest[:n_extra]
    o_ref = rest[n_extra]
    acc_refs = rest[n_extra + 1:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        for acc in acc_refs:
            acc[...] = jnp.zeros_like(acc)

    def deshear(w):
        if upcast_dtype is not None:  # fp8 path: widen before the vector de-shear
            w = w.astype(upcast_dtype)
        return common.deshear_block(w, perm_tile)

    x = x_ref[...]
    acc_refs[0][...] += jnp.dot(
        x, deshear(p_ref[...]), preferred_element_type=acc_refs[0].dtype
    )
    if spec.dual_weight:  # up projection over the SAME (already quantized) x
        acc_refs[1][...] += jnp.dot(
            x, deshear(extra[0][...]), preferred_element_type=acc_refs[1].dtype
        )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        xs = xs_ref[...]
        z = acc_refs[0][...].astype(jnp.float32) * xs * ws_ref[...]
        if epilogue == "none":
            o_ref[...] = z.astype(o_ref.dtype)
        else:
            if spec.dual_weight:  # extra = (q_up, ws_up)
                aux = (acc_refs[1][...].astype(jnp.float32) * xs * extra[1][...],)
            else:
                aux = tuple(op[...].astype(jnp.float32) for op in extra)
            o_ref[...] = epi.apply(epilogue, z, *aux).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "perm_tile", "interpret",
                     "out_dtype", "epilogue", "prologue", "prologue_k",
                     "prologue_eps"),
)
def dip_matmul_q_pallas(
    x: jax.Array,
    q: jax.Array,
    w_scale: jax.Array,
    *epilogue_operands: jax.Array,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    perm_tile: int = 64,
    interpret: bool = False,
    out_dtype=None,
    epilogue: str = "none",
    prologue: str = "none",
    prologue_operands=(),
    prologue_k=None,
    prologue_eps: float = pro.DEFAULT_EPS,
):
    """``epilogue(x @ dequant(unpermute_tiled(q)))`` with quantized arithmetic.

    ``x``: (M, K) float activations; ``q``: (K, N) quantized DiP-permutated
    storage (int8 or fp8 e4m3); ``w_scale``: (1, N) f32 per-output-channel
    scales.  Shapes must already be padded to block multiples (the registry
    dispatch shim handles padding).  int8 storage selects the W8A8 int32
    path; fp8 the weight-only upcast path (module doc).
    ``epilogue_operands``: ``(q_up, w_scale_up)`` for ``swiglu`` (a second
    quantized weight + its scales), ``(b,)`` (1, N) for the bias variants,
    ``(r,)`` (M, N) for ``residual``.
    """
    m, kdim = x.shape
    k2, n = q.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {q.shape}")
    if w_scale.shape != (1, n):
        raise ValueError(
            f"w_scale must be (1, {n}) per-output-channel, got {w_scale.shape}"
        )
    if m % block_m or kdim % block_k or n % block_n:
        raise ValueError(f"unpadded shapes {x.shape} @ {q.shape} for blocks "
                         f"({block_m},{block_k},{block_n})")
    if block_k % perm_tile or block_n % perm_tile:
        raise ValueError("block_k/block_n must be multiples of the permutation tile")
    spec = epi.spec(epilogue)
    epi.validate_operands(
        epilogue, epilogue_operands, m=m, n=n, w_shape=q.shape,
        w_dtype=q.dtype, with_scales=True,
    )
    if pro.spec(prologue).normalize:
        # The quantized kernels' load stage IS the activation quantization,
        # which happens here in the wrapper (one jnp pass over x).  The
        # RMSNorm folds into that same pass — x is normalized before the
        # per-row amax/rounding so the int8/fp8 operands carry the
        # normalized values, and the dispatch stays ONE pallas launch.
        (gain,) = prologue_operands
        x = pro.apply(prologue, x, gain.reshape(-1),
                      k_true=prologue_k, eps=prologue_eps)

    int_path = jnp.issubdtype(q.dtype, jnp.integer)
    if int_path:
        if q.dtype != jnp.int8:
            raise ValueError(f"integer storage must be int8, got {q.dtype}")
        xk, x_scale = quantize_acts_int8(x)
        acc_dtype, upcast = jnp.int32, None
    else:
        upcast = fp8_compute_dtype()
        xk = x.astype(upcast)
        x_scale = jnp.ones((m, 1), jnp.float32)
        acc_dtype = jnp.float32
    out_dtype = out_dtype or (
        x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
    )
    w_scale = w_scale.astype(jnp.float32)
    grid = (m // block_m, n // block_n, kdim // block_k)

    extra_in = list(epilogue_operands)
    if spec.dual_weight:  # the up scales ride f32 like the gate scales
        extra_in[1] = extra_in[1].astype(jnp.float32)
    extra_specs = epi.operand_block_specs(
        epilogue, block_m=block_m, block_n=block_n, block_k=block_k,
        with_scales=True,
    )

    scratch = [common.VMEM((block_m, block_n), acc_dtype)]
    if spec.dual_weight:
        scratch.append(common.VMEM((block_m, block_n), acc_dtype))

    return pl.pallas_call(
        functools.partial(
            _kernel, perm_tile=perm_tile, upcast_dtype=upcast, epilogue=epilogue
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xk, q, x_scale, w_scale, *extra_in)
