"""Pallas TPU kernels for the DiP matmul fast path and dataflow emulation.

Every kernel has a pure-jnp oracle in ref.py and is validated in
interpret=True mode on CPU (tests/test_kernels.py); on TPU the same code
compiles through Mosaic.  See each module's docstring for the VMEM/BlockSpec
design.
"""

from repro.kernels import epilogue, ref
from repro.kernels.dip_matmul import dip_matmul_pallas
from repro.kernels.dip_matmul_q import dip_matmul_q_pallas
from repro.kernels.dip_systolic import dip_systolic_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ws_matmul import ws_matmul_pallas

__all__ = [
    "epilogue",
    "ref",
    "dip_matmul_pallas",
    "dip_matmul_q_pallas",
    "dip_systolic_pallas",
    "flash_attention_pallas",
    "ws_matmul_pallas",
]
