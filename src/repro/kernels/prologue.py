"""Shared prologue library for the fused matmul kernels.

PR 4 fused everything *downstream* of the matmul (``kernels/epilogue.py``):
bias / activation / SwiGLU / residual ride the accumulator flush.  This
module is the mirror image for the *upstream* side.  Every transformer
projection is preceded by an RMSNorm of the same activation block, and the
unfused form pays one full HBM round-trip for it: the norm writes its
(M, K) result only for the kernel to immediately stream it back in.  The
tiled kernels already own the natural fusion point — the ``x`` block load
at the top of each grid step — so the prologue is applied there, on the
block that is already in VMEM, and the raw (un-normalized) activations are
the only x tensor that ever reaches HBM.

The split mirrors how RMSNorm factorizes: the *reduction* (one scalar
``1/rms`` per row) is O(M) data and runs as a plain XLA reduction in the
dispatch wrapper, while the O(M*K) *elementwise application* — the part
that costs a round-trip — happens inside the kernel:

    inv[i]  = rsqrt( sum_k x[i,k]^2 / k_true + eps )     (wrapper, XLA)
    xn[i,k] = cast( x32[i,k] * inv[i] * g[k] )           (kernel load stage)

so a fused dispatch is still exactly ONE pallas launch.  The cast back to
the input dtype makes the fused path bit-match the decomposed
``layers.rms_norm(x, g) -> matmul`` composition.

One definition serves three consumers (same contract as the epilogue
library): the Pallas kernels apply :func:`kernel_load` at their load stage;
the pure-jnp oracles in ``kernels/ref.py`` and the registry's decomposed
fallback (backends without prologue support, e.g. ``xla``) apply
:func:`apply` to the full activation.

Variants (``PROLOGUES``):

    none        identity (the historical load)
    rmsnorm     rms-normalize each x row, scale by a learned (K,) gain
                operands: (g,) — the norm weight
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "PROLOGUES",
    "PrologueSpec",
    "DEFAULT_EPS",
    "spec",
    "n_operands",
    "apply",
    "inv_rms",
    "validate_operands",
    "operand_block_specs",
    "kernel_load",
]

DEFAULT_EPS = 1e-5  # matches layers.rms_norm


@dataclasses.dataclass(frozen=True)
class PrologueSpec:
    """Static description of one prologue variant.

    ``normalize`` marks the rmsnorm family: the kernel receives the
    per-row ``(M, 1)`` inverse-rms column (reduced in the wrapper) plus the
    ``(1, K)`` gain row, and rescales each x block at load time.
    """

    name: str
    normalize: bool = False

    @property
    def n_operands(self) -> int:
        """Extra operands beyond (x, w) at the *dispatch* level: the norm
        gain for ``rmsnorm``.  (Kernels additionally receive the derived
        inverse-rms column — see :func:`operand_block_specs`.)"""
        return int(self.normalize)


PROLOGUES: Tuple[str, ...] = ("none", "rmsnorm")

_SPECS = {
    "none": PrologueSpec("none"),
    "rmsnorm": PrologueSpec("rmsnorm", normalize=True),
}


def spec(name: Optional[str]) -> PrologueSpec:
    """Resolve a prologue name (``None`` means ``"none"``); raises on
    unknown names so a typo fails at dispatch, not silently unfused."""
    try:
        return _SPECS[name or "none"]
    except KeyError:
        raise ValueError(
            f"unknown prologue {name!r}; supported: {list(PROLOGUES)}"
        ) from None


def n_operands(name: Optional[str]) -> int:
    return spec(name).n_operands


def inv_rms(
    x: jax.Array, *, k_true: Optional[int] = None, eps: float = DEFAULT_EPS
) -> jax.Array:
    """Per-row ``(M, 1)`` float32 inverse RMS of ``x``.

    ``k_true`` is the *logical* contraction dim: dispatch pads K with zero
    columns, which add nothing to the sum of squares, but the mean's
    divisor must stay the un-padded width for fused/decomposed parity.
    """
    x32 = x.astype(jnp.float32)
    k = x.shape[-1] if k_true is None else k_true
    ssq = jnp.sum(x32 * x32, axis=-1, keepdims=True)
    return jax.lax.rsqrt(ssq / k + eps)


def apply(
    name: Optional[str],
    x: jax.Array,
    *operands: jax.Array,
    k_true: Optional[int] = None,
    eps: float = DEFAULT_EPS,
) -> jax.Array:
    """Apply one prologue to the activation ``x`` (reference / decomposed
    form).  Math runs in float32 and casts back to ``x.dtype`` — identical
    to ``layers.rms_norm`` and to what the fused kernels compute blockwise.
    """
    s = spec(name)
    if len(operands) != s.n_operands:
        raise ValueError(
            f"prologue {s.name!r} takes {s.n_operands} operand(s), "
            f"got {len(operands)}"
        )
    if not s.normalize:
        return x
    (g,) = operands
    inv = inv_rms(x, k_true=k_true, eps=eps)
    xn = x.astype(jnp.float32) * inv * g.reshape(1, -1).astype(jnp.float32)
    return xn.astype(x.dtype)


# ---------------------------------------------------------------------------
# shared kernel-side plumbing: ONE operand contract and ONE load across the
# fused kernels (dip_matmul / dip_systolic; the quantized wrapper normalizes
# before activation quantization), so the contract cannot drift between them.
def validate_operands(name: Optional[str], operands, *, m: int, k: int) -> None:
    """Check a kernel's ``prologue_operands`` against the shared contract:
    the ``(M, 1)`` float32 inverse-rms column (reduced by the wrapper)
    followed by the ``(1, K)`` gain row."""
    s = spec(name)
    expected = 2 * s.n_operands  # (inv, gain) per normalizing prologue
    if len(operands) != expected:
        raise ValueError(
            f"prologue {s.name!r} takes {expected} kernel operand(s), "
            f"got {len(operands)}"
        )
    if s.normalize:
        inv, g = operands
        if tuple(inv.shape) != (m, 1) or inv.dtype != jnp.float32:
            raise ValueError(
                f"prologue inverse-rms must be ({m}, 1) float32, "
                f"got {inv.shape}:{inv.dtype}"
            )
        if tuple(g.shape) != (1, k):
            raise ValueError(
                f"prologue gain must be (1, {k}), got {g.shape}"
            )


def operand_block_specs(name: Optional[str], *, block_m: int, block_k: int):
    """BlockSpecs for the validated prologue operands, in the kernels'
    shared ``(i, j, k)`` grid convention: the inverse-rms column rides as a
    (bm, 1) block at (i, 0), the gain row as a (1, bk) block at (0, k) —
    both revisited per j like the x block itself.  The wavefront kernel
    passes its ``array_n`` as ``block_k``."""
    s = spec(name)
    if not s.normalize:
        return []
    return [
        pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
        pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)),
    ]


def kernel_load(name: Optional[str], x_ref, pro_refs):
    """The fused kernels' x-block load: ``none`` reads the block straight
    through (the historical load); ``rmsnorm`` rescales it by the per-row
    inverse rms and the gain row in float32, then casts ONCE back to the
    input dtype so the streamed block bit-matches the decomposed
    ``rms_norm -> matmul`` composition (the MXU sees the same operand)."""
    x = x_ref[...]
    if (name or "none") == "none":
        return x
    inv_ref, g_ref = pro_refs
    xn = x.astype(jnp.float32) * inv_ref[...] * g_ref[...].astype(jnp.float32)
    return xn.astype(x.dtype)
