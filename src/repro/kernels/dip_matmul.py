"""DiP fast-path matmul kernel: MXU matmul over permutated weight storage.

The paper stores weights *permutated* (offline, software-level — Fig. 3) so
the array consumes them without synchronization FIFOs.  On TPU the analogous
first-class storage format keeps weights DiP-permutated in HBM; this kernel
de-shears each weight block in VMEM (log2(64)=6 static rolls + selects, see
kernels/common.py) and feeds the MXU, so the de-shear cost is amortized over
the whole M dimension of the input block:

    vector work  : O(bk * bn * log2 tile)   per weight block
    MXU work     : O(bm * bk * bn)          per weight block

Block layout (grid = (M/bm, N/bn, K/bk), K innermost for accumulation):

    x : (bm, bk) VMEM   p : (bk, bn) VMEM   out : (bm, bn) VMEM
    acc scratch : (bm, bn) f32/i32 VMEM

All of bm/bk/bn default to MXU-aligned multiples of 128; bk and bn must be
multiples of the permutation tile (64).

Fused epilogues (kernels/epilogue.py) ride the accumulator flush: the
``k == num_programs - 1`` step applies bias / activation / residual to the
f32 accumulator while it is still in VMEM, so the activated result is the
only (M, N) tensor that reaches HBM.  ``swiglu`` is dual-weight: the gate
and up projections stream over the same x block with two accumulators — one
read of x, no intermediate gate/up arrays.

Fused prologues (kernels/prologue.py) mirror that on the load stage: the
per-row inverse RMS (reduced once in the wrapper, O(M) data) and the norm
gain rescale each x block right after it lands in VMEM, so the raw
activations are the only x tensor that ever reaches HBM — still ONE pallas
launch per dispatch.

ABFT verification (reliability/abft.py) audits this kernel from the outside
rather than the inside: in the paper's dataflow a Huang–Abraham checksum
probe is just one more input row streaming diagonally through the array
(the weights sit still, so ``sum_n out[m, n] == x[m, :] @ row_checksum``
holds for whatever the array computed), and because the DiP permutation
rotates elements *within* storage columns, the storage column sums are
layout-invariant and can audit the permutated bytes directly.  Neither
check touches this kernel's body — ``api.matmul(..., verify=)`` wraps the
dispatch with O(M·N) jnp reductions, keeping the verified output
bit-identical and the launch count at ONE (asserted by the fleet's
``verify_probe`` column).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common
from repro.kernels import epilogue as epi
from repro.kernels import prologue as pro
from repro.kernels.ref import acc_dtype_for

__all__ = ["dip_matmul_pallas"]


def _kernel(x_ref, p_ref, *rest, perm_tile: int, fuse_deshear: bool,
            epilogue: str, prologue: str):
    spec = epi.spec(epilogue)
    n_pro = 2 * pro.n_operands(prologue)
    pro_refs = rest[:n_pro]
    rest = rest[n_pro:]
    extra = rest[: spec.n_operands]
    o_ref = rest[spec.n_operands]
    acc_refs = rest[spec.n_operands + 1:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        for acc in acc_refs:
            acc[...] = jnp.zeros_like(acc)

    x = pro.kernel_load(prologue, x_ref, pro_refs)
    w = common.deshear_block(p_ref[...], perm_tile) if fuse_deshear else p_ref[...]
    acc_refs[0][...] += jnp.dot(x, w, preferred_element_type=acc_refs[0].dtype)
    if spec.dual_weight:  # up projection over the SAME x block
        wu = (
            common.deshear_block(extra[0][...], perm_tile)
            if fuse_deshear else extra[0][...]
        )
        acc_refs[1][...] += jnp.dot(x, wu, preferred_element_type=acc_refs[1].dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        epi.kernel_flush(epilogue, o_ref, acc_refs, extra)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "perm_tile", "interpret",
                     "out_dtype", "fuse_deshear", "epilogue", "prologue",
                     "prologue_k", "prologue_eps"),
)
def dip_matmul_pallas(
    x: jax.Array,
    p: jax.Array,
    *epilogue_operands: jax.Array,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    perm_tile: int = 64,
    interpret: bool = False,
    out_dtype=None,
    fuse_deshear: bool = True,
    epilogue: str = "none",
    prologue: str = "none",
    prologue_operands=(),
    prologue_k=None,
    prologue_eps: float = pro.DEFAULT_EPS,
):
    """``epilogue(prologue(x) @ unpermute_tiled(p))`` with the de-shear
    fused into the MXU loop, the prologue fused into the x-block load, and
    the epilogue fused into the accumulator flush.

    Shapes must already be padded to block multiples (the registry dispatch
    shim handles padding); ``p`` is the DiP-permutated weight (K, N).  With
    ``fuse_deshear=False`` the kernel is a plain WS tiled matmul (used as
    the baseline and for pre-desheared weights).  ``epilogue_operands`` per
    variant: ``(p_up,)`` for ``swiglu`` (a second (K, N) weight), ``(b,)``
    of shape (1, N) for the bias variants, ``(r,)`` of shape (M, N) for
    ``residual`` — see kernels/epilogue.py.  ``prologue_operands`` is the
    (1, K) norm gain row for ``rmsnorm``; ``prologue_k`` is the logical
    (un-padded) contraction dim the RMS mean divides by.
    """
    m, kdim = x.shape
    k2, n = p.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {p.shape}")
    if m % block_m or kdim % block_k or n % block_n:
        raise ValueError(f"unpadded shapes {x.shape} @ {p.shape} for blocks "
                         f"({block_m},{block_k},{block_n})")
    if block_k % perm_tile or block_n % perm_tile:
        raise ValueError("block_k/block_n must be multiples of the permutation tile")
    spec = epi.spec(epilogue)
    epi.validate_operands(
        epilogue, epilogue_operands, m=m, n=n, w_shape=p.shape, w_dtype=p.dtype
    )
    pro_in = []
    if pro.spec(prologue).normalize:
        (gain,) = prologue_operands
        gain = gain.reshape(1, kdim)
        inv = pro.inv_rms(x, k_true=prologue_k, eps=prologue_eps)
        pro_in = [inv, gain]
        pro.validate_operands(prologue, pro_in, m=m, k=kdim)

    acc_dtype = acc_dtype_for(x, p)
    if epilogue == "none":
        out_dtype = out_dtype or (x.dtype if acc_dtype == jnp.float32 else acc_dtype)
    else:
        # epilogue arithmetic is f32 on the widened accumulator: the output
        # is float even when the matmul accumulates in int32
        out_dtype = out_dtype or (
            x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
        )
    grid = (m // block_m, n // block_n, kdim // block_k)

    extra_in = list(epilogue_operands)
    pro_specs = pro.operand_block_specs(prologue, block_m=block_m, block_k=block_k)
    extra_specs = epi.operand_block_specs(
        epilogue, block_m=block_m, block_n=block_n, block_k=block_k
    )
    scratch = [common.VMEM((block_m, block_n), acc_dtype)]
    if spec.dual_weight:
        scratch.append(common.VMEM((block_m, block_n), acc_dtype))

    return pl.pallas_call(
        functools.partial(
            _kernel, perm_tile=perm_tile, fuse_deshear=fuse_deshear,
            epilogue=epilogue, prologue=prologue,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            *pro_specs,
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, p, *pro_in, *extra_in)
