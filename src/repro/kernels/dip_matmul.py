"""DiP fast-path matmul kernel: MXU matmul over permutated weight storage.

The paper stores weights *permutated* (offline, software-level — Fig. 3) so
the array consumes them without synchronization FIFOs.  On TPU the analogous
first-class storage format keeps weights DiP-permutated in HBM; this kernel
de-shears each weight block in VMEM (log2(64)=6 static rolls + selects, see
kernels/common.py) and feeds the MXU, so the de-shear cost is amortized over
the whole M dimension of the input block:

    vector work  : O(bk * bn * log2 tile)   per weight block
    MXU work     : O(bm * bk * bn)          per weight block

Block layout (grid = (M/bm, N/bn, K/bk), K innermost for accumulation):

    x : (bm, bk) VMEM   p : (bk, bn) VMEM   out : (bm, bn) VMEM
    acc scratch : (bm, bn) f32/i32 VMEM

All of bm/bk/bn default to MXU-aligned multiples of 128; bk and bn must be
multiples of the permutation tile (64).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common
from repro.kernels.ref import acc_dtype_for

__all__ = ["dip_matmul_pallas"]


def _kernel(x_ref, p_ref, o_ref, acc_ref, *, perm_tile: int, fuse_deshear: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = common.deshear_block(p_ref[...], perm_tile) if fuse_deshear else p_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "perm_tile", "interpret", "out_dtype", "fuse_deshear"),
)
def dip_matmul_pallas(
    x: jax.Array,
    p: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    perm_tile: int = 64,
    interpret: bool = False,
    out_dtype=None,
    fuse_deshear: bool = True,
):
    """``x @ unpermute_tiled(p)`` with the de-shear fused into the MXU loop.

    Shapes must already be padded to block multiples (ops.py handles padding);
    ``p`` is the DiP-permutated weight (K, N).  With ``fuse_deshear=False``
    the kernel is a plain WS tiled matmul (used as the baseline and for
    pre-desheared weights).
    """
    m, kdim = x.shape
    k2, n = p.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {p.shape}")
    if m % block_m or kdim % block_k or n % block_n:
        raise ValueError(f"unpadded shapes {x.shape} @ {p.shape} for blocks "
                         f"({block_m},{block_k},{block_n})")
    if block_k % perm_tile or block_n % perm_tile:
        raise ValueError("block_k/block_n must be multiples of the permutation tile")

    acc_dtype = acc_dtype_for(x, p)
    out_dtype = out_dtype or (x.dtype if acc_dtype == jnp.float32 else acc_dtype)
    grid = (m // block_m, n // block_n, kdim // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, perm_tile=perm_tile, fuse_deshear=fuse_deshear),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[common.VMEM((block_m, block_n), acc_dtype)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, p)
