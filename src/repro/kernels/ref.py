"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` computes the same mathematical function as its kernel with
plain jax.numpy ops (no Pallas), in float32/int32 accumulation, so the
kernels can be asserted allclose against them across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import permute
from repro.kernels import epilogue as _epi

__all__ = [
    "acc_dtype_for",
    "ws_matmul_ref",
    "dip_matmul_ref",
    "dip_systolic_ref",
    "quantize_acts_int8",
    "dip_matmul_int8w_ref",
    "dip_matmul_fp8_ref",
    "epilogue_ref",
    "ws_matmul_epilogue_ref",
    "dip_matmul_epilogue_ref",
    "dip_matmul_int8w_epilogue_ref",
    "dip_matmul_fp8_epilogue_ref",
]


def acc_dtype_for(*args: jax.Array) -> jnp.dtype:
    """MXU accumulation dtype: int32 for integer operands, else float32."""
    if all(jnp.issubdtype(a.dtype, jnp.integer) for a in args):
        return jnp.int32
    return jnp.float32


def ws_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain matmul — the weight-stationary (TPU-like) semantics."""
    return jnp.matmul(x, w, preferred_element_type=acc_dtype_for(x, w))


def dip_matmul_ref(x: jax.Array, p: jax.Array, *, perm_tile: int = 64) -> jax.Array:
    """DiP fast-path semantics: x @ unpermute_tiled(p).

    ``p`` holds the weights in DiP-permutated storage (per ``perm_tile`` x
    ``perm_tile`` block, paper Fig. 3 applied tile-wise).
    """
    w = permute.unpermute_tiled(p, perm_tile)
    return jnp.matmul(x, w, preferred_element_type=acc_dtype_for(x, p))


def dip_systolic_ref(x: jax.Array, p: jax.Array, *, perm_tile: int = 64) -> jax.Array:
    """Wavefront-emulation semantics — mathematically identical to the fast
    path; kept separate so both kernels are pinned to an explicit oracle."""
    return dip_matmul_ref(x, p, perm_tile=perm_tile)


# ---------------------------------------------------------------------------
# quantized-path oracles (kernels/dip_matmul_q.py).  The activation-side
# quantizer lives here so the kernel wrapper and the oracle share ONE
# definition — parity between them is then exact int32 arithmetic plus
# identically-ordered float32 scaling.
def quantize_acts_int8(x: jax.Array):
    """Dynamic symmetric per-row int8 activation quantization.

    Returns ``(q, scale)`` with ``q`` int8 of x's shape and ``scale``
    float32 ``(..., 1)`` such that ``q * scale ~= x``.  All-zero rows get a
    floor scale instead of a 0/0.
    """
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dip_matmul_int8w_ref(
    x: jax.Array, q: jax.Array, w_scale: jax.Array, *, perm_tile: int = 64
) -> jax.Array:
    """W8A8-dynamic semantics: per-row int8 acts x per-column int8 weights,
    exact int32 accumulation, fused f32 scale-on-output.

    ``q``: int8 DiP-permutated storage (K, N); ``w_scale``: (1, N) f32.
    """
    xq, x_scale = quantize_acts_int8(x)
    w = permute.unpermute_tiled(q, perm_tile)
    acc = jnp.matmul(xq, w, preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * x_scale * w_scale.astype(jnp.float32)
    return out.astype(x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32)


def dip_matmul_fp8_ref(
    x: jax.Array, q: jax.Array, w_scale: jax.Array, *, perm_tile: int = 64
) -> jax.Array:
    """fp8-weight semantics: fp8 storage upcast, f32 accumulation, fused
    per-column scale-on-output; activations stay in their float dtype."""
    w = permute.unpermute_tiled(q, perm_tile).astype(jnp.float32)
    acc = jnp.matmul(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    out = acc * w_scale.astype(jnp.float32)
    return out.astype(x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32)


# ---------------------------------------------------------------------------
# fused-epilogue oracles (kernels/epilogue.py applied at the flush).  The
# epilogue arithmetic itself is ONE definition shared with the kernels —
# ``epilogue_ref`` is literally ``kernels.epilogue.apply`` — so parity
# between a fused kernel and its oracle is the matmul semantics above plus
# identically-ordered f32 epilogue math and the single output cast.
epilogue_ref = _epi.apply


def _f32(t: jax.Array) -> jax.Array:
    return t.astype(jnp.float32)


def _epilogue_out_dtype(x: jax.Array):
    """Epilogues compute in f32, so the fused output is float even for
    integer-accumulating kernels (matches the kernel wrappers)."""
    return x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32


def ws_matmul_epilogue_ref(
    x: jax.Array, w: jax.Array, *, epilogue: str = "none", operands=()
) -> jax.Array:
    """Natural-layout fused semantics: ``epilogue(x @ w)``.  For ``swiglu``
    ``operands`` is ``(w_up,)`` (natural layout); for bias/residual the
    broadcastable bias row / the (M, N) residual."""
    z = _f32(ws_matmul_ref(x, w))
    spec = _epi.spec(epilogue)
    if spec.dual_weight:
        aux = (_f32(ws_matmul_ref(x, operands[0])),)
    else:
        aux = tuple(_f32(op) for op in operands)
    return _epi.apply(epilogue, z, *aux).astype(_epilogue_out_dtype(x))


def dip_matmul_epilogue_ref(
    x: jax.Array, p: jax.Array, *, epilogue: str = "none", operands=(),
    perm_tile: int = 64
) -> jax.Array:
    """DiP fast-path fused semantics: ``epilogue(x @ unpermute_tiled(p))``.
    For ``swiglu`` ``operands`` is ``(p_up,)`` in permutated storage."""
    z = _f32(dip_matmul_ref(x, p, perm_tile=perm_tile))
    spec = _epi.spec(epilogue)
    if spec.dual_weight:
        aux = (_f32(dip_matmul_ref(x, operands[0], perm_tile=perm_tile)),)
    else:
        aux = tuple(_f32(op) for op in operands)
    return _epi.apply(epilogue, z, *aux).astype(_epilogue_out_dtype(x))


def dip_matmul_int8w_epilogue_ref(
    x: jax.Array, q: jax.Array, w_scale: jax.Array, *, epilogue: str = "none",
    operands=(), perm_tile: int = 64
) -> jax.Array:
    """W8A8-dynamic fused semantics: the epilogue composes AFTER the rank-1
    scale-on-output.  For ``swiglu`` ``operands`` is ``(q_up, w_scale_up)``
    — both projections consume the SAME quantized-activation block (x is
    quantized once for the pair, exactly as the kernel does)."""
    xq, x_scale = quantize_acts_int8(x)
    spec = _epi.spec(epilogue)

    def z_of(qs, ws):
        w = permute.unpermute_tiled(qs, perm_tile)
        acc = jnp.matmul(xq, w, preferred_element_type=jnp.int32)
        return _f32(acc) * x_scale * _f32(ws)

    z = z_of(q, w_scale)
    if spec.dual_weight:
        aux = (z_of(operands[0], operands[1]),)
    else:
        aux = tuple(_f32(op) for op in operands)
    return _epi.apply(epilogue, z, *aux).astype(_epilogue_out_dtype(x))


def dip_matmul_fp8_epilogue_ref(
    x: jax.Array, q: jax.Array, w_scale: jax.Array, *, epilogue: str = "none",
    operands=(), perm_tile: int = 64
) -> jax.Array:
    """fp8-weight fused semantics: per-column scale then epilogue, all f32."""
    spec = _epi.spec(epilogue)

    def z_of(qs, ws):
        w = permute.unpermute_tiled(qs, perm_tile).astype(jnp.float32)
        acc = jnp.matmul(_f32(x), w, preferred_element_type=jnp.float32)
        return acc * _f32(ws)

    z = z_of(q, w_scale)
    if spec.dual_weight:
        aux = (z_of(operands[0], operands[1]),)
    else:
        aux = tuple(_f32(op) for op in operands)
    return _epi.apply(epilogue, z, *aux).astype(_epilogue_out_dtype(x))
