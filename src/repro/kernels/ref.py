"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` computes the same mathematical function as its kernel with
plain jax.numpy ops (no Pallas), in float32/int32 accumulation, so the
kernels can be asserted allclose against them across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import permute

__all__ = ["acc_dtype_for", "ws_matmul_ref", "dip_matmul_ref", "dip_systolic_ref"]


def acc_dtype_for(*args: jax.Array) -> jnp.dtype:
    """MXU accumulation dtype: int32 for integer operands, else float32."""
    if all(jnp.issubdtype(a.dtype, jnp.integer) for a in args):
        return jnp.int32
    return jnp.float32


def ws_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain matmul — the weight-stationary (TPU-like) semantics."""
    return jnp.matmul(x, w, preferred_element_type=acc_dtype_for(x, w))


def dip_matmul_ref(x: jax.Array, p: jax.Array, *, perm_tile: int = 64) -> jax.Array:
    """DiP fast-path semantics: x @ unpermute_tiled(p).

    ``p`` holds the weights in DiP-permutated storage (per ``perm_tile`` x
    ``perm_tile`` block, paper Fig. 3 applied tile-wise).
    """
    w = permute.unpermute_tiled(p, perm_tile)
    return jnp.matmul(x, w, preferred_element_type=acc_dtype_for(x, p))


def dip_systolic_ref(x: jax.Array, p: jax.Array, *, perm_tile: int = 64) -> jax.Array:
    """Wavefront-emulation semantics — mathematically identical to the fast
    path; kept separate so both kernels are pinned to an explicit oracle."""
    return dip_matmul_ref(x, p, perm_tile=perm_tile)
