"""Shared epilogue library for the fused matmul kernels.

The paper's dataflow wins by keeping the operand stream and the array in
lockstep — no synchronization FIFOs between producer and consumer.  Our TPU
analogue of that synchronization tax is the HBM round-trip between a
projection and the elementwise ops glued to it: an unfused ``linear`` writes
its (M, N) result to HBM only for XLA to immediately re-read it for the bias
add, activation, SwiGLU gate, or residual add.  Every tiled kernel in this
package already owns the natural fusion point — the ``k == num_programs - 1``
accumulator flush — so the epilogue is applied there, on the f32 accumulator
block that is still in VMEM, and the activated result is the only thing that
ever reaches HBM.

One definition serves three consumers:

* the Pallas kernels apply :func:`apply` to their accumulator block inside
  the flush (``kernels/dip_matmul.py`` / ``dip_systolic.py`` /
  ``dip_matmul_q.py``);
* the pure-jnp oracles in ``kernels/ref.py`` apply the *same* function to
  the full matmul result, so fused-vs-reference parity is exact epilogue
  arithmetic plus the one output cast;
* the registry's decomposed fallback (``api.matmul`` on a backend without
  epilogue support, e.g. ``xla``/GSPMD) applies it after an unfused matmul.

Variants (``EPILOGUES``):

    none        identity (the historical flush)
    bias        z + b                         operands: (b,)  — (N,) bias
    bias_gelu   gelu(z + b)                   operands: (b,)
    bias_silu   silu(z + b)                   operands: (b,)
    swiglu      silu(z_gate) * z_up           dual-weight: w = (w_gate, w_up)
    residual    z + r                         operands: (r,) — (M, N) residual

``swiglu`` is the headline: a dual-weight kernel computes the gate and up
projections over the same ``x`` block in one pass (one read of ``x``, two
accumulators, one write of the activated product — no intermediate gate/up
arrays in HBM).  All epilogue arithmetic happens in float32 on the
accumulator; integer-accumulating kernels (int8 operands) widen the int32
accumulator first, so any epilogue other than ``none`` produces a float
output.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "EPILOGUES",
    "EpilogueSpec",
    "spec",
    "n_operands",
    "apply",
    "validate_operands",
    "operand_block_specs",
    "kernel_flush",
]


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """Static description of one epilogue variant.

    ``dual_weight`` kernels consume a (gate, up) weight pair over the same
    activation block and keep two accumulators; ``bias`` / ``residual``
    describe the extra non-weight operand the flush reads ((1, N) bias row
    vs (M, N) residual block); ``activation`` is applied after the bias add.
    """

    name: str
    dual_weight: bool = False
    bias: bool = False
    residual: bool = False
    activation: Optional[str] = None  # None | "gelu" | "silu"

    @property
    def n_operands(self) -> int:
        """Extra operands beyond (x, w): the up-projection weight for
        dual-weight epilogues, the bias row, or the residual block."""
        return int(self.dual_weight) + int(self.bias) + int(self.residual)


EPILOGUES: Tuple[str, ...] = (
    "none",
    "bias",
    "bias_gelu",
    "bias_silu",
    "swiglu",
    "residual",
)

_SPECS = {
    "none": EpilogueSpec("none"),
    "bias": EpilogueSpec("bias", bias=True),
    "bias_gelu": EpilogueSpec("bias_gelu", bias=True, activation="gelu"),
    "bias_silu": EpilogueSpec("bias_silu", bias=True, activation="silu"),
    "swiglu": EpilogueSpec("swiglu", dual_weight=True),
    "residual": EpilogueSpec("residual", residual=True),
}


def spec(name: Optional[str]) -> EpilogueSpec:
    """Resolve an epilogue name (``None`` means ``"none"``); raises on
    unknown names so a typo fails at dispatch, not silently unfused."""
    try:
        return _SPECS[name or "none"]
    except KeyError:
        raise ValueError(
            f"unknown epilogue {name!r}; supported: {list(EPILOGUES)}"
        ) from None


def n_operands(name: Optional[str]) -> int:
    return spec(name).n_operands


def _activate(kind: Optional[str], z: jax.Array) -> jax.Array:
    if kind is None:
        return z
    if kind == "gelu":
        # tanh-approximate gelu: jnp-only, lowers through Mosaic (no erf)
        return jax.nn.gelu(z, approximate=True)
    if kind == "silu":
        return jax.nn.silu(z)
    raise ValueError(f"unknown epilogue activation {kind!r}")


def apply(name: Optional[str], z: jax.Array, *operands: jax.Array) -> jax.Array:
    """Apply one epilogue to the f32 pre-activation ``z``.

    ``z`` is the (block of the) matmul accumulator, already in float32.  For
    ``swiglu``, ``z`` is the *gate* pre-activation and ``operands`` is
    ``(z_up,)`` — the up-projection accumulator; for the bias variants
    ``operands`` is ``(b,)`` broadcastable over rows; for ``residual`` it is
    ``(r,)`` of z's shape.  Everything stays float32; the single cast to the
    output dtype is the caller's job (the kernel flush / the reference).
    """
    s = spec(name)
    if len(operands) != s.n_operands:
        raise ValueError(
            f"epilogue {s.name!r} takes {s.n_operands} operand(s), "
            f"got {len(operands)}"
        )
    if s.dual_weight:
        (z_up,) = operands
        return jax.nn.silu(z) * z_up
    if s.bias:
        (b,) = operands
        z = z + b
    z = _activate(s.activation, z)
    if s.residual:
        (r,) = operands
        z = z + r
    return z


# ---------------------------------------------------------------------------
# shared kernel-side plumbing: ONE operand contract and ONE flush across the
# three fused kernels (dip_matmul / dip_systolic / dip_matmul_q), so a new
# epilogue variant or a contract change cannot drift between them.
def validate_operands(
    name: Optional[str],
    operands,
    *,
    m: int,
    n: int,
    w_shape,
    w_dtype,
    with_scales: bool = False,
) -> None:
    """Check a kernel's ``epilogue_operands`` against the shared contract:
    ``(p_up[, scale_up])`` matching the gate weight for ``swiglu`` (scales
    on the quantized kernels, ``with_scales=True``), a (1, N) bias row, or
    an (M, N) residual block."""
    s = spec(name)
    expected = 2 if (s.dual_weight and with_scales) else s.n_operands
    if len(operands) != expected:
        raise ValueError(
            f"epilogue {s.name!r} takes {expected} operand(s), "
            f"got {len(operands)}"
        )
    if s.dual_weight:
        pu = operands[0]
        if tuple(pu.shape) != tuple(w_shape) or pu.dtype != w_dtype:
            raise ValueError(
                f"swiglu up-weight must match the gate weight "
                f"{tuple(w_shape)}:{w_dtype}, got {pu.shape}:{pu.dtype}"
            )
        if with_scales and operands[1].shape != (1, n):
            raise ValueError(
                f"up scales must be (1, {n}), got {operands[1].shape}"
            )
    elif s.bias and operands[0].shape != (1, n):
        raise ValueError(
            f"bias operand must be (1, {n}), got {operands[0].shape}"
        )
    elif s.residual and operands[0].shape != (m, n):
        raise ValueError(
            f"residual operand must be ({m}, {n}), got {operands[0].shape}"
        )


def operand_block_specs(
    name: Optional[str],
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    with_scales: bool = False,
):
    """BlockSpecs for the validated epilogue operands, in the kernels'
    shared ``(i, j, k)`` grid convention: the dual-weight up projection
    streams like the gate weight ((bk, bn) at (k, j); plus its (1, bn)
    scale row on the quantized kernels), bias rides as a (1, bn) row,
    residual as the output-aligned (bm, bn) block.  The wavefront kernel
    passes its ``array_n`` for both block_n and block_k."""
    s = spec(name)
    if s.dual_weight:
        specs = [pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j))]
        if with_scales:
            specs.append(pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)))
        return specs
    if s.bias:
        return [pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))]
    if s.residual:
        return [pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j))]
    return []


def kernel_flush(name: Optional[str], o_ref, acc_refs, extra_refs) -> None:
    """The float kernels' accumulator flush: ``none`` writes the accumulator
    straight through (the historical fast path); anything else widens to
    f32, applies :func:`apply`, and casts ONCE to the output dtype.  For
    dual-weight epilogues the up-projection pre-activation is the second
    accumulator; otherwise the extra operand refs feed the epilogue.
    (The quantized kernel has its own flush — its scale-on-output composes
    before the epilogue.)"""
    if (name or "none") == "none":
        o_ref[...] = acc_refs[0][...].astype(o_ref.dtype)
        return
    s = spec(name)
    z = acc_refs[0][...].astype(jnp.float32)
    if s.dual_weight:
        aux = (acc_refs[1][...].astype(jnp.float32),)
    else:
        aux = tuple(op[...].astype(jnp.float32) for op in extra_refs)
    o_ref[...] = apply(name, z, *aux).astype(o_ref.dtype)
