"""DEPRECATED shims over ``repro.api`` — kept for one PR of compatibility.

The public matmul surface moved to ``repro.api``:

    ops.to_dip_format(w)            -> api.DipWeight.from_natural(w).data
    ops.from_dip_format(p, shape)   -> api.DipWeight(p, *shape).to_natural()
    ops.dip_matmul(x, p, ...)       -> api.matmul(x, dip_weight, backend="pallas_dip")
    ops.dip_matmul_systolic(...)    -> api.matmul(..., backend="pallas_systolic")
    ops.ws_matmul(x, w, ...)        -> api.matmul(x, w, backend="ws")

These wrappers keep existing call sites working (raw permutated-storage
arrays in, arrays out) but carry no metadata — new code should hold a
``DipWeight`` and call ``api.matmul``.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro import api
from repro.api import PERM_TILE, DipWeight, default_interpret

__all__ = [
    "PERM_TILE",
    "default_interpret",
    "to_dip_format",
    "from_dip_format",
    "dip_matmul",
    "dip_matmul_systolic",
    "ws_matmul",
]


def to_dip_format(w: jax.Array, perm_tile: int = PERM_TILE) -> jax.Array:
    """DEPRECATED: returns bare permutated storage; prefer
    ``api.DipWeight.from_natural`` which keeps the logical-shape metadata."""
    return DipWeight.from_natural(w, perm_tile).data


def from_dip_format(
    p: jax.Array, shape: Optional[tuple] = None, perm_tile: int = PERM_TILE
) -> jax.Array:
    """DEPRECATED: recover the natural-layout weight (crops if ``shape`` given)."""
    d_in = shape[-2] if shape is not None else p.shape[-2]
    d_out = shape[-1] if shape is not None else p.shape[-1]
    return DipWeight(p, d_in, d_out, perm_tile).to_natural()


def _wrap_storage(x: jax.Array, p: jax.Array, out_features: Optional[int]) -> DipWeight:
    # Bare storage carries no logical d_in, so take it from the activation
    # (the seed semantics: x pads up to the stored K or the call is invalid)
    # and crop the output to ``out_features``.
    return DipWeight(p, x.shape[-1], out_features or p.shape[-1], PERM_TILE)


def dip_matmul(
    x: jax.Array,
    p: jax.Array,
    *,
    out_features: Optional[int] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """DEPRECATED: ``x @ w`` where ``p = to_dip_format(w)``."""
    return api.matmul(
        x, _wrap_storage(x, p, out_features), backend="pallas_dip",
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )


def dip_matmul_systolic(
    x: jax.Array,
    p: jax.Array,
    *,
    out_features: Optional[int] = None,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """DEPRECATED: wavefront-emulation path."""
    return api.matmul(
        x, _wrap_storage(x, p, out_features), backend="pallas_systolic",
        block_m=block_m, interpret=interpret,
    )


def ws_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """DEPRECATED: baseline tiled matmul with natural-layout weights."""
    return api.matmul(
        x, w, backend="ws",
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )
