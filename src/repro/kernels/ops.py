"""Public jit'd entry points for the DiP kernels.

These wrappers make the kernels shape-agnostic (padding to block multiples,
arbitrary leading batch dims), pick interpret mode automatically off-TPU, and
expose the permutated storage format helpers used by the model zoo's
`DipLinear`.

API:
    to_dip_format(w)        -> permutated + padded storage tensor
    dip_matmul(x, p)        -> x @ w  from permutated storage (MXU fast path)
    dip_matmul_systolic(..) -> same, via wavefront emulation (validation path)
    ws_matmul(x, w)         -> baseline tiled matmul (natural layout)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import permute
from repro.kernels import ref
from repro.kernels.dip_matmul import dip_matmul_pallas
from repro.kernels.dip_systolic import dip_systolic_pallas
from repro.kernels.ws_matmul import ws_matmul_pallas

__all__ = [
    "default_interpret",
    "to_dip_format",
    "from_dip_format",
    "dip_matmul",
    "dip_matmul_systolic",
    "ws_matmul",
]

PERM_TILE = 64  # the paper's array dimension


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere (CPU CI)."""
    return jax.default_backend() != "tpu"


def _pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def to_dip_format(w: jax.Array, perm_tile: int = PERM_TILE) -> jax.Array:
    """Convert a (K, N) weight to DiP permutated storage.

    Pads K and N up to ``perm_tile`` multiples (zero rows/cols are inert in
    the matmul) and applies the per-tile permutation.  This is the offline
    software step of paper Fig. 3 — in this framework it happens at parameter
    initialization / checkpoint-load time, never per step.
    """
    w = _pad_dim(_pad_dim(w, -1, perm_tile), -2, perm_tile)
    return permute.permute_tiled(w, perm_tile)


def from_dip_format(
    p: jax.Array, shape: Optional[tuple] = None, perm_tile: int = PERM_TILE
) -> jax.Array:
    """Recover the natural-layout weight (crops padding if ``shape`` given)."""
    w = permute.unpermute_tiled(p, perm_tile)
    if shape is not None:
        w = w[..., : shape[-2], : shape[-1]]
    return w


def _flatten_batch(x: jax.Array):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


# ---- autodiff: Pallas forward, XLA backward -------------------------------
# pallas_call with scratch accumulators has no jvp rule; training through the
# DiP kernels therefore uses a custom VJP whose backward runs plain XLA
# matmuls.  Gradient w.r.t. the *permutated storage* is the permuted gradient
# of the natural weight (the layout map is a permutation, hence linear and
# orthogonal): d/dP f(unperm(P)) = perm(d/dW f(W)).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _pallas_mm(x2, w2, opts):
    kind, block_m, block_n, block_k, interpret = opts
    if kind == "dip":
        return dip_matmul_pallas(
            x2, w2, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )
    if kind == "ws":
        return ws_matmul_pallas(
            x2, w2, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )
    if kind == "systolic":
        return dip_systolic_pallas(x2, w2, block_m=block_m, interpret=interpret)
    raise ValueError(kind)


def _pallas_mm_fwd(x2, w2, opts):
    return _pallas_mm(x2, w2, opts), (x2, w2)


def _pallas_mm_bwd(opts, res, g):
    kind = opts[0]
    x2, w2 = res
    permuted = kind in ("dip", "systolic")
    wn = permute.unpermute_tiled(w2, PERM_TILE) if permuted else w2
    g32 = g.astype(jnp.float32)
    dx = jnp.matmul(g32, wn.astype(jnp.float32).T).astype(x2.dtype)
    dwn = jnp.matmul(x2.astype(jnp.float32).T, g32)
    dw = (permute.permute_tiled(dwn, PERM_TILE) if permuted else dwn).astype(w2.dtype)
    return dx, dw


_pallas_mm.defvjp(_pallas_mm_fwd, _pallas_mm_bwd)


def _matmul_via(kind, x, w, out_cols, block_m, block_n, block_k, interpret):
    """Shared padding/batching shim around a 2-D pallas matmul kernel."""
    if interpret is None:
        interpret = default_interpret()
    x2, lead = _flatten_batch(x)
    m = x2.shape[0]
    block_m = min(block_m, max(8, 1 << (m - 1).bit_length()))  # don't over-block tiny M
    x2 = _pad_dim(_pad_dim(x2, 0, block_m), 1, block_k)
    w2 = _pad_dim(_pad_dim(w, 0, block_k), 1, block_n)
    out = _pallas_mm(x2, w2, (kind, block_m, block_n, block_k, interpret))
    return out[:m, :out_cols].reshape(lead + (out_cols,))


@functools.partial(
    jax.jit,
    static_argnames=("out_features", "block_m", "block_n", "block_k", "interpret"),
)
def dip_matmul(
    x: jax.Array,
    p: jax.Array,
    *,
    out_features: Optional[int] = None,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``x @ w`` where ``p = to_dip_format(w)``; x: (..., K), p: (Kp, Np)."""
    out_features = out_features or p.shape[-1]
    xk = _pad_dim(x, -1, PERM_TILE)  # match the stored padding of K
    if xk.shape[-1] != p.shape[0]:
        raise ValueError(f"x contraction {x.shape[-1]} does not match dip storage {p.shape}")
    return _matmul_via(
        "dip", xk, p, out_features, block_m, block_n, block_k, interpret
    )


@functools.partial(
    jax.jit, static_argnames=("out_features", "block_m", "interpret")
)
def dip_matmul_systolic(
    x: jax.Array,
    p: jax.Array,
    *,
    out_features: Optional[int] = None,
    block_m: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Wavefront-emulation path (validation / dataflow demonstration)."""
    if interpret is None:
        interpret = default_interpret()
    out_features = out_features or p.shape[-1]
    xk = _pad_dim(x, -1, PERM_TILE)
    x2, lead = _flatten_batch(xk)
    m = x2.shape[0]
    block_m = min(block_m, max(8, 1 << (m - 1).bit_length()))
    x2 = _pad_dim(x2, 0, block_m)
    out = _pallas_mm(x2, p, ("systolic", block_m, 0, 0, interpret))
    return out[:m, :out_features].reshape(lead + (out_features,))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def ws_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Baseline tiled matmul with natural-layout weights."""
    return _matmul_via(
        "ws", x, w, w.shape[-1], block_m, block_n, block_k, interpret
    )
