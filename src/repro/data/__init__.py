"""Deterministic synthetic data pipeline (offline container — no corpora)."""

from repro.data.pipeline import DataState, SyntheticLM

__all__ = ["SyntheticLM", "DataState"]
