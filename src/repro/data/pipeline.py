"""Deterministic, shardable, restart-safe synthetic LM data pipeline.

Design goals (the ones that matter at 1000+ nodes):

  * **Stateless addressing** — batch ``i`` is a pure function of
    ``(seed, i)`` via counter-based hashing (threefry, same family as JAX
    PRNG).  Any host can produce any batch shard without coordination, so
    elastic re-sharding and restart-after-failure need only the integer
    ``step`` stored in the checkpoint (see DataState).
  * **Host sharding** — each host materializes only its
    ``global_batch / num_shards`` slice.
  * **Prefetch** — a small background thread keeps ``prefetch`` batches
    ready (overlaps host-side generation with device steps).

The token stream is structured (document lengths ~ geometric, EOS-delimited,
Zipf-ish unigram distribution) so losses behave like a language-modeling
run, not uniform noise.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "DataState"]


@dataclasses.dataclass
class DataState:
    """Checkpointable pipeline position."""

    step: int = 0

    def to_dict(self) -> Dict:
        return {"step": int(self.step)}

    @staticmethod
    def from_dict(d: Dict) -> "DataState":
        return DataState(step=int(d["step"]))


class SyntheticLM:
    def __init__(
        self,
        *,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        shard_index: int = 0,
        num_shards: int = 1,
        emit_embeddings: Optional[int] = None,  # [vlm]/[audio]: d_model or None
        prefetch: int = 2,
    ):
        if global_batch % num_shards:
            raise ValueError("global_batch must divide num_shards")
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard_index
        self.num_shards = num_shards
        self.emit_embeddings = emit_embeddings
        self._prefetch_n = prefetch
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._cursor = 0
        self._stop = threading.Event()

    # --------------------------------------------------------- batch math --
    def _rng_for(self, step: int, row: int) -> np.random.Generator:
        # counter-based: unique stream per (seed, step, global row index)
        gidx = self.shard * self.local_batch + row
        return np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(step, gidx))
        )

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = self._rng_for(step, row)
        out = np.empty(self.seq + 1, np.int32)
        pos = 0
        while pos < self.seq + 1:
            doc_len = int(rng.geometric(1.0 / 384.0))
            # clamp to the remaining room LAST (min-of-max, not max-of-min:
            # the other order overruns the buffer when < 8 slots remain)
            doc_len = min(max(8, doc_len), self.seq + 1 - pos)
            # Zipf-ish unigrams, rejected down into the vocab
            toks = rng.zipf(1.3, size=doc_len).astype(np.int64)
            toks = (toks - 1) % max(2, self.vocab - 2) + 2  # ids 0/1 reserved
            out[pos : pos + doc_len] = toks
            pos += doc_len
            if pos < self.seq + 1:
                out[pos - 1] = 1  # EOS
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """The shard-local batch for global step ``step`` (pure function)."""
        rows = np.stack([self._row(step, r) for r in range(self.local_batch)])
        item = {"tokens": rows[:, : self.seq], "labels": rows[:, : self.seq]}
        if self.emit_embeddings:
            rng = self._rng_for(step, 1 << 30)
            item = {
                "embeddings": rng.standard_normal(
                    (self.local_batch, self.seq, self.emit_embeddings), np.float32
                )
                * 0.02,
                "labels": rows[:, : self.seq],
            }
        return item

    # ----------------------------------------------------------- prefetch --
    def start(self, state: DataState) -> None:
        self._cursor = state.step
        self._queue = queue.Queue(maxsize=self._prefetch_n)
        self._stop.clear()

        def worker():
            s = self._cursor
            while not self._stop.is_set():
                try:
                    item = (s, self.batch(s))
                except Exception as exc:  # surface worker death to the consumer
                    item = ("error", exc)
                while not self._stop.is_set():
                    try:
                        self._queue.put(item, timeout=0.2)
                        break
                    except queue.Full:
                        continue
                if item[0] == "error":
                    return
                s += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
        self._queue = None

    def __iter__(self) -> Iterator:
        if self._queue is None:
            raise RuntimeError("call start(DataState) first")
        while True:
            step, item = self._queue.get()
            if step == "error":
                raise RuntimeError("data pipeline worker failed") from item
            yield step, item
