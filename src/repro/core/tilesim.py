"""Tile-level scheduler: full GEMM workloads on a DiP / WS array (Fig. 6).

The paper evaluates 64x64 DiP vs a TPU-like WS array on transformer MHA/FFN
GEMMs via cycle-accurate simulation with matrix tiling (Sec. IV-C):

  * M2 (weights, N_inner x K) is tiled A x A; every weight tile is loaded once
    and stays stationary ("weight tile stationary").
  * For each weight tile, all T = ceil(M/A) tiles of M1 are streamed through.
  * Per weight tile the array costs its base tile latency for the first input
    tile and A cycles for each subsequent streamed tile (outputs overlap).

Closed form (validated against the register-level simulator in streaming
mode):

    cycles(arch) = W_tiles * [ base(arch) + (T - 1) * A ]        (paper model)
    base(WS)  = 3A + S - 3,   base(DiP) = 2A + S - 2

This reproduces the paper's endpoints exactly: latency ratio 1.492 for a
single-tile workload and 1.030 for T=32 (A=64, S=2); energy ratios 1.81 /
1.25 after multiplying by the Table-I power ratio.

Beyond the paper, the event-driven variant models weight-load cycles and
double-buffered weight loading (the TPU-like optimization of hiding the next
tile's load behind compute), used in the §Perf exploration.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import analytical

__all__ = ["GemmWorkload", "TileSchedule", "schedule_gemm", "simulate_gemm_event"]


@dataclasses.dataclass(frozen=True)
class GemmWorkload:
    """A GEMM of (M x N_inner) @ (N_inner x K) — paper Table III notation."""

    m: int
    n_inner: int
    k: int
    name: str = ""

    @property
    def macs(self) -> int:
        return self.m * self.n_inner * self.k

    @property
    def ops(self) -> int:
        return 2 * self.macs


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """Result of scheduling one GEMM on one array."""

    workload: GemmWorkload
    arch: str                 # "dip" | "ws"
    array_n: int
    stages: int
    weight_tiles: int
    input_tiles_per_weight: int
    cycles: int
    include_weight_load: bool
    double_buffered: bool

    @property
    def utilization(self) -> float:
        """Useful MACs / (PE count * cycles)."""
        return self.workload.macs / (self.array_n**2 * self.cycles)

    def latency_s(self, freq_hz: float = 1e9) -> float:
        return self.cycles / freq_hz

    def energy_j(self, power_w: float, freq_hz: float = 1e9) -> float:
        return self.latency_s(freq_hz) * power_w


def _tiles(x: int, a: int) -> int:
    return max(1, math.ceil(x / a))


def schedule_gemm(
    wl: GemmWorkload,
    arch: str,
    *,
    array_n: int = 64,
    stages: int = 2,
    include_weight_load: bool = False,
    double_buffered: bool = False,
) -> TileSchedule:
    """Closed-form tile schedule (the paper's Fig. 6 cost model).

    ``include_weight_load`` adds the A-cycle weight-load per weight tile
    (DiP overlaps one cycle with the first input row, Fig. 4 Cycle 0).
    ``double_buffered`` hides the load behind the previous tile's compute
    entirely (beyond-paper WS/TPU optimization; first tile still pays).
    """
    if arch not in ("dip", "ws"):
        raise ValueError(arch)
    a = array_n
    w_tiles = _tiles(wl.n_inner, a) * _tiles(wl.k, a)
    t_in = _tiles(wl.m, a)
    base = (
        analytical.dip_latency(a, stages)
        if arch == "dip"
        else analytical.ws_latency(a, stages)
    )
    per_weight_tile = base + (t_in - 1) * a
    cycles = w_tiles * per_weight_tile
    if include_weight_load:
        if double_buffered:
            cycles += a  # only the first load is exposed
        else:
            load = a - 1 if arch == "dip" else a  # DiP overlaps 1 cycle
            cycles += w_tiles * load
    return TileSchedule(
        workload=wl,
        arch=arch,
        array_n=a,
        stages=stages,
        weight_tiles=w_tiles,
        input_tiles_per_weight=t_in,
        cycles=cycles,
        include_weight_load=include_weight_load,
        double_buffered=double_buffered,
    )


def simulate_gemm_event(
    wl: GemmWorkload,
    arch: str,
    *,
    array_n: int = 64,
    stages: int = 2,
    double_buffered: bool = False,
) -> int:
    """Event-driven tile scheduler: steps tile-by-tile through time.

    Models the weight-load/compute dependency explicitly; with
    ``double_buffered=False`` it reproduces ``schedule_gemm(...,
    include_weight_load=True)`` exactly (cross-checked in tests); with
    double buffering the next weight tile loads while the current computes.
    Returns total cycles.
    """
    a = array_n
    w_tiles = _tiles(wl.n_inner, a) * _tiles(wl.k, a)
    t_in = _tiles(wl.m, a)
    base = (
        analytical.dip_latency(a, stages)
        if arch == "dip"
        else analytical.ws_latency(a, stages)
    )
    compute_per_tile = base + (t_in - 1) * a
    load = a - 1 if arch == "dip" else a

    t = 0           # wall-clock cycle
    load_done = 0   # cycle at which the pending weight tile finished loading
    for i in range(w_tiles):
        if double_buffered:
            # tile i's load starts as soon as the buffer frees: at the start
            # of tile i-1's compute (or t=0 for the first tile)
            load_start = 0 if i == 0 else compute_start
            load_done = load_start + load
            compute_start = max(t, load_done)
            t = compute_start + compute_per_tile
        else:
            t += load + compute_per_tile
    return t
