"""22nm hardware design-space model calibrated to the paper's Tables I/II/IV.

The paper implements WS and DiP from RTL to GDSII in commercial 22nm at 1 GHz
and reports area + power per array size (Table I).  We cannot re-run a
silicon flow here, so the published numbers are the calibration points of
this model; everything derived from them (improvement ratios, TOPS, TOPS/W,
workload energy in Fig. 6) is *computed*, and the computed values are
validated against the paper's own derived claims (Table II ratios, Table IV
peak numbers, Fig. 6 endpoints) in tests/benchmarks.

Between calibration points, area and power are interpolated with a
quadratic-in-N fit (PE count scales with N^2, FIFO registers with N(N-1)),
which recovers every calibration point exactly at the measured sizes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Literal

from repro.core import analytical

__all__ = [
    "HardwarePoint",
    "TABLE_I",
    "hardware_point",
    "peak_tops",
    "energy_efficiency_tops_per_w",
    "table_ii_improvements",
    "workload_energy_j",
]

Arch = Literal["ws", "dip"]


@dataclasses.dataclass(frozen=True)
class HardwarePoint:
    """One (arch, size) implementation point at 22nm / 1 GHz."""

    arch: Arch
    n: int
    area_um2: float
    power_mw: float
    freq_hz: float = 1e9

    @property
    def power_w(self) -> float:
        return self.power_mw * 1e-3

    @property
    def area_mm2(self) -> float:
        return self.area_um2 * 1e-6


# Table I — commercial 22nm @ 1 GHz (area in um^2, power in mW).
TABLE_I: Dict[Arch, Dict[int, HardwarePoint]] = {
    "ws": {
        4: HardwarePoint("ws", 4, 5_178, 4.168),
        8: HardwarePoint("ws", 8, 18_703, 16.2),
        16: HardwarePoint("ws", 16, 71_204, 64.28),
        32: HardwarePoint("ws", 32, 275_000, 264.2),
        64: HardwarePoint("ws", 64, 1_085_000, 1041.0),
    },
    "dip": {
        4: HardwarePoint("dip", 4, 4_872, 3.582),
        8: HardwarePoint("dip", 8, 17_376, 13.72),
        16: HardwarePoint("dip", 16, 65_421, 53.63),
        32: HardwarePoint("dip", 32, 253_000, 211.5),
        64: HardwarePoint("dip", 64, 1_012_000, 857.8),
    },
}


def hardware_point(arch: Arch, n: int) -> HardwarePoint:
    """Calibrated point if measured; otherwise per-PE quadratic interpolation."""
    table = TABLE_I[arch]
    if n in table:
        return table[n]
    # Fit a + b*N + c*N^2 through the three nearest calibration sizes.
    sizes = sorted(table)
    lo = max(s for s in sizes if s <= n) if any(s <= n for s in sizes) else sizes[0]
    idx = sizes.index(lo)
    pts = sizes[max(0, idx - 1): max(0, idx - 1) + 3]
    if len(pts) < 3:
        pts = sizes[-3:]

    def quad_fit(vals):
        import numpy as np

        a = np.vander(np.array(pts, dtype=float), 3)
        coef = np.linalg.solve(a, np.array(vals, dtype=float))
        return float(np.polyval(coef, n))

    area = quad_fit([table[p].area_um2 for p in pts])
    power = quad_fit([table[p].power_mw for p in pts])
    return HardwarePoint(arch, n, area, power)


def peak_tops(n: int = 64, freq_hz: float = 1e9) -> float:
    """Peak INT8 performance: 2 ops/MAC * N^2 MACs * f.  64x64@1GHz = 8.2 TOPS."""
    return 2 * n * n * freq_hz / 1e12


def energy_efficiency_tops_per_w(arch: Arch = "dip", n: int = 64) -> float:
    """Table IV: peak TOPS / W.  DiP 64x64 -> 9.55 TOPS/W."""
    hp = hardware_point(arch, n)
    return peak_tops(n, hp.freq_hz) / hp.power_w


@dataclasses.dataclass(frozen=True)
class Improvements:
    n: int
    throughput: float
    power: float
    area: float

    @property
    def overall(self) -> float:
        """Table II 'overall improvement' = energy efficiency per area
        = throughput x power x area ratios."""
        return self.throughput * self.power * self.area


def table_ii_improvements(n: int, s: int = 2) -> Improvements:
    """DiP-over-WS improvement ratios at one size (reproduces Table II)."""
    thr = analytical.dip_throughput(n, s) / analytical.ws_throughput(n, s)
    ws_hp, dip_hp = hardware_point("ws", n), hardware_point("dip", n)
    return Improvements(
        n=n,
        throughput=thr,
        power=ws_hp.power_mw / dip_hp.power_mw,
        area=ws_hp.area_um2 / dip_hp.area_um2,
    )


def workload_energy_j(cycles: int, arch: Arch, n: int = 64) -> float:
    """Energy of a workload = cycles * clock period * average power."""
    hp = hardware_point(arch, n)
    return cycles / hp.freq_hz * hp.power_w


def deepscale_normalize(value: float, from_nm: int, to_nm: int = 22, kind: str = "power") -> float:
    """Crude DeepScaleTool-style technology normalization (Table IV footnote).

    Dennard-style scaling: area ~ s^2, power ~ s (activity-dominated).  Only
    used to contextualize the Table IV cross-accelerator comparison; the
    paper used the actual DeepScaleTool [40].
    """
    s = from_nm / to_nm
    if kind == "area":
        return value / (s * s)
    if kind == "power":
        return value / s
    raise ValueError(kind)
