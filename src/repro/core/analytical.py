"""Analytical models for WS and DiP systolic arrays — paper eqs. (1)-(7).

All models are validated cycle-for-cycle against the register-level simulators
in :mod:`repro.core.simulator` (see tests/test_core_analytical.py), and
extended beyond the paper to the streaming regime (M input rows through an
NxN array) used by the tile-level scheduler.

Paper equations (N = array dim, S = MAC pipeline stages):

    (1) WS latency            = 3N + S - 3
    (2) WS throughput         = 2N^3 / (3N + S - 3)
    (3) WS register overhead  = N(N-1)           [sync-FIFO register count]
    (4) WS TFPU               = 2N - 1
    (5) DiP latency           = 2N + S - 2
    (6) DiP throughput        = 2N^3 / (2N + S - 2)
    (7) DiP TFPU              = N
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "ws_latency",
    "dip_latency",
    "ws_throughput",
    "dip_throughput",
    "ws_tfpu",
    "dip_tfpu",
    "ws_fifo_registers",
    "ws_fifo_registers_normalized",
    "pe_internal_registers_normalized",
    "register_savings_fraction",
    "ws_streaming_latency",
    "dip_streaming_latency",
    "ArrayComparison",
    "compare",
]


# ---------------------------------------------------------------- latency ---
def ws_latency(n: int, s: int = 2) -> int:
    """Eq. (1): cycles to push an NxN input tile through the WS array."""
    return 3 * n + s - 3


def dip_latency(n: int, s: int = 2) -> int:
    """Eq. (5): cycles to push an NxN input tile through the DiP array."""
    return 2 * n + s - 2


def ws_streaming_latency(n: int, m: int, s: int = 2) -> int:
    """Streaming extension: M input rows (M >= 1) through the WS array.

    One extra cycle per input row beyond the first N (simulator-validated).
    """
    return ws_latency(n, s) + max(0, m - n)


def dip_streaming_latency(n: int, m: int, s: int = 2) -> int:
    return dip_latency(n, s) + max(0, m - n)


# ------------------------------------------------------------- throughput ---
def ws_throughput(n: int, s: int = 2) -> float:
    """Eq. (2): ops/cycle (multiplications + additions) for one NxN tile."""
    return 2.0 * n**3 / ws_latency(n, s)


def dip_throughput(n: int, s: int = 2) -> float:
    """Eq. (6)."""
    return 2.0 * n**3 / dip_latency(n, s)


# ------------------------------------------------------------------ TFPU ----
def ws_tfpu(n: int) -> int:
    """Eq. (4): cycles until every PE holds live input (diagonal wavefront)."""
    return 2 * n - 1


def dip_tfpu(n: int) -> int:
    """Eq. (7): DiP fills row-by-row — N cycles."""
    return n


# -------------------------------------------------------------- registers ---
def ws_fifo_registers(n: int) -> int:
    """Eq. (3): raw count of sync-FIFO registers (input group + output group).

    Each group is N-1 FIFOs of depths 1..N-1 -> N(N-1)/2 registers per group.
    """
    return n * (n - 1)


def ws_fifo_registers_normalized(n: int, *, in_bits: int = 8, out_bits: int = 16) -> float:
    """FIFO registers normalized to 8-bit units (paper Fig. 5c normalization).

    Input FIFOs hold ``in_bits`` values, output FIFOs hold ``out_bits`` psums.
    """
    group = n * (n - 1) / 2
    return group * (in_bits / 8.0) + group * (out_bits / 8.0)


def pe_internal_registers_normalized(
    n: int, *, w_bits: int = 8, x_bits: int = 8, mul_bits: int = 16, add_bits: int = 16
) -> float:
    """Internal PE registers (weight, input, multiplier, adder — Fig. 2b),
    normalized to 8-bit units."""
    per_pe = (w_bits + x_bits + mul_bits + add_bits) / 8.0
    return n * n * per_pe


def register_savings_fraction(n: int) -> float:
    """Fraction of total WS registers eliminated by DiP (byte-normalized).

    DiP keeps only the internal PE registers; WS adds both FIFO groups.
    Reaches ~19.8% at N=64 (paper: "up to 20%").
    """
    fifo = ws_fifo_registers_normalized(n)
    pe = pe_internal_registers_normalized(n)
    return fifo / (fifo + pe)


# ------------------------------------------------------------- comparison ---
@dataclasses.dataclass(frozen=True)
class ArrayComparison:
    n: int
    s: int
    ws_latency: int
    dip_latency: int
    latency_saving: float          # (WS - DiP) / WS
    ws_throughput: float
    dip_throughput: float
    throughput_improvement: float  # DiP / WS
    ws_tfpu: int
    dip_tfpu: int
    tfpu_improvement: float        # (WS - DiP) / WS
    ws_registers_norm: float
    dip_registers_norm: float
    register_saving: float


def compare(n: int, s: int = 2) -> ArrayComparison:
    """Full WS-vs-DiP analytical comparison at one array size (Fig. 5 row)."""
    wl, dl = ws_latency(n, s), dip_latency(n, s)
    wt, dt = ws_throughput(n, s), dip_throughput(n, s)
    wf, df = ws_tfpu(n), dip_tfpu(n)
    pe = pe_internal_registers_normalized(n)
    fifo = ws_fifo_registers_normalized(n)
    return ArrayComparison(
        n=n,
        s=s,
        ws_latency=wl,
        dip_latency=dl,
        latency_saving=(wl - dl) / wl,
        ws_throughput=wt,
        dip_throughput=dt,
        throughput_improvement=dt / wt,
        ws_tfpu=wf,
        dip_tfpu=df,
        tfpu_improvement=(wf - df) / wf,
        ws_registers_norm=pe + fifo,
        dip_registers_norm=pe,
        register_saving=fifo / (pe + fifo),
    )
