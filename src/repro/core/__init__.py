"""DiP core: the paper's contribution as a composable library.

Layers:
  permute     — the DiP weight permutation (Fig. 3) and tiled variants
  dataflow    — functional semantics (rolled-MAC identity) for DiP and WS
  simulator   — cycle-accurate register-level array simulators
  analytical  — eqs. (1)-(7): latency / throughput / TFPU / registers
  tilesim     — tile-level GEMM scheduler (Fig. 6 cost model)
  energy      — 22nm DSE model calibrated to Tables I/II/IV
  workloads   — transformer MHA/FFN GEMM workloads (Table III)
"""

from repro.core import analytical, dataflow, energy, permute, simulator, tilesim, workloads

__all__ = [
    "analytical",
    "dataflow",
    "energy",
    "permute",
    "simulator",
    "tilesim",
    "workloads",
]
