"""Functional semantics of the DiP and WS dataflows.

These are the *mathematical* specifications that every other layer (the
cycle-accurate simulator, the Pallas kernels, the model-zoo `DipLinear`) is
tested against.

Key identity (paper Sec. III-B, proved by the Fig. 4 walk-through):
with the weight matrix permutated as ``P[r][i] = W[(r+i) mod K][i]`` and the
input row rotated left by ``r`` when it reaches PE row ``r``::

    out[m, i] = sum_r  x[m, (i+r) mod K] * P[r, i]
              = sum_k  x[m, k] * W[k, i]
              = (x @ W)[m, i]

so DiP computes exactly ``x @ W`` while the array consumes the *permutated*
layout with diagonally-moving inputs and zero synchronization FIFOs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import permute

__all__ = [
    "ws_matmul",
    "dip_matmul_from_permuted",
    "dip_matmul_rolled",
    "dip_matmul_rolled_np",
]


def ws_matmul(x: jax.Array, w: jax.Array, *, precision=None) -> jax.Array:
    """Weight-stationary semantics: a plain matmul (the TPU-like baseline)."""
    return jnp.matmul(x, w, precision=precision)


def dip_matmul_from_permuted(x: jax.Array, p: jax.Array, *, precision=None) -> jax.Array:
    """Fast-path semantics: de-shear the permutated weights, then one matmul.

    This is what the TPU-native Pallas kernel does per VMEM tile: the de-shear
    is O(K*N) gather work amortized against O(M*K*N) MXU work.
    """
    return jnp.matmul(x, permute.unpermute_weights(p), precision=precision)


def dip_matmul_rolled(x: jax.Array, p: jax.Array) -> jax.Array:
    """Systolic-faithful semantics: sum of rolled-input MACs.

    Computes ``out[m, i] = sum_r x[m, (i+r) % K] * p[r, i]`` by materializing
    the diagonal input movement: PE row ``r`` sees the input row rotated left
    by ``r`` and multiplies it elementwise with its stationary (permutated)
    weights.  O(K) vector MACs — exactly the work the physical array performs,
    one PE row per term.  K (rows of p) must equal the contraction dim of x.
    """
    k = p.shape[0]
    if x.shape[-1] != k:
        raise ValueError(f"contraction mismatch: x has {x.shape[-1]}, p has {k} rows")

    def body(r, acc):
        # input rotated left by r, broadcast against PE row r's weights
        xr = jnp.roll(x, -r, axis=-1)
        return acc + xr * p[r][None, :]

    acc0 = jnp.zeros(x.shape[:-1] + (p.shape[1],), dtype=jnp.result_type(x, p))
    if p.shape[1] != k:
        # Rectangular tile: rotation is modulo K (rows); weights column-count C
        # may differ. Roll over K then take the first C lanes of each rotation.
        def body_rect(r, acc):
            xr = jnp.roll(x, -r, axis=-1)[..., : p.shape[1]]
            return acc + xr * p[r][None, :]

        # Rectangular DiP tiles require C == K for the wrap-around to close;
        # the physical array is NxN so this path only supports square tiles.
        raise ValueError("dip_matmul_rolled requires square tiles (array is NxN)")
    return jax.lax.fori_loop(0, k, body, acc0)


def dip_matmul_rolled_np(x: np.ndarray, p: np.ndarray) -> np.ndarray:
    """Literal numpy transcription (oracle for the oracle)."""
    m, k = x.shape
    k2, n = p.shape
    assert k == k2 == n, "square tiles only"
    out = np.zeros((m, n), dtype=np.result_type(x, p))
    for r in range(k):
        xr = np.roll(x, -r, axis=1)
        out += xr * p[r][None, :]
    return out
