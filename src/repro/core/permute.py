"""DiP weight permutation (paper Fig. 3) and its inverse.

The DiP dataflow stores the weight matrix *permutated*: each column ``i`` is
rotated **up** by ``i`` positions (wrap-around)::

    P[j][i] = W[(j + i) mod R][i]          (R = number of rows)

The permutation is a pure relayout performed offline in software ("at almost
zero cost" — paper Sec. III-B); the systolic array then consumes inputs moving
diagonally with no synchronization FIFOs.  In this framework the permutated
layout is a first-class storage format (`DipFormat`): checkpoints and HBM
tensors may hold weights permutated, and the matmul kernels either de-shear in
VMEM (fast path) or consume the layout natively (systolic-faithful path).

Everything here is shape-polymorphic: the paper defines the permutation for an
NxN array tile; we extend it to arbitrary (R, C) matrices (rotation modulo R)
and to *tiled* application, where each (tile_r x tile_c) block of a large
matrix is permutated independently — exactly what a 64x64 DiP array would see
after matrix tiling (paper Sec. IV-C).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "permutation_indices",
    "permute_weights",
    "unpermute_weights",
    "permute_weights_np",
    "unpermute_weights_np",
    "permute_tiled",
    "unpermute_tiled",
    "rotate_rows_left",
]


def permutation_indices(rows: int, cols: int) -> np.ndarray:
    """Static gather indices implementing ``P[j][i] = W[(j+i) % rows][i]``.

    Returns an int32 array ``idx`` of shape (rows, cols) such that
    ``P = W[idx, col_iota]``.  Kept in numpy so callers can bake it into a
    jitted computation as a compile-time constant.
    """
    j = np.arange(rows)[:, None]
    i = np.arange(cols)[None, :]
    return ((j + i) % rows).astype(np.int32)


def inverse_permutation_indices(rows: int, cols: int) -> np.ndarray:
    """Indices for the inverse map ``W[k][i] = P[(k - i) % rows][i]``."""
    k = np.arange(rows)[:, None]
    i = np.arange(cols)[None, :]
    return ((k - i) % rows).astype(np.int32)


def _apply_row_gather(w: jax.Array, idx: np.ndarray) -> jax.Array:
    cols = np.broadcast_to(np.arange(w.shape[-1]), idx.shape)
    if w.ndim == 2:
        return w[idx, cols]
    # Batched (leading dims untouched): vmap over leading axes.
    flat = w.reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda m: m[idx, cols])(flat)
    return out.reshape(w.shape)


def permute_weights(w: jax.Array) -> jax.Array:
    """DiP-permute the trailing two dims of ``w`` (paper Fig. 3 pseudocode)."""
    rows, cols = w.shape[-2], w.shape[-1]
    return _apply_row_gather(w, permutation_indices(rows, cols))


def unpermute_weights(p: jax.Array) -> jax.Array:
    """Inverse of :func:`permute_weights`."""
    rows, cols = p.shape[-2], p.shape[-1]
    return _apply_row_gather(p, inverse_permutation_indices(rows, cols))


def permute_weights_np(w: np.ndarray) -> np.ndarray:
    """Pure-numpy reference, the literal transcription of the paper's pseudocode."""
    rows, cols = w.shape
    out = np.empty_like(w)
    for i in range(cols):
        for j in range(rows):
            out[j][i] = w[(j + i) % rows][i]
    return out


def unpermute_weights_np(p: np.ndarray) -> np.ndarray:
    rows, cols = p.shape
    out = np.empty_like(p)
    for i in range(cols):
        for k in range(rows):
            out[k][i] = p[(k - i) % rows][i]
    return out


def _pad_to_multiple(w: jax.Array, tile_r: int, tile_c: int) -> jax.Array:
    r, c = w.shape[-2], w.shape[-1]
    pr = (-r) % tile_r
    pc = (-c) % tile_c
    if pr == 0 and pc == 0:
        return w
    pad = [(0, 0)] * (w.ndim - 2) + [(0, pr), (0, pc)]
    return jnp.pad(w, pad)


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_c", "inverse"))
def _permute_tiled_impl(w: jax.Array, tile_r: int, tile_c: int, inverse: bool) -> jax.Array:
    r, c = w.shape[-2], w.shape[-1]
    wp = _pad_to_multiple(w, tile_r, tile_c)
    rp, cp = wp.shape[-2], wp.shape[-1]
    lead = wp.shape[:-2]
    # (..., Rt, tile_r, Ct, tile_c) -> (..., Rt, Ct, tile_r, tile_c)
    blk = wp.reshape(lead + (rp // tile_r, tile_r, cp // tile_c, tile_c))
    blk = jnp.swapaxes(blk, -3, -2)
    idx = (
        inverse_permutation_indices(tile_r, tile_c)
        if inverse
        else permutation_indices(tile_r, tile_c)
    )
    cols = np.broadcast_to(np.arange(tile_c), idx.shape)
    blk = blk[..., idx, cols]
    blk = jnp.swapaxes(blk, -3, -2)
    # NOTE: the result stays PADDED to the tile grid — cropping would drop
    # elements the per-tile rotation moved into the padding rows, making the
    # transform lossy for unaligned shapes (callers crop after unpermuting;
    # see api.DipWeight.to_natural).
    return blk.reshape(lead + (rp, cp))


def permute_tiled(w: jax.Array, tile: int = 64) -> jax.Array:
    """Permute each ``tile x tile`` block independently (matrix-tiling regime).

    This is the layout a 64x64 DiP array consumes when a large weight matrix
    is processed tile-by-tile (paper Sec. IV-C).  Ragged edges are
    zero-padded up to the tile grid and the PADDED tensor is returned (the
    storage format); ``unpermute_tiled(permute_tiled(w))[..., :r, :c] == w``.
    """
    return _permute_tiled_impl(w, tile, tile, False)


def unpermute_tiled(p: jax.Array, tile: int = 64) -> jax.Array:
    return _permute_tiled_impl(p, tile, tile, True)


def rotate_rows_left(x: jax.Array, shift: int) -> jax.Array:
    """Rotate the trailing axis left by ``shift`` (diagonal input movement).

    In the DiP array, an input row hops from PE row ``r`` to PE row ``r+1``
    rotated left by one: the leftmost PE column feeds the rightmost PE column
    of the next row (paper Fig. 2a / Fig. 4a).
    """
    return jnp.roll(x, -shift, axis=-1)
