"""Cycle-accurate register-level simulators for the DiP and WS systolic arrays.

Both simulators are *synchronous register-transfer* models: every cycle, all
registers update simultaneously from the previous cycle's values.  They
produce numerically exact matmul outputs **and** per-cycle traces (inputs fed,
outputs emitted, active PE rows), so the paper's analytical equations
(1)-(7) are *measured*, not assumed:

    WS  latency = 3N + S - 3        DiP latency = 2N + S - 2      (M = N rows)
    WS  TFPU    = 2N - 1            DiP TFPU    = N
    WS  sync-FIFO registers = N(N-1) (raw count; 1.5*N(N-1) byte-normalized)

Pipeline-stage convention (S):
  S=2 — the paper's PE (Fig. 2b): input/weight registers feed a multiplier
        register and an adder register; at array level the psum advances one
        PE row per cycle, one cycle behind the input wavefront.  Matches the
        Fig. 4 walk-through exactly (first output row at cycle N, 0-indexed
        from the first input load at cycle 0).
  S=1 — single-register PE: MAC is combinational after the input register.

Timing is validated against the Fig. 4 example in tests (first output cycle 3,
last cycle 5 for N=3, S=2).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import permute

__all__ = ["SimResult", "simulate_dip", "simulate_ws", "simulate_weight_load_dip"]


@dataclasses.dataclass
class SimResult:
    """Trace of one systolic-array run (processing phase only).

    Attributes:
      output:        (M, N) result matrix, numerically exact.
      latency:       total processing cycles (first input load .. last output).
      first_output_cycle: 0-indexed cycle at which output row 0 is registered.
      tfpu:          cycles until every PE row is simultaneously active
                     (None when M < N — the array never fills).
      active_rows:   per-cycle count of PE rows doing useful MACs.
      weight_load_cycles: cycles spent loading weights (N-1 exclusive + 1
                     overlapped with the first input row, per Fig. 4).
      mac_count:     total useful MAC operations executed (= M*N*N).
    """

    output: np.ndarray
    latency: int
    first_output_cycle: int
    tfpu: Optional[int]
    active_rows: List[int]
    weight_load_cycles: int
    mac_count: int

    @property
    def throughput_ops_per_cycle(self) -> float:
        # ops = multiplications + additions (paper counts both): 2*M*N*N
        return 2.0 * self.mac_count / self.latency

    @property
    def mean_utilization(self) -> float:
        n_rows = max(self.active_rows) if self.active_rows else 1
        return float(np.mean(self.active_rows)) / n_rows if self.active_rows else 0.0


def simulate_weight_load_dip(w: np.ndarray) -> np.ndarray:
    """Simulate the weight-loading phase: permutated rows shift down the array.

    Rows of the permutated matrix are pushed bottom-row-first through the top
    (Fig. 4, cycles -2..0); after N shift cycles PE row r holds P[r, :].
    Returns the resident weight array (== permute_weights_np(w)).
    """
    p = permute.permute_weights_np(np.asarray(w))
    n = p.shape[0]
    resident = np.zeros_like(p)
    for cycle in range(n):  # one row pushed per cycle, everything shifts down
        resident[1:] = resident[:-1]
        resident[0] = p[n - 1 - cycle]
    return resident


def simulate_dip(
    x: np.ndarray,
    w: np.ndarray,
    *,
    stages: int = 2,
    weights_prepermuted: bool = False,
) -> SimResult:
    """Run the DiP array on ``x @ w`` with an M-row input stream.

    ``x``: (M, N) input matrix, ``w``: (N, N) weights (un-permutated unless
    ``weights_prepermuted``).  Returns exact outputs plus the cycle trace.
    """
    x = np.asarray(x)
    w = np.asarray(w)
    m_rows, n = x.shape
    if w.shape != (n, n):
        raise ValueError(f"DiP array is NxN; got weights {w.shape} for N={n}")
    if stages not in (1, 2):
        raise ValueError("stages (S) must be 1 or 2")

    p = np.asarray(w) if weights_prepermuted else permute.permute_weights_np(w)
    acc_dtype = np.result_type(x.dtype, w.dtype, np.int64 if x.dtype.kind in "iu" else np.float64)

    # Registers
    x_reg = np.zeros((n, n), dtype=x.dtype)          # X[r]: input vector at PE row r
    x_valid = np.zeros(n, dtype=bool)
    ps_reg = np.zeros((n, n), dtype=acc_dtype)       # PS[r]: psum vector leaving row r
    ps_row_id = -np.ones(n, dtype=np.int64)          # which input row each psum belongs to

    outputs = np.zeros((m_rows, n), dtype=acc_dtype)
    emitted = 0
    first_output_cycle = -1
    tfpu = None
    active_rows: List[int] = []

    t = 0
    max_cycles = 2 * (m_rows + 2 * n + stages + 4)
    while emitted < m_rows and t < max_cycles:
        # ---- next-state computation from current registers ----
        new_x = np.empty_like(x_reg)
        new_xv = np.empty_like(x_valid)
        if t < m_rows:
            new_x[0] = x[t]
            new_xv[0] = True
        else:
            new_x[0] = 0
            new_xv[0] = False
        # diagonal movement: row r-1's registered input, rotated left by one
        new_x[1:] = np.roll(x_reg[:-1], -1, axis=1)
        new_xv[1:] = x_valid[:-1]

        # MAC source: S=2 uses the previous-cycle input register (pipelined);
        # S=1 uses the freshly-written register (combinational MAC after it).
        mac_x, mac_v = (x_reg, x_valid) if stages == 2 else (new_x, new_xv)

        new_ps = np.zeros_like(ps_reg)
        new_ps_id = -np.ones_like(ps_row_id)
        for r in range(n):
            if not mac_v[r]:
                continue
            contrib = mac_x[r].astype(acc_dtype) * p[r].astype(acc_dtype)
            if r == 0:
                new_ps[r] = contrib
                # row 0 stamps the input-row index it just consumed
                new_ps_id[r] = t if stages == 1 else t - 1
            else:
                new_ps[r] = contrib + ps_reg[r - 1]
                new_ps_id[r] = ps_row_id[r - 1]
        # Utilization is counted on input-register validity (the paper's TFPU
        # definition: cycles until every PE holds live input), independent of S.
        active = int(new_xv.sum())
        active_rows.append(active)
        if tfpu is None and active == n:
            tfpu = t + 1  # cycles elapsed including this one

        # ---- commit ----
        x_reg, x_valid, ps_reg = new_x, new_xv, new_ps
        old_ps_id = ps_row_id
        ps_row_id = new_ps_id

        # bottom-row psum register now holds a finished output row
        if ps_row_id[n - 1] >= 0:
            row_id = int(ps_row_id[n - 1])
            outputs[row_id] = ps_reg[n - 1]
            emitted += 1
            if first_output_cycle < 0:
                first_output_cycle = t
        del old_ps_id
        t += 1

    if emitted != m_rows:
        raise RuntimeError("simulator did not converge — timing bug")

    return SimResult(
        output=outputs,
        latency=t,
        first_output_cycle=first_output_cycle,
        tfpu=tfpu if m_rows >= n else None,
        active_rows=active_rows,
        weight_load_cycles=n,  # N-1 exclusive + 1 overlapped with first input
        mac_count=m_rows * n * n,
    )


def simulate_ws(
    x: np.ndarray,
    w: np.ndarray,
    *,
    stages: int = 2,
) -> SimResult:
    """Run the conventional WS array (TPU-like) with input/output sync FIFOs.

    Input FIFO on row k has depth k (skew); output FIFO on column i has depth
    N-1-i (de-skew).  PE(k, i) holds W[k, i]; inputs stream left-to-right,
    psums accumulate top-to-bottom.
    """
    x = np.asarray(x)
    w = np.asarray(w)
    m_rows, n = x.shape
    if w.shape != (n, n):
        raise ValueError(f"WS array is NxN; got weights {w.shape} for N={n}")
    if stages not in (1, 2):
        raise ValueError("stages (S) must be 1 or 2")

    acc_dtype = np.result_type(x.dtype, w.dtype, np.int64 if x.dtype.kind in "iu" else np.float64)

    x_reg = np.zeros((n, n), dtype=x.dtype)        # xreg[k][i]
    x_valid = np.zeros((n, n), dtype=bool)
    ps_reg = np.zeros((n, n), dtype=acc_dtype)     # ps[k][i]
    ps_id = -np.ones((n, n), dtype=np.int64)       # input-row id carried by psum

    # Output de-skew FIFOs: column i delays its column-stream by (n-1-i).
    out_fifo = [[(-1, 0)] * (n - 1 - i) for i in range(n)]

    outputs = np.zeros((m_rows, n), dtype=acc_dtype)
    out_seen = np.zeros((m_rows, n), dtype=bool)
    emitted_rows = 0
    first_output_cycle = -1
    tfpu = None
    active_rows: List[int] = []

    t = 0
    max_cycles = 2 * (m_rows + 3 * n + stages + 4)
    while emitted_rows < m_rows and t < max_cycles:
        new_x = np.empty_like(x_reg)
        new_xv = np.zeros_like(x_valid)
        for k in range(n):
            m = t - k  # input skew FIFO of depth k on row k
            if 0 <= m < m_rows:
                new_x[k, 0] = x[m, k]
                new_xv[k, 0] = True
            else:
                new_x[k, 0] = 0
        new_x[:, 1:] = x_reg[:, :-1]
        new_xv[:, 1:] = x_valid[:, :-1]

        mac_x, mac_v = (x_reg, x_valid) if stages == 2 else (new_x, new_xv)

        contrib = np.where(mac_v, mac_x.astype(acc_dtype) * w.astype(acc_dtype), 0)
        new_ps = np.zeros_like(ps_reg)
        new_ps_id = -np.ones_like(ps_id)
        # row 0 stamps the input-row id: x[m, 0] enters PE(0, i) at cycle m + i
        base = t if stages == 1 else t - 1
        new_ps[0] = contrib[0]
        new_ps_id[0] = np.where(mac_v[0], base - np.arange(n), -1)
        new_ps[1:] = np.where(mac_v[1:], contrib[1:] + ps_reg[:-1], 0)
        new_ps_id[1:] = np.where(mac_v[1:], ps_id[:-1], -1)
        # active PEs this cycle, counted on input validity (paper's TFPU def.)
        active = int(new_xv.sum())
        active_rows.append(active)
        if tfpu is None and active == n * n:
            tfpu = t + 1

        x_reg, x_valid, ps_reg, ps_id = new_x, new_xv, new_ps, new_ps_id

        # bottom-row psums enter the per-column output FIFOs
        for i in range(n):
            item = (int(ps_id[n - 1, i]), ps_reg[n - 1, i]) if ps_id[n - 1, i] >= 0 else (-1, 0)
            out_fifo[i].append(item)
            row_id, val = out_fifo[i].pop(0)
            if row_id >= 0:
                outputs[row_id, i] = val
                out_seen[row_id, i] = True
                if out_seen[row_id].all():
                    emitted_rows += 1
                    if first_output_cycle < 0:
                        first_output_cycle = t
        t += 1

    if emitted_rows != m_rows:
        raise RuntimeError("WS simulator did not converge — timing bug")

    return SimResult(
        output=outputs,
        latency=t,
        first_output_cycle=first_output_cycle,
        tfpu=tfpu if m_rows >= n else None,
        active_rows=active_rows,
        weight_load_cycles=n,
        mac_count=m_rows * n * n,
    )
