"""Transformer MHA/FFN GEMM workloads — paper Table III & Sec. IV-B/C.

Table III decomposes transformer inference into six GEMM stages; the paper
evaluates nine models (Encoder-Decoder: Vanilla/T5/BART; Encoder-only:
BERT/ALBERT/Transformer-XL; Decoder-only: GPT-2/GPT-3/LLaMA) over sequence
lengths 64..2048, d_model in (512, 768, 1024, 1280, 5120), d_k in (64, 128),
d_ffn in (2048, 3072, 4096, 5120).

The exact per-model hyper-parameters are standard; where a family's true FFN
size exceeds the paper's stated d_ffn grid (GPT-3 13B and LLaMA-13B use
20480/13824), we follow the paper's grid cap of 5120 and note it here.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

from repro.core.tilesim import GemmWorkload

__all__ = [
    "ModelPreset",
    "PAPER_MODELS",
    "PAPER_SEQ_LENS",
    "mha_workloads",
    "ffn_workloads",
    "model_workloads",
    "paper_workload_grid",
]


@dataclasses.dataclass(frozen=True)
class ModelPreset:
    name: str
    kind: str          # encoder-decoder | encoder-only | decoder-only
    d_model: int
    n_heads: int
    d_k: int
    d_ffn: int


# Nine models spanning SLMs to LLMs (paper Sec. IV-C), hyper-parameters drawn
# from the paper's stated grids.
PAPER_MODELS: Dict[str, ModelPreset] = {
    "vanilla": ModelPreset("vanilla", "encoder-decoder", 512, 8, 64, 2048),
    "t5_base": ModelPreset("t5_base", "encoder-decoder", 768, 12, 64, 3072),
    "bart_large": ModelPreset("bart_large", "encoder-decoder", 1024, 16, 64, 4096),
    "bert_base": ModelPreset("bert_base", "encoder-only", 768, 12, 64, 3072),
    "albert_base": ModelPreset("albert_base", "encoder-only", 768, 12, 64, 3072),
    "transformer_xl": ModelPreset("transformer_xl", "encoder-only", 1024, 16, 64, 4096),
    "gpt2_large": ModelPreset("gpt2_large", "decoder-only", 1280, 20, 64, 5120),
    "gpt3_13b": ModelPreset("gpt3_13b", "decoder-only", 5120, 40, 128, 5120),
    "llama_13b": ModelPreset("llama_13b", "decoder-only", 5120, 40, 128, 5120),
}

PAPER_SEQ_LENS: Tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)


def mha_workloads(seq: int, d_model: int, d_k: int) -> List[GemmWorkload]:
    """Table III MHA rows: per-head projections + scores + context + out-proj."""
    return [
        GemmWorkload(seq, d_model, d_k, name=f"mha_qkv_proj_l{seq}_dm{d_model}_dk{d_k}"),
        GemmWorkload(seq, d_k, seq, name=f"mha_scores_l{seq}_dk{d_k}"),
        GemmWorkload(seq, seq, d_k, name=f"mha_attnv_l{seq}_dk{d_k}"),
        GemmWorkload(seq, d_model, d_model, name=f"mha_out_proj_l{seq}_dm{d_model}"),
    ]


def ffn_workloads(seq: int, d_model: int, d_ffn: int) -> List[GemmWorkload]:
    """Table III FFN rows: W1 and W2 projections."""
    return [
        GemmWorkload(seq, d_model, d_ffn, name=f"ffn_w1_l{seq}_dm{d_model}_dff{d_ffn}"),
        GemmWorkload(seq, d_ffn, d_model, name=f"ffn_w2_l{seq}_dm{d_model}_dff{d_ffn}"),
    ]


def model_workloads(preset: ModelPreset, seq: int) -> List[GemmWorkload]:
    return mha_workloads(seq, preset.d_model, preset.d_k) + ffn_workloads(
        seq, preset.d_model, preset.d_ffn
    )


def paper_workload_grid() -> Iterator[Tuple[str, int, GemmWorkload]]:
    """Every (model, seq, GEMM) cell of the paper's evaluation sweep."""
    for name, preset in PAPER_MODELS.items():
        for seq in PAPER_SEQ_LENS:
            for wl in model_workloads(preset, seq):
                yield name, seq, wl
