"""repro.serving — production serving engine (see docs/serving.md).

Continuous (in-flight) batching over a fixed slot pool, a paged block KV
cache with optional int8 storage, chunked prefill, per-request sampling, and
FCFS admission with LIFO preemption.  ``runtime.Server`` is a thin
compatibility wrapper over :class:`Engine`; use the engine directly for
streaming callbacks, per-request sampling params, and stats.

    from repro.serving import Engine, EngineConfig, SamplingParams

    eng = Engine(cfg, params, engine_cfg=EngineConfig(slots=8))
    rid = eng.add_request(prompt_tokens, SamplingParams(max_new_tokens=32))
    results = eng.run()          # {rid: [tokens...]}
"""

from repro.serving.engine import Engine, EngineConfig
from repro.serving.kv_cache import (
    BlockAllocator,
    PagedKVCache,
    blocks_for_budget,
    bytes_per_block,
    make_import_fn,
    max_concurrent,
)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import FCFSScheduler, SamplingParams, ServeRequest

__all__ = [
    "Engine",
    "EngineConfig",
    "SamplingParams",
    "ServeRequest",
    "FCFSScheduler",
    "BlockAllocator",
    "PagedKVCache",
    "bytes_per_block",
    "blocks_for_budget",
    "max_concurrent",
    "make_import_fn",
    "sample_tokens",
]
