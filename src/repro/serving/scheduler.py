"""Admission queue and scheduling policy for the serving engine.

The scheduler owns *which* request runs next and *who* gets evicted under
memory pressure; the engine owns the device work.  Policy here is FCFS with
head-of-line admission (a request is admitted the moment a slot AND its
prompt's KV blocks are both available) and LIFO preemption (the
latest-admitted running request is the victim — it has the least sunk decode
work and frees its blocks fastest).  A preempted request re-queues at the
*front* carrying its generated tokens, so its next admission re-prefills
prompt+generated and generation continues where it stopped.

Reliability additions (docs/reliability.md):

  * **Deadlines** — a request may carry ``deadline_s`` (monotonic-clock
    absolute); ``drop_expired`` sweeps the waiting queue each tick so a
    request that can never be served in time stops occupying the head.
  * **Retry backoff** — a request the engine faulted carries
    ``not_before_tick``; admission skips it (without blocking the requests
    behind it — a faulted head must not become head-of-line blocking) until
    the engine's tick counter catches up.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, List, Optional

import numpy as np

__all__ = ["SamplingParams", "ServeRequest", "FCFSScheduler",
           "QUEUED", "PREFILL", "RUNNING", "DONE"]

QUEUED, PREFILL, RUNNING, DONE = "queued", "prefill", "running", "done"


@dataclasses.dataclass
class SamplingParams:
    """Per-request decode controls.  ``temperature <= 0`` is greedy (argmax,
    noise ignored); ``top_k=0`` / ``top_p=1.0`` disable those filters."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    max_new_tokens: int = 32
    seed: int = 0


@dataclasses.dataclass
class ServeRequest:
    """One request plus its runtime bookkeeping (engine-managed)."""

    rid: int
    prompt: np.ndarray                       # (prompt_len,) int32 — original
    sampling: SamplingParams
    on_token: Optional[Callable] = None      # (rid, token, done) per token

    # engine-managed runtime state
    state: str = QUEUED
    slot: int = -1
    admit_index: int = -1                    # admission order (victim pick)
    generated: List[int] = dataclasses.field(default_factory=list)
    rng: Optional[np.random.Generator] = None
    preemptions: int = 0
    arrival_s: float = 0.0
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None

    # reliability state (engine-managed; docs/reliability.md)
    deadline_s: Optional[float] = None       # absolute monotonic deadline
    retries: int = 0                         # fault-triggered re-prefills
    degraded: bool = False                   # decodes via the xla fallback
    not_before_tick: int = 0                 # admission backoff after a fault

    @property
    def serve_prompt(self) -> np.ndarray:
        """Tokens to prefill at (re-)admission: prompt + already-generated."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)]
        )

    @property
    def remaining_new_tokens(self) -> int:
        return self.sampling.max_new_tokens - len(self.generated)


class FCFSScheduler:
    """First-come-first-served queue with LIFO preemption.

    ``on_preempt(request)`` fires when the engine evicts a victim — the hook
    the satellite spec asks for (metrics, logging, or policy experiments
    plug in here without touching the engine).
    """

    def __init__(self, on_preempt: Optional[Callable] = None):
        self.waiting: Deque[ServeRequest] = collections.deque()
        self.on_preempt = on_preempt
        self._admitted = 0

    def __len__(self) -> int:
        return len(self.waiting)

    def add(self, req: ServeRequest) -> None:
        self.waiting.append(req)

    def next_waiting(self, tick: Optional[int] = None) -> Optional[ServeRequest]:
        """First admissible request.  With a ``tick``, requests still in
        retry backoff are skipped *without* blocking those behind them."""
        for req in self.waiting:
            if tick is None or req.not_before_tick <= tick:
                return req
        return None

    def pop(self, tick: Optional[int] = None) -> ServeRequest:
        """Remove and stamp the request :meth:`next_waiting` chose."""
        for i, req in enumerate(self.waiting):
            if tick is None or req.not_before_tick <= tick:
                del self.waiting[i]
                req.admit_index = self._admitted
                self._admitted += 1
                return req
        raise IndexError("no admissible request (all in retry backoff)")

    def drop_expired(self, now: float) -> List[ServeRequest]:
        """Sweep waiting requests whose deadline has passed (engine calls
        once per tick; returns them so it can record the eviction)."""
        expired = [
            r for r in self.waiting
            if r.deadline_s is not None and now >= r.deadline_s
        ]
        for r in expired:
            self.waiting.remove(r)
        return expired

    def pick_victim(self, running: List[ServeRequest]) -> ServeRequest:
        """Latest-admitted running request (least sunk decode work)."""
        return max(running, key=lambda r: r.admit_index)

    def preempt(self, req: ServeRequest) -> None:
        """Return an evicted request to the queue head."""
        req.state = QUEUED
        req.slot = -1
        req.preemptions += 1
        self.waiting.appendleft(req)
        if self.on_preempt is not None:
            self.on_preempt(req)
