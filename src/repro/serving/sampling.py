"""Vectorized host-side token sampling (Gumbel-max).

Replaces the per-row ``rng.choice`` Python loop of the legacy server —
O(batch * vocab) Python-object work per token — with one numpy pass over the
(B, V) logits.  The Gumbel-max identity,

    argmax_i (logits_i / T + g_i),   g_i ~ Gumbel(0, 1)

draws from softmax(logits / T) exactly, so no normalized probabilities (and
no ``rng.choice``) are ever materialized.  Per-row temperature / top-k /
top-p / greedy all vectorize as masks on the scaled logits.

Randomness comes in as explicit per-row uniforms so callers control
determinism: the engine draws each row from its request's own seeded
generator (a request's sample stream is independent of which slot or
batch-mates it runs with), the legacy server from one shared generator.
"""

from __future__ import annotations

import numpy as np

__all__ = ["sample_tokens", "gumbel_from_uniform"]

_EPS = 1e-20
# largest double strictly below 1.0: the old `1.0 - 1e-20` upper clip rounds
# to exactly 1.0 in float64, so a boundary uniform of 1.0 sailed through to
# -log(-log(1.0)) = +inf — one inf noise lane then hijacks the argmax (and
# lands on a -inf-masked token as inf + -inf = nan)
_ONE_BELOW = np.nextafter(1.0, 0.0)


def gumbel_from_uniform(u: np.ndarray) -> np.ndarray:
    """Standard Gumbel(0,1) noise from uniforms in [0, 1)."""
    return -np.log(-np.log(np.clip(u, _EPS, _ONE_BELOW)))


def sample_tokens(
    logits: np.ndarray,          # (B, V) float
    *,
    temperature: np.ndarray,     # (B,) — rows with T <= 0 decode greedily
    top_k: np.ndarray,           # (B,) int — 0 disables
    top_p: np.ndarray,           # (B,) float — 1.0 disables
    uniforms: np.ndarray,        # (B, V) in [0, 1)
) -> np.ndarray:
    """Draw one token per row; returns (B,) int32.

    Greedy rows (temperature <= 0) take ``argmax`` of the raw logits and
    ignore top-k/top-p/noise entirely, so a greedy request is bit-stable
    regardless of the uniforms supplied for its row.
    """
    logits = np.asarray(logits, np.float32)
    b, v = logits.shape
    temperature = np.asarray(temperature, np.float32)
    top_k = np.asarray(top_k, np.int64)
    top_p = np.asarray(top_p, np.float32)

    greedy = temperature <= 0.0
    t_safe = np.where(greedy, 1.0, temperature)[:, None]
    scaled = logits / t_safe

    # ranks of each logit within its row, descending (rank 0 = largest)
    order = np.argsort(-scaled, axis=-1, kind="stable")         # (B, V)
    ranks = np.empty_like(order)
    np.put_along_axis(ranks, order, np.broadcast_to(np.arange(v), (b, v)), -1)

    # top-k: keep ranks < k (k <= 0 keeps everything)
    k_eff = np.where(top_k <= 0, v, top_k)[:, None]
    keep = ranks < k_eff

    # top-p (nucleus): over the *descending* row, keep the smallest prefix
    # whose probability mass reaches top_p.  "cum - p < top_p" keeps the
    # first token crossing the threshold, so at least one survives.
    p_mask = top_p < 1.0
    if p_mask.any():
        masked = np.where(keep, scaled, -np.inf)        # nucleus after top-k
        shifted = masked - masked.max(-1, keepdims=True)
        probs = np.exp(shifted)
        probs /= probs.sum(-1, keepdims=True)
        p_sorted = np.take_along_axis(probs, order, -1)
        cum = np.cumsum(p_sorted, -1)
        keep_sorted = (cum - p_sorted) < top_p[:, None]
        keep_p = np.empty_like(keep)
        np.put_along_axis(keep_p, order, keep_sorted, -1)
        keep &= ~p_mask[:, None] | keep_p

    noisy = np.where(keep, scaled, -np.inf) + gumbel_from_uniform(uniforms)
    drawn = noisy.argmax(-1)
    return np.where(greedy, logits.argmax(-1), drawn).astype(np.int32)
