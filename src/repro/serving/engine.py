"""The serving engine: continuous batching over a paged KV pool.

One ``Engine`` owns a fixed pool of decode slots, a paged KV cache, and a
scheduler.  ``step()`` advances the whole pool by one tick:

    1. **admission** — the queue head is admitted the moment a slot and its
       prompt's KV blocks are both free (FCFS);
    2. **chunked prefill** — the admitted prompt runs through the existing
       contiguous ``forward`` in fixed-size chunks (one compiled prefill
       shape), then a jitted scatter imports its K/V into the slot's pool
       blocks — long prompts never stall running decodes for more than one
       chunk;
    3. **decode** — ONE compiled step serves every running slot (static
       shapes; free slots compute into the null block and are ignored), each
       row sampled with its request's own params and seeded stream.

Because every slot attends only to its own blocks with its own positions,
rows are independent: a greedy request's output is bit-identical whether it
runs alone or packed with arbitrary batch-mates — the property
``tests/test_serving.py`` pins down.

Under memory pressure (``ensure`` fails mid-decode) the scheduler's LIFO
victim is evicted: blocks freed, request re-queued at the front carrying its
generated tokens (re-prefilled on re-admission).

**Fail-safe serving** (``EngineConfig.verify``; docs/reliability.md): each
tick screens every request's logits row for nonfinite values — the signature
of corrupted KV blocks or a tripped verified matmul.  A faulted request is
retried (evicted so re-prefill rebuilds clean KV, with tick backoff), then
degraded to an ``xla``-compiled decode step, then failed — while its
batch-mates keep streaming untouched.  Requests may carry deadlines
(``ttl_s``); expired ones are swept each tick.  Counters
(``faults_detected`` / ``retries`` / ``deadline_evictions`` /
``degraded_requests``) surface in ``last_stats``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.models import transformer as tf_model
from repro.serving import kv_cache as kvc
from repro.serving import sampling
from repro.serving.scheduler import (
    DONE, PREFILL, QUEUED, RUNNING, FCFSScheduler, SamplingParams, ServeRequest,
)

__all__ = ["Engine", "EngineConfig"]


@dataclasses.dataclass
class EngineConfig:
    slots: int = 4
    max_seq: int = 512                   # hard per-sequence context cap
    block_size: Optional[int] = None     # None -> cfg.kv_block_size
    kv_quant: Optional[str] = None       # None -> cfg.kv_quant
    num_blocks: Optional[int] = None     # None -> full occupancy, no preemption
    prefill_chunk: int = 64
    eos_id: int = 1
    # --- reliability (docs/reliability.md §serving) ---
    verify: bool = False                 # screen decode logits for nonfinite
    max_retries: int = 1                 # fault-triggered re-prefills/request
    retry_backoff_ticks: int = 2         # admission backoff after a fault
    ttl_s: Optional[float] = None        # default per-request deadline


class Engine:
    """``add_request`` / ``step`` / ``run`` over a fixed slot pool."""

    def __init__(self, cfg, params=None, *, engine_cfg: Optional[EngineConfig] = None,
                 plan=None, scheduler: Optional[FCFSScheduler] = None,
                 on_preempt: Optional[Callable] = None, seed: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg = engine_cfg or EngineConfig()
        be = api.get_backend(cfg.matmul_backend)  # fail fast on unknown backends
        if be.layout == "dip_q" and cfg.quant_scheme != be.scheme:
            raise ValueError(
                f"backend {be.name!r} consumes {be.scheme!r}-quantized weights "
                f"but cfg.quantization={cfg.quantization!r}"
            )
        if be.layout == "sharded" and plan is None:
            raise ValueError(
                f"backend {be.name!r} dispatches on the weights' ShardingPlan "
                "metadata; pass plan= (repro.distributed.make_plan) or serve "
                "through the implicit GSPMD path (matmul_backend='xla')"
            )
        self.plan = plan
        if params is None:
            params = tf_model.init_params(jax.random.PRNGKey(seed), cfg)
        if plan is not None:
            params = plan.attach_params(params)
            shardings = plan.param_shardings(params)
            params = jax.tree_util.tree_map(jax.device_put, params, shardings)
        self.params = params

        self.block_size = ecfg.block_size or cfg.kv_block_size
        self.kv_quant = ecfg.kv_quant if ecfg.kv_quant is not None else cfg.kv_quant
        if self.kv_quant != "none":
            api.quant.scheme_info(self.kv_quant)  # validate the scheme name
        if self.kv_quant != cfg.kv_quant:
            # the paged decode step reads its storage format off the config;
            # an EngineConfig override must be visible there too
            cfg = self.cfg = dataclasses.replace(cfg, kv_quant=self.kv_quant)
        blocks_per_seq = -(-ecfg.max_seq // self.block_size)
        num_blocks = ecfg.num_blocks or ecfg.slots * blocks_per_seq + 1
        # pure SSM has no attention KV: state is per-slot, nothing is paged
        self._paged = not cfg.is_ssm
        self.kv = kvc.PagedKVCache(
            cfg, num_blocks=num_blocks, block_size=self.block_size,
            slots=ecfg.slots, max_seq=ecfg.max_seq, kv_quant=self.kv_quant,
            plan=plan,
        )

        self._decode = jax.jit(tf_model.paged_decode_step_fn(cfg, plan=plan))
        # chunked prefill routes through the fused flash-attention kernel
        # (api.attention backend "flash") whenever the logits stay local: the
        # kernel takes the chunk's cache offset as a *traced* q_offset, so
        # every chunk of every prompt shares one compiled shape.  Sharded
        # plans keep the GSPMD online-softmax path (the kernel is per-shard).
        self._prefill_fwd = jax.jit(tf_model.decode_step_fn(
            cfg, plan=plan, attn_backend="flash" if plan is None else None,
        ))
        self._import = jax.jit(kvc.make_import_fn(
            cfg, num_blocks, self.block_size, self.kv_quant
        ))
        # prefill buffer: padded so every chunk call has ONE compiled shape
        c = ecfg.prefill_chunk
        self._prefill_buf_len = -(-ecfg.max_seq // c) * c

        self.scheduler = scheduler or FCFSScheduler(on_preempt=on_preempt)
        self._slots: List[Optional[ServeRequest]] = [None] * ecfg.slots
        self._cur = np.zeros((ecfg.slots, 1), np.int32)     # next token to feed
        self._ctx = np.zeros((ecfg.slots,), np.int32)       # tokens in cache
        self._prefilling: Optional[ServeRequest] = None
        self._prefill_cache: Any = None
        self._prefill_tokens: Optional[np.ndarray] = None
        self._prefill_done: int = 0                         # tokens processed
        self._next_rid = 0
        self.results: Dict[int, List[int]] = {}
        self.request_stats: Dict[int, Dict[str, Any]] = {}
        self._decode_steps = 0
        self._prefill_chunks = 0
        self._preempt_count = 0
        self._generated_total = 0
        self.last_stats: Dict[str, Any] = {}
        # reliability bookkeeping (docs/reliability.md §serving)
        self._tick = 0
        self._faults_detected = 0
        self._retries_total = 0
        self._deadline_evictions = 0
        self._degraded_requests = 0
        self._decode_xla = None             # degraded-path step (built lazily)

    # ------------------------------------------------------------ intake ---
    def add_request(self, prompt, sampling_params: Optional[SamplingParams] = None,
                    *, rid: Optional[int] = None,
                    on_token: Optional[Callable] = None,
                    ttl_s: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.size >= self.ecfg.max_seq:
            raise ValueError(
                f"prompt of {prompt.size} tokens leaves no room to generate "
                f"under max_seq={self.ecfg.max_seq}"
            )
        if self._paged:
            # admission-time capacity check: a prompt needing more blocks
            # than the whole pool owns would sit at the queue head forever
            # (can_allocate never true) and spin the engine — fail fast
            need = self.kv.blocks_needed(prompt.size)
            usable = self.kv.num_blocks - 1     # block 0 is the null block
            if need > usable:
                raise ValueError(
                    f"prompt of {prompt.size} tokens needs {need} KV blocks "
                    f"but the entire pool has {usable} usable blocks of "
                    f"{self.block_size} — it can never be admitted"
                )
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        sp = sampling_params or SamplingParams()
        req = ServeRequest(rid=rid, prompt=prompt, sampling=sp, on_token=on_token)
        req.rng = np.random.default_rng(sp.seed)
        req.arrival_s = time.monotonic()
        ttl = ttl_s if ttl_s is not None else self.ecfg.ttl_s
        if ttl is not None:
            req.deadline_s = req.arrival_s + ttl
        self.scheduler.add(req)
        return rid

    # ----------------------------------------------------------- helpers ---
    @property
    def _running(self) -> List[ServeRequest]:
        return [r for r in self._slots if r is not None and r.state == RUNNING]

    def _busy(self) -> bool:
        return bool(len(self.scheduler) or self._prefilling is not None
                    or any(s is not None for s in self._slots))

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _ensure(self, slot: int, length: int) -> bool:
        return self.kv.ensure(slot, length) if self._paged else True

    def _evict(self, req: ServeRequest) -> None:
        slot = req.slot
        if self._paged:
            self.kv.release(slot)
        self._slots[slot] = None
        self._ctx[slot] = 0
        self._preempt_count += 1
        self.scheduler.preempt(req)

    def _finish(self, req: ServeRequest, *, deadline_expired: bool = False,
                fault_failed: bool = False) -> None:
        slot = req.slot
        if slot >= 0:
            if self._paged:
                self.kv.release(slot)
            self._slots[slot] = None
            self._ctx[slot] = 0
        req.state = DONE
        req.finish_s = time.monotonic()
        self.results[req.rid] = list(req.generated)
        self.request_stats[req.rid] = {
            "prompt_len": int(req.prompt.size),
            "new_tokens": len(req.generated),
            "ttft_s": (req.first_token_s - req.arrival_s
                       if req.first_token_s is not None else None),
            "latency_s": req.finish_s - req.arrival_s,
            "preemptions": req.preemptions,
            "retries": req.retries,
            "degraded": req.degraded,
            "deadline_expired": deadline_expired,
            "fault_failed": fault_failed,
        }

    def _emit(self, req: ServeRequest, token: int, done: bool) -> None:
        req.generated.append(token)
        self._generated_total += 1
        if req.first_token_s is None:
            req.first_token_s = time.monotonic()
        if req.on_token is not None:
            req.on_token(req.rid, token, done)

    def _append_token(self, req: ServeRequest, token: int) -> bool:
        """Record one generated token; returns True if the request finished."""
        slot = req.slot
        done = (
            token == self.ecfg.eos_id
            or len(req.generated) + 1 >= req.sampling.max_new_tokens
            or int(self._ctx[slot]) >= self.ecfg.max_seq
        )
        self._emit(req, token, done)
        if done:
            self._finish(req)
            return True
        self._cur[slot, 0] = token
        return False

    def _sample_rows(self, logits: np.ndarray,
                     reqs: List[Optional[ServeRequest]]) -> np.ndarray:
        """One vectorized draw over the (B, V) logits; rows without a request
        fall back to greedy and are ignored by the caller."""
        b, v = logits.shape
        temp = np.zeros(b, np.float32)
        top_k = np.zeros(b, np.int64)
        top_p = np.ones(b, np.float32)
        uniforms = np.zeros((b, v), np.float64)
        for i, r in enumerate(reqs):
            if r is None:
                continue
            sp = r.sampling
            temp[i], top_k[i], top_p[i] = sp.temperature, sp.top_k, sp.top_p
            if sp.temperature > 0:
                uniforms[i] = r.rng.random(v)
        return sampling.sample_tokens(
            logits, temperature=temp, top_k=top_k, top_p=top_p,
            uniforms=uniforms,
        )

    # ------------------------------------------------------------- faults --
    def _handle_fault(self, req: ServeRequest) -> None:
        """A verified step tripped for ``req``: bounded retry (evict —
        re-prefill rebuilds clean KV — with tick backoff), then degrade the
        request to the ``xla`` decode path, then give up.  Peers are never
        touched: rows are independent, so one poisoned row costs one row."""
        self._faults_detected += 1
        if req is self._prefilling:
            self._prefilling = None
            self._prefill_cache = None
            self._prefill_tokens = None
        if req.degraded:
            # the fallback path faulted too — persistent corruption; stop
            # burning ticks on this request and surface the failure
            self._finish(req, fault_failed=True)
            return
        req.not_before_tick = self._tick + self.ecfg.retry_backoff_ticks
        if req.retries < self.ecfg.max_retries:
            req.retries += 1
            self._retries_total += 1
        else:
            req.degraded = True
            self._degraded_requests += 1
        self._evict(req)

    def _get_decode_xla(self):
        """Decode step compiled against the plain ``xla`` matmul backend —
        the bottom rung of the degradation ladder.  Built on first fault."""
        if self._decode_xla is None:
            cfg_xla = dataclasses.replace(self.cfg, matmul_backend="xla")
            self._decode_xla = jax.jit(
                tf_model.paged_decode_step_fn(cfg_xla, plan=self.plan)
            )
        return self._decode_xla

    def _expire(self, req: ServeRequest) -> None:
        self._deadline_evictions += 1
        if req is self._prefilling:
            self._prefilling = None
            self._prefill_cache = None
            self._prefill_tokens = None
        self._finish(req, deadline_expired=True)

    def _sweep_deadlines(self) -> None:
        now = time.monotonic()
        for req in self.scheduler.drop_expired(now):
            self._expire(req)
        for req in list(self._slots):
            if (req is not None and req.deadline_s is not None
                    and now >= req.deadline_s):
                self._expire(req)

    # ---------------------------------------------------------- admission --
    def _try_admit(self) -> None:
        if self._prefilling is not None:
            return
        req = self.scheduler.next_waiting(self._tick)
        if req is None:
            return
        slot = self._free_slot()
        if slot is None:
            return
        plen = int(req.serve_prompt.size)
        if self._paged and not self.kv.can_allocate(plen):
            return
        req = self.scheduler.pop(self._tick)
        req.state = PREFILL
        req.slot = slot
        self._slots[slot] = req
        if self._paged:
            ok = self.kv.ensure(slot, plen)   # can_allocate held above
            assert ok, "allocator disagreed with can_allocate"
        buf = np.zeros(self._prefill_buf_len, np.int32)
        buf[:plen] = req.serve_prompt
        self._prefilling = req
        self._prefill_tokens = buf
        self._prefill_done = 0
        self._prefill_cache = tf_model.init_cache(self.cfg, 1, self._prefill_buf_len)

    # ------------------------------------------------------------ prefill --
    def _advance_prefill(self) -> None:
        req = self._prefilling
        if req is None:
            return
        c = self.ecfg.prefill_chunk
        plen = int(req.serve_prompt.size)
        done = self._prefill_done
        last_logits = None

        if self.cfg.ssm_state:
            # The recurrent state is exact only over the real tokens, so the
            # tail that doesn't fill a chunk runs token-by-token through the
            # O(1) decode path (<= chunk-1 cheap steps) instead of padding.
            if plen - done >= c:
                chunk = self._prefill_tokens[done:done + c][None]
                last_logits, self._prefill_cache = self._prefill_fwd(
                    self.params, self._prefill_cache, jnp.asarray(chunk)
                )
                done += c
                self._prefill_chunks += 1
            else:
                while done < plen:
                    tok = self._prefill_tokens[done:done + 1][None]
                    last_logits, self._prefill_cache = self._prefill_fwd(
                        self.params, self._prefill_cache, jnp.asarray(tok)
                    )
                    done += 1
                self._prefill_chunks += 1
        else:
            # attention-only: the padded tail of the final chunk writes cache
            # rows >= plen, which the import drops and positions never reach
            chunk = self._prefill_tokens[done:done + c][None]
            last_logits, self._prefill_cache = self._prefill_fwd(
                self.params, self._prefill_cache, jnp.asarray(chunk)
            )
            done += c
            self._prefill_chunks += 1
        self._prefill_done = done

        if done >= plen:
            self._finish_prefill(req, plen, last_logits)

    def _finish_prefill(self, req: ServeRequest, plen: int, last_logits) -> None:
        slot = req.slot
        pools = self.kv.pools["layers"]
        self.kv.pools["layers"] = self._import(
            pools, self._prefill_cache["layers"],
            jnp.int32(slot), jnp.int32(plen),
            jnp.asarray(self.kv.table_row(slot)),
        )
        # first token: logits row of the prompt's last position within the
        # final prefill call (padded chunk: plen-1 relative to chunk start;
        # SSM single-token tail: the only row)
        row = np.asarray(last_logits[0, (plen - 1) - (self._prefill_done - last_logits.shape[1])])
        if self.ecfg.verify and not np.isfinite(row).all():
            self._handle_fault(req)
            return
        tok = int(self._sample_rows(row[None], [req])[0])
        self._prefilling = None
        self._prefill_cache = None
        self._prefill_tokens = None
        req.state = RUNNING
        self._ctx[slot] = plen
        if not self._append_token(req, tok):
            pass  # request keeps its slot; next decode feeds `tok`

    # ------------------------------------------------------------- decode --
    def _decode_once(self) -> None:
        # grow every running slot's table for the position it writes next;
        # under exhaustion the LIFO victim is evicted until the rest fit
        for req in sorted(self._running, key=lambda r: r.admit_index):
            if req.state != RUNNING:
                continue
            while not self._ensure(req.slot, int(self._ctx[req.slot]) + 1):
                victims = self._running
                victim = self.scheduler.pick_victim(victims)
                if victim is req and len(victims) == 1:
                    raise RuntimeError(
                        f"KV pool too small for one sequence: "
                        f"{self.kv.num_blocks} blocks of {self.block_size}"
                    )
                self._evict(victim)
                if victim is req:
                    break

        reqs = [r if (r is not None and r.state == RUNNING) else None
                for r in self._slots]
        if not any(r is not None for r in reqs):
            return
        # a tick with any degraded request runs the WHOLE pool through the
        # xla-compiled step (one compiled step per tick is the engine
        # invariant; healthy rows are row-independent either way)
        decode = (
            self._get_decode_xla()
            if any(r is not None and r.degraded for r in reqs)
            else self._decode
        )
        logits, self.kv.pools = decode(
            self.params, self.kv.pools,
            jnp.asarray(self._cur), jnp.asarray(self._ctx),
            jnp.asarray(self.kv.block_tables),
        )
        self._decode_steps += 1
        rows = np.asarray(logits[:, -1])
        next_tokens = self._sample_rows(rows, reqs)
        for i, req in enumerate(reqs):
            if req is None:
                continue
            if self.ecfg.verify and not np.isfinite(rows[i]).all():
                # corrupted KV / a tripped verified matmul surfaces here as a
                # nonfinite logits row; only this row's request pays
                self._handle_fault(req)
                continue
            self._ctx[i] += 1   # the fed token is now in the cache
            self._append_token(req, int(next_tokens[i]))

    # -------------------------------------------------------------- drive --
    def step(self) -> bool:
        """One engine tick (deadline sweep -> admit -> prefill chunk ->
        decode step).  Returns True while there is work left."""
        self._tick += 1
        self._sweep_deadlines()
        self._try_admit()
        self._advance_prefill()
        self._try_admit()    # a finished prefill may free the pipeline
        self._decode_once()
        return self._busy()

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; returns {rid: generated tokens} and fills
        ``last_stats`` / ``request_stats``."""
        t0 = time.monotonic()
        steps0, gen0 = self._decode_steps, self._generated_total
        while self.step():
            pass
        wall = time.monotonic() - t0
        self.last_stats = {
            "decode_steps": self._decode_steps - steps0,
            "wall_s": wall,
            "tok_per_s": (self._generated_total - gen0) / max(wall, 1e-9),
            "prefill_chunks": self._prefill_chunks,
            "preemptions": self._preempt_count,
            "requests": len(self.results),
            "faults_detected": self._faults_detected,
            "retries": self._retries_total,
            "deadline_evictions": self._deadline_evictions,
            "degraded_requests": self._degraded_requests,
        }
        return dict(self.results)
