"""Paged KV cache: free-list block allocator, per-slot block tables, and the
prefill-import scatter.

Layout (see docs/serving.md §Paged KV layout): every attention layer's K/V
(or MLA latent) lives in one pool of ``num_blocks`` blocks of ``block_size``
tokens.  A sequence owns an ordered list of blocks; logical position ``p``
maps to physical row ``table[p // block_size] * block_size + p % block_size``.
Pools are static-shaped, so one compiled decode step serves every sequence
in the pool for the engine's lifetime; growing a sequence is a *host-side*
table edit, never a reallocation.

**Block 0 is reserved as the null block**: free slots' tables point at it, so
their (masked, ignored) decode writes land somewhere harmless and no branch
is needed in the compiled step.  The allocator therefore hands out blocks
``1..num_blocks-1``.

Storage is bf16 (``kv_quant="none"``) or int8 with per-token/head float32
scales (``kv_quant="int8"``, via ``api.quant.quantize_rows``) — int8 halves
the bytes per cached token, so a fixed byte budget holds ~2x the blocks
(:func:`blocks_for_budget` makes that exact).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention
from repro.models import transformer as tf_model
from repro.reliability.inject import maybe_fail

__all__ = [
    "BlockAllocator",
    "PagedKVCache",
    "bytes_per_block",
    "blocks_for_budget",
    "max_concurrent",
    "make_import_fn",
]


class BlockAllocator:
    """Free-list allocator over blocks ``1..num_blocks-1`` (0 = null block).

    ``alloc`` is all-or-nothing: a request that cannot get every block it
    asked for gets none (the scheduler then waits or preempts).  Double-free
    and foreign-free raise — the invariants the property tests lean on.
    """

    NULL_BLOCK = 0

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._allocated: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        maybe_fail("kv.alloc")
        # slice-atomically: popping one block at a time would leak the
        # already-popped prefix if anything raised mid-loop (the invariant
        # the fail-point property tests exercise)
        got = self._free[-n:][::-1] if n else []
        del self._free[len(self._free) - n:]
        self._allocated.update(got)
        return got

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise ValueError(f"freeing block {b} not currently allocated")
        maybe_fail("kv.free")
        for b in blocks:
            self._allocated.discard(b)
            self._free.append(b)


class PagedKVCache:
    """Device pools + host-side block tables for a fixed slot pool.

    ``block_tables`` is host numpy (slots, blocks_per_seq) int32 — rows of
    free slots are all null-block.  ``ensure(slot, length)`` grows a slot's
    table to cover ``length`` tokens (False if the allocator is exhausted —
    the engine's preemption trigger); ``release(slot)`` returns everything.
    """

    def __init__(self, cfg, *, num_blocks: int, block_size: int, slots: int,
                 max_seq: int, kv_quant: str = "none", plan=None):
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.slots = slots
        self.kv_quant = kv_quant
        self.blocks_per_seq = -(-max_seq // block_size)
        self.pools = tf_model.init_paged_cache(
            cfg, num_blocks, block_size, slots=slots, kv_quant=kv_quant
        )
        if plan is not None:
            shardings = plan.paged_cache_shardings(self.pools)
            self.pools = jax.tree_util.tree_map(
                jax.device_put, self.pools, shardings
            )
        self.allocator = BlockAllocator(num_blocks)
        self.block_tables = np.zeros((slots, self.blocks_per_seq), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(slots)]

    def blocks_needed(self, length: int) -> int:
        return -(-length // self.block_size)

    def can_allocate(self, length: int) -> bool:
        return self.blocks_needed(length) <= self.allocator.num_free

    def ensure(self, slot: int, length: int) -> bool:
        """Grow ``slot``'s table to cover ``length`` tokens; all-or-nothing."""
        need = self.blocks_needed(length)
        if need > self.blocks_per_seq:
            raise ValueError(
                f"sequence of {length} tokens needs {need} blocks > "
                f"blocks_per_seq={self.blocks_per_seq} (raise max_seq)"
            )
        have = len(self.owned[slot])
        if need <= have:
            return True
        got = self.allocator.alloc(need - have)
        if got is None:
            return False
        for b in got:
            self.block_tables[slot, len(self.owned[slot])] = b
            self.owned[slot].append(b)
        return True

    def release(self, slot: int) -> None:
        if self.owned[slot]:
            self.allocator.free(self.owned[slot])
        self.owned[slot] = []
        self.block_tables[slot] = BlockAllocator.NULL_BLOCK

    def table_row(self, slot: int) -> np.ndarray:
        return self.block_tables[slot]


# ------------------------------------------------------------- capacity ----
def bytes_per_block(cfg, block_size: Optional[int] = None,
                    kv_quant: Optional[str] = None) -> int:
    """Exact device bytes one KV block costs across all layers.

    GQA: L * 2 * bs * KV * hd elements; MLA: L * bs * (rank + rope); hybrid:
    only the ``n_super`` shared-attention instances page.  int8 storage is
    1 byte/element plus a float32 scale per (token, head) row — the bound
    the int8-beats-bf16 capacity criterion is tested against.  Pure SSM has
    no paged state (returns 0).
    """
    bs = block_size if block_size is not None else cfg.kv_block_size
    kvq = kv_quant if kv_quant is not None else cfg.kv_quant
    item = 1 if kvq != "none" else jnp.dtype(cfg.compute_dtype).itemsize

    if cfg.is_ssm:
        return 0
    if cfg.use_mla:
        per_tok = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * item
        scale = 2 * 4 if kvq != "none" else 0          # c_kv + k_rope scales
        return cfg.n_layers * bs * (per_tok + scale)
    n_inst = cfg.n_layers // cfg.attn_every if cfg.is_hybrid else cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    per_tok = 2 * kv * hd * item                       # k + v
    scale = 2 * kv * 4 if kvq != "none" else 0
    return n_inst * bs * (per_tok + scale)


def blocks_for_budget(cfg, budget_bytes: int, block_size: Optional[int] = None,
                      kv_quant: Optional[str] = None) -> int:
    """Usable blocks (null block excluded) a byte budget buys."""
    per = bytes_per_block(cfg, block_size, kv_quant)
    if per == 0:
        raise ValueError(f"{cfg.name}: pure-SSM config has no paged KV bytes")
    return max(0, budget_bytes // per - 1)


def max_concurrent(cfg, num_usable_blocks: int, seq_len: int,
                   block_size: Optional[int] = None) -> int:
    """Sequences of ``seq_len`` tokens that fit in ``num_usable_blocks``."""
    bs = block_size if block_size is not None else cfg.kv_block_size
    per_seq = -(-seq_len // bs)
    return num_usable_blocks // per_seq


# ------------------------------------------------------- prefill import ----
def make_import_fn(cfg, num_blocks: int, block_size: int, kv_quant: str):
    """Jitted scatter of a finished contiguous B=1 prefill cache into a
    slot's pool blocks (and SSM state into its slot rows).

    Prefill runs through the existing contiguous ``forward`` (one compiled
    chunk shape) and lands here once per admission: positions ``0..plen-1``
    scatter to ``block_row[p // bs] * bs + p % bs``; buffer rows at or beyond
    ``plen`` (prompt padding) get the out-of-range sentinel ``nb * bs`` and
    are dropped by the scatter.  ``slot`` / ``plen`` / ``block_row`` are
    traced, so one compilation covers every admission.
    """
    nb, bs = num_blocks, block_size

    def scatter_all(pool, scale_pool, vals, phys):
        # pool (N, nb, bs, ...) / vals (N, Sp, ...): vmap over the stack axis
        if kv_quant != "none":
            def one(p, s, v):
                return attention.paged_write(
                    p, phys, v, scale_pool=s, kv_quant=kv_quant
                )
            return jax.vmap(one)(pool, scale_pool, vals)

        def one(p, v):
            return attention.paged_write(p, phys, v)[0]

        return jax.vmap(one)(pool, vals), None

    def phys_for(block_row, plen, sp):
        pos = jnp.arange(sp, dtype=jnp.int32)
        blk = block_row[jnp.minimum(pos // bs, block_row.shape[0] - 1)]
        return jnp.where(pos < plen, blk * bs + pos % bs, nb * bs)

    def import_attn(pool, prefill, names, block_row, plen):
        out = {}
        ph = phys_for(block_row, plen, prefill[names[0]].shape[2])
        for nm in names:
            data, scales = scatter_all(
                pool[nm], pool.get(f"{nm}_scale"), prefill[nm][:, 0], ph
            )
            out[nm] = data
            if kv_quant != "none":
                out[f"{nm}_scale"] = scales
        return out

    def imp(pool_layers, prefill_layers, slot, plen, block_row):
        if cfg.ssm_state:
            out = dict(pool_layers)
            out["conv"] = pool_layers["conv"].at[:, slot].set(
                prefill_layers["conv"][:, 0].astype(pool_layers["conv"].dtype)
            )
            out["state"] = pool_layers["state"].at[:, slot].set(
                prefill_layers["state"][:, 0]
            )
            if cfg.is_hybrid:
                out["attn"] = import_attn(
                    pool_layers["attn"], prefill_layers["attn"],
                    ("k", "v"), block_row, plen,
                )
            return out
        names = ("c_kv", "k_rope") if cfg.use_mla else ("k", "v")
        return import_attn(pool_layers, prefill_layers, names, block_row, plen)

    return imp
