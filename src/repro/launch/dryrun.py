import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.  Only
this entry point forces them; tests and benches see the real device count.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.jsonl

Per cell it prints/records: compile ok, memory_analysis, cost_analysis
FLOPs/bytes, per-kind collective bytes, and the three roofline terms
(docs/benchmarks.md §Dry-run / §Roofline read from the JSONL).
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, get_config, shape_cells_for
from repro.configs.base import SHAPE_CELLS
from repro.distributed.plan import make_plan, make_production_mesh
from repro.launch import roofline as rl
from repro.launch.specs import input_specs


def _compile_cell(cfg, cell, *, multi_pod: bool, kv_chunk: int, unroll: bool,
                  donate: bool, seq_parallel: bool = True, microbatch: int = 1):
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = make_plan(mesh, cfg, cell.kind, seq_parallel=seq_parallel)
    # cost probes (unroll=True) always run single-pass: cost totals are
    # token-linear, while a microbatch scan body would be counted once
    fn, args = input_specs(cfg, cell, policy, kv_chunk=kv_chunk, unroll=unroll,
                           microbatch=1 if unroll else microbatch)
    if not donate:
        donate_args = ()
    elif cell.kind == "train":
        donate_args = (0,)      # train state buffers update in place
    elif cell.kind == "decode":
        donate_args = (1,)      # KV/SSM cache updates in place (vLLM-style)
    else:
        donate_args = ()
    with mesh:
        jfn = jax.jit(fn, donate_argnums=donate_args)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    return compiled, mesh


def _probe_costs(cfg, cell, *, multi_pod: bool, kv_chunk: int, donate: bool,
                 seq_parallel: bool = True):
    """(flops, bytes, coll_bytes) extrapolated to the full layer count.

    XLA cost analysis counts a while-loop body ONCE regardless of trip count,
    so a scanned L-layer model under-reports by ~L.  We compile two *unrolled*
    probes at 1 and 2 layer-units (a unit = attn_every layers for hybrids, so
    the shared-attention block appears a proportional number of times) and
    extrapolate linearly: total(L) = base + units(L) * per_unit.  Everything
    linear in L (per-layer compute, optimizer update on stacked params,
    per-layer collectives) is captured exactly; embed/logits/loss are in
    ``base``.
    """
    import dataclasses as dc

    unit = cfg.attn_every if cfg.is_hybrid else 1
    units_full = cfg.n_layers // unit

    def measure(n_units):
        pcfg = dc.replace(cfg, n_layers=n_units * unit)
        compiled, _ = _compile_cell(
            pcfg, cell, multi_pod=multi_pod, kv_chunk=kv_chunk, unroll=True,
            donate=donate, seq_parallel=seq_parallel,
        )
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        coll = rl.collective_bytes(compiled.as_text())
        return (
            float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["total"]),
            coll,
        )

    f1, b1, c1, _ = measure(1)
    f2, b2, c2, coll2 = measure(2)
    per = (max(f2 - f1, 0.0), max(b2 - b1, 0.0), max(c2 - c1, 0.0))
    base = (max(f1 - per[0], 0.0), max(b1 - per[1], 0.0), max(c1 - per[2], 0.0))
    total = tuple(b + units_full * p for b, p in zip(base, per))
    return total, coll2


def run_cell(arch: str, cell, *, multi_pod: bool, kv_chunk: int = 1024,
             donate: bool = True, verbose: bool = True, probes: bool = True,
             seq_parallel: bool = True, microbatch: int = 1):
    cfg = get_config(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    t0 = time.monotonic()
    # 1) the real artifact: full depth, scanned — the compile/memory gate
    compiled, mesh = _compile_cell(
        cfg, cell, multi_pod=multi_pod, kv_chunk=kv_chunk, unroll=False,
        donate=donate, seq_parallel=seq_parallel, microbatch=microbatch,
    )
    t_full = time.monotonic() - t0
    chips = mesh.devices.size

    report = rl.analyze_compiled(
        compiled, arch=arch, shape=cell.name, mesh_name=mesh_name,
        chips=chips, cfg=cfg, cell=cell,
    )

    # 2) cost probes: correct per-layer totals for the roofline terms
    if probes:
        (flops, byts, coll), coll_kinds = _probe_costs(
            cfg, cell, multi_pod=multi_pod, kv_chunk=kv_chunk, donate=donate,
            seq_parallel=seq_parallel,
        )
        hw = rl.HW()
        report.flops_per_device = flops
        report.bytes_per_device = byts
        report.coll_bytes_per_device = coll
        report.coll_by_kind = coll_kinds
        report.compute_s = flops / hw.peak_flops
        report.memory_s = byts / hw.hbm_bw
        report.collective_s = coll / hw.ici_bw
        report.useful_flops_ratio = (
            report.model_flops_global / (flops * chips) if flops else 0.0
        )
    t_all = time.monotonic() - t0

    row = report.row()
    row.update(compile_s=round(t_full, 1), total_s=round(t_all, 1), status="ok")
    if verbose:
        print(f"--- {arch} x {cell.name} x {mesh_name} ---")
        print(compiled.memory_analysis())
        print(json.dumps({k: v for k, v in row.items() if k != "coll_by_kind"},
                         default=str))
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (assignment spelling ok)")
    ap.add_argument("--shape", default=None, choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--all", action="store_true", help="full assigned grid")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation slices for train cells")
    ap.add_argument("--out", default=None, help="append JSONL rows here")
    args = ap.parse_args()

    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows = []
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        cells = shape_cells_for(cfg)
        if args.shape:
            cells = [c for c in cells if c.name == args.shape]
            if not cells:
                print(f"[skip] {arch} x {args.shape}: not applicable "
                      f"(sub-quadratic gate, see DESIGN.md §4)")
                continue
        for cell in cells:
            for mp in meshes:
                try:
                    row = run_cell(arch, cell, multi_pod=mp, kv_chunk=args.kv_chunk,
                                   seq_parallel=not args.no_seq_parallel,
                                   microbatch=args.microbatch)
                except Exception as e:  # a failure here is a bug in the system
                    failures += 1
                    row = {
                        "arch": arch, "shape": cell.name,
                        "mesh": "pod2x16x16" if mp else "pod16x16",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                    }
                    traceback.print_exc()
                rows.append(row)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row, default=str) + "\n")

    ok = sum(1 for r in rows if r.get("status") == "ok")
    print(f"\n=== dry-run: {ok}/{len(rows)} cells compiled, {failures} failures ===")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
