"""Launchers: dry-run, training and serving drivers.

Mesh construction lives in the unified distributed plan
(``repro.distributed.plan``); ``make_production_mesh`` / ``make_local_mesh``
are re-exported here for launcher convenience.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — never import it from
library code; it is an entry point only (python -m repro.launch.dryrun).
"""

from repro.distributed.plan import make_local_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]
