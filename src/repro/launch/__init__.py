"""Launchers: mesh construction, dry-run, training and serving drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — never import it from
library code; it is an entry point only (python -m repro.launch.dryrun).
"""

from repro.launch.mesh import make_local_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]
