"""Launchers: dry-run, training and serving drivers.

Mesh construction moved into the unified distributed plan
(``repro.distributed.plan``); the re-exports here (and the
``repro.launch.mesh`` shim) remain for one PR.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — never import it from
library code; it is an entry point only (python -m repro.launch.dryrun).
"""

from repro.distributed.plan import make_local_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]
