"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) the three terms (docs/benchmarks.md §Roofline):

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory_s     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective_s = collective_bytes_per_device / ICI_link_bandwidth

``cost_analysis()`` on the partitioned executable reports the *per-device*
program, so per-chip constants apply directly.  Collective bytes are not in
cost_analysis — they are summed from the optimized HLO text (the compiled
module, after SPMD partitioning inserted the collectives), using each
collective op's result shapes.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment-provided).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per link
    hbm_bytes: float = 16e9             # v5e capacity


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  f32[128,1024]{1,0}   bf16[4]   pred[]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in optimized HLO, by kind."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.lstrip()
        # result-op lines look like:  %name = TYPE all-reduce(...)
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.*)", stripped)
        if not m:
            continue
        rest = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rest):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rest:
            continue  # avoid double counting start/done pairs
        # result type is everything before the op name: may be a tuple
        type_part = rest.split(kind)[0]
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_part))
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float           # 6*N*D (dense) / 6*N_active*D (MoE)
    useful_flops_ratio: float           # MODEL_FLOPS / (HLO_FLOPs * chips)
    peak_memory_bytes: Optional[float]  # from memory_analysis when available

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time lower bound (perfect overlap: max of terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound step time (the §Perf score)."""
        if self.step_time_s <= 0:
            return 0.0
        useful_s = self.model_flops_global / (self.chips * HW().peak_flops)
        return useful_s / self.step_time_s

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_per_dev": self.flops_per_device,
            "hlo_bytes_per_dev": self.bytes_per_device,
            "coll_bytes_per_dev": self.coll_bytes_per_device,
            "model_flops": self.model_flops_global,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def model_flops(cfg, cell, tokens: Optional[int] = None) -> float:
    """6*N*D with N = active params; decode counts one token per sequence."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        d = cell.global_batch * cell.seq_len
        return 6.0 * n * d
    if cell.kind == "prefill":
        d = cell.global_batch * cell.seq_len
        return 2.0 * n * d           # forward only
    # decode: one token per slot
    return 2.0 * n * cell.global_batch


def analyze_compiled(
    compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
    cfg=None, cell=None, hw: HW = HW(),
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = None
    coll = collective_bytes(compiled.as_text())
    mf = model_flops(cfg, cell) if (cfg is not None and cell is not None) else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=float(coll["total"]),
        coll_by_kind=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=byts / hw.hbm_bw,
        collective_s=coll["total"] / hw.ici_bw,
        model_flops_global=mf,
        useful_flops_ratio=(mf / (flops * chips)) if flops else 0.0,
        peak_memory_bytes=peak,
    )
