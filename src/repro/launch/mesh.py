"""Deprecation shim (one PR): mesh construction moved into the unified
distributed plan — import from ``repro.distributed.plan`` (or
``repro.distributed``) instead."""

from __future__ import annotations

from repro.distributed.plan import make_local_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]
