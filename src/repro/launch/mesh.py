"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only launch/dryrun.py is allowed to force 512 host devices.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one v5e pod slice of 256 chips (16x16), or two pods.

    Axes: "data" carries DP+FSDP, "model" carries TP/EP/SP; "pod" (multi-pod)
    carries pure DP across the DCN link.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
