"""ShapeDtypeStruct stand-ins for every model input — the dry-run currency.

``input_specs(cfg, cell, policy)`` returns (fn, args) where ``fn`` is the
step to lower (train_step / prefill_step / serve_step) and ``args`` is a
pytree of sharding-annotated ShapeDtypeStructs.  Nothing here allocates.

DiP-stored linears appear as ``api.DipWeight`` pytree nodes wrapping their
storage spec; ``param_specs`` / ``param_shardings`` produce them with
identical metadata, so the spec/sharding zips below traverse in lockstep and
the optimizer-moment mirror inherits the wrapping for free.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.plan import ShardingPlan
from repro.models import transformer as tf_model
from repro.optim import AdamW

__all__ = ["input_specs", "train_state_specs"]


def _with_sharding(specs: Any, shardings: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs,
        shardings,
    )


def train_state_specs(cfg: ArchConfig, policy: ShardingPlan) -> Dict:
    """Specs for {params, opt_state, step} with FSDP/TP shardings attached.

    Param specs carry the plan's per-weight ``WeightPlan`` metadata exactly
    like materialized params would (``attach_params`` works on spec trees),
    so the dry-run lowers the same dispatch the real run takes."""
    pspecs = policy.attach_params(tf_model.param_specs(cfg))
    pshard = policy.param_shardings(pspecs)
    params = _with_sharding(pspecs, pshard)
    # Adam moments mirror the parameter pytree (and sharding) in f32
    moments = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sh),
        pspecs,
        pshard,
    )
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    return {
        "params": params,
        "opt_state": {"mu": moments, "nu": moments, "count": scalar, "grad_norm": f32},
        "step": scalar,
    }


def _batch_specs(cfg: ArchConfig, cell: ShapeCell, policy: ShardingPlan) -> Dict:
    b, s = cell.global_batch, cell.seq_len
    mesh = policy.mesh
    dp = policy.dp_for(b) or None
    tok_shard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(dp, None))
    emb_shard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(dp, None, None))
    if cfg.frontend != "none" and cell.kind != "decode":
        return {
            "embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.compute_dtype), sharding=emb_shard),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_shard),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_shard),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_shard),
    }


def _cache_specs(cfg: ArchConfig, cell: ShapeCell, policy: ShardingPlan) -> Any:
    shapes = jax.eval_shape(
        lambda: tf_model.init_cache(cfg, cell.global_batch, cell.seq_len)
    )
    return _with_sharding(shapes, _cache_shardings(shapes, policy))


def _cache_shardings(shapes: Any, policy: ShardingPlan) -> Any:
    def walk(t, name=None):
        if isinstance(t, dict):
            return {k: walk(v, k) for k, v in t.items()}
        if len(t.shape) == 0:  # pos scalar
            return jax.sharding.NamedSharding(policy.mesh, jax.sharding.PartitionSpec())
        return policy.named(policy.cache_pspec(name, tuple(t.shape)))

    return walk(shapes)


def input_specs(
    cfg: ArchConfig, cell: ShapeCell, policy: ShardingPlan, *,
    kv_chunk: int = 1024, unroll: bool = False, microbatch: int = 1,
) -> Tuple[Any, Tuple]:
    """(fn_to_lower, arg_specs) for one (arch x shape) cell.

    ``unroll=True`` unrolls the layer scans — used by the dry-run's cost
    probes (XLA cost analysis counts a while body once; see launch/dryrun).
    """
    if cell.kind == "train":
        opt = AdamW(lr=3e-4)
        # online-softmax attention for any long-ish context: bounds live
        # scores to (b, heads, s_q, kv_chunk) by construction
        kc = kv_chunk if cell.seq_len >= 4096 else 0
        fn = tf_model.train_step_fn(cfg, opt, plan=policy, unroll=unroll,
                                    kv_chunk=kc, microbatch=microbatch)
        return fn, (train_state_specs(cfg, policy), _batch_specs(cfg, cell, policy))

    # inference serves bf16 weights (no f32 masters): halves every
    # param-touching byte — HBM reads, FSDP gathers, and the f32 relayout
    # traffic that f32 storage drags into the graph (§Perf pair 3)
    cd = jnp.dtype(cfg.compute_dtype)
    serve_specs = policy.attach_params(
        jax.tree_util.tree_map(
            lambda t: jax.ShapeDtypeStruct(t.shape, cd), tf_model.param_specs(cfg)
        )
    )
    pspecs = _with_sharding(serve_specs, policy.param_shardings(serve_specs))

    if cell.kind == "prefill":
        def prefill(params, batch):
            logits, _, _ = tf_model.forward(
                params, cfg,
                tokens=batch.get("tokens"), embeddings=batch.get("embeddings"),
                kv_chunk=kv_chunk, plan=policy, unroll=unroll,
                logits_positions="last",
            )
            return logits
        batch = _batch_specs(cfg, cell, policy)
        batch.pop("labels")
        return prefill, (pspecs, batch)

    # decode: one new token against a cache of cell.seq_len
    fn = tf_model.decode_step_fn(cfg, plan=policy, unroll=unroll)
    cache = _cache_specs(cfg, cell, policy)
    mesh = policy.mesh
    tok = jax.ShapeDtypeStruct(
        (cell.global_batch, 1), jnp.int32,
        sharding=jax.sharding.NamedSharding(
            mesh,
            jax.sharding.PartitionSpec(policy.dp_for(cell.global_batch) or None, None),
        ),
    )
    return fn, (pspecs, cache, tok)
