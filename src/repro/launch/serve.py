"""Serving driver: batched requests through the serving engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --requests 8 --max-new 32

``--engine paged`` (default) serves through the continuous-batching engine
with the paged KV cache (``repro.serving``); ``--engine wave`` runs the
legacy static-batch wave loop for comparison.  ``--kv-quant int8`` stores
K/V at int8 (~2x sequences per byte; see docs/serving.md).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf_model
from repro.runtime import Server, ServerConfig, WaveServer
from repro.runtime.server import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--engine", choices=("paged", "wave"), default="paged",
                    help="paged: continuous-batching engine (repro.serving); "
                         "wave: legacy static-batch loop")
    ap.add_argument("--block-size", type=int, default=None,
                    help="paged-KV block size (tokens; default "
                         "cfg.kv_block_size)")
    ap.add_argument("--kv-quant", choices=("none", "int8"), default=None,
                    help="KV-cache storage (default cfg.kv_quant); int8 "
                         "halves cache bytes per token")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked-prefill granularity (tokens per chunk)")
    ap.add_argument("--dip", action="store_true",
                    help="store weights DiP-permutated + use the Pallas kernel")
    ap.add_argument("--sharded", choices=("tp", "fsdp"), default=None,
                    help="serve through the explicit multi-chip backends "
                         "(dip_tp / dip_fsdp) on a mesh over the local "
                         "devices — see docs/distributed.md")
    ap.add_argument("--quantize", choices=("int8", "fp8_e4m3"), default=None,
                    help="quantize the DiP projections and serve through the "
                         "matching quantized kernel (dip_int8w / dip_fp8)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure block-size candidates for this config's "
                         "projections before serving (tiled backends only)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.dip:
        import dataclasses
        cfg = dataclasses.replace(cfg, matmul_backend="pallas_dip",
                                  compute_dtype="float32")
    if args.quantize:
        import dataclasses
        from repro.api import quant
        cfg = dataclasses.replace(
            cfg, quantization=args.quantize,
            matmul_backend=quant.scheme_info(args.quantize).backend,
            compute_dtype="float32",
        )
    plan = None
    if args.sharded:
        import dataclasses
        from repro.distributed.plan import make_local_mesh, make_plan
        # explicit multi-chip serving: TP over all local devices, or FSDP
        # over all local devices, dispatched per the weights' plan metadata
        n_dev = jax.device_count()
        mesh = (make_local_mesh(data=1, model=n_dev) if args.sharded == "tp"
                else make_local_mesh(data=n_dev, model=1))
        backend = {"tp": "dip_tp", "fsdp": "dip_fsdp"}[args.sharded]
        cfg = dataclasses.replace(cfg, sharding=args.sharded,
                                  matmul_backend=backend,
                                  compute_dtype="float32")
        plan = make_plan(mesh, cfg, "decode")
    if args.autotune:
        # registers measured tuning entries before the first forward traces,
        # so every jitted dispatch below picks them up
        from repro.api import autotune
        autotune.autotune_for_config(cfg, tokens=args.slots, verbose=True)

    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServerConfig(
        batch_slots=args.slots, max_seq=args.max_seq,
        max_new_tokens=args.max_new, prefill_chunk=args.prefill_chunk,
        block_size=args.block_size, kv_quant=args.kv_quant,
    )
    cls = Server if args.engine == "paged" else WaveServer
    server = cls(cfg, scfg, params, plan=plan)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, size=rng.integers(4, 16)))
        for i in range(args.requests)
    ]
    results = server.serve(reqs)
    for rid in sorted(results):
        print(f"req {rid}: {len(results[rid])} tokens -> {results[rid][:8]}...")
    print(f"[serve:{args.engine}] {server.last_stats}")


if __name__ == "__main__":
    main()
