"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --reduced \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/run1

On the CPU container this trains reduced configs end-to-end (the ~100M-scale
example lives in examples/train_lm.py); on a real slice the same driver jits
the full config against the production mesh (--mesh single|multi).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.distributed.plan import (
    STRATEGIES, make_local_mesh, make_plan, make_production_mesh,
)
from repro.optim import AdamW, cosine_schedule
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="none", choices=["none", "local", "single", "multi"])
    ap.add_argument("--sharding", default=None, choices=list(STRATEGIES),
                    help="override cfg.sharding: gspmd (implicit XLA "
                         "partitioning) | tp | fsdp | sp | ep (explicit "
                         "shard_map backends) | pp (pipeline stage axis; "
                         "pair with --stages — see docs/distributed.md)")
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages for --sharding pp: the local mesh "
                         "gets a leading 'stage' axis of this size")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="GPipe microbatches per step for --sharding pp "
                         "(0 = auto: 2x stages)")
    ap.add_argument("--strict-sharding", action="store_true",
                    help="raise (instead of warn-once + replicate) when a "
                         "param dim does not divide its mesh axis")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="measure block-size candidates for this config's "
                         "projections before training (tiled backends only)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.sharding:
        import dataclasses
        cfg = dataclasses.replace(cfg, sharding=args.sharding)
        explicit = {"tp": "dip_tp", "fsdp": "dip_fsdp", "sp": "dip_sp", "ep": "dip_ep"}
        if args.sharding in explicit:
            # the explicit strategies dispatch through their sharded backend;
            # without this the flag would silently keep the implicit path.
            # pp is a stage axis, not a backend — the per-stage matmuls keep
            # the config's backend.
            cfg = dataclasses.replace(cfg, matmul_backend=explicit[args.sharding])
    if args.autotune:
        # registers measured tuning entries before train_step traces, so the
        # jitted step dispatches with them
        from repro.api import autotune
        autotune.autotune_for_config(cfg, tokens=args.batch * args.seq, verbose=True)

    mesh = plan = None
    if args.mesh == "local":
        if args.stages > 1:
            if jax.device_count() % args.stages:
                raise SystemExit(
                    f"--stages {args.stages} does not divide "
                    f"{jax.device_count()} devices"
                )
            mesh = make_local_mesh(
                data=jax.device_count() // args.stages, model=1, stage=args.stages
            )
        else:
            mesh = make_local_mesh(data=jax.device_count())
        plan = make_plan(mesh, cfg, "train", strict=args.strict_sharding)
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        plan = make_plan(mesh, cfg, "train", strict=args.strict_sharding)

    gt = None
    if args.compress_grads:
        from repro.distributed import compression
        gt = compression.compression_transform()
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps), grad_transform=gt)

    trainer = Trainer(
        cfg,
        TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir,
                      pipeline_microbatches=args.microbatches),
        optimizer=opt,
        mesh=mesh,
        plan=plan,
        seq_len=args.seq,
        global_batch=args.batch,
    )
    ctx = mesh if mesh is not None else _null()
    with ctx:
        out = trainer.run()
    print(f"[train] done: {len(out['metrics'])} steps in {out['wall_s']:.1f}s, "
          f"final loss {out['metrics'][-1]['loss']:.4f}")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
