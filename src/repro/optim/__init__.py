"""Optimizers and schedules (self-contained — no optax dependency)."""

from repro.optim.adamw import AdamW, GradientTransform, clip_by_global_norm
from repro.optim.schedules import cosine_schedule, linear_warmup

__all__ = [
    "AdamW",
    "GradientTransform",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup",
]
