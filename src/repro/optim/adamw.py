"""AdamW with global-norm clipping, built for sharded pytrees.

Self-contained (optax is not available offline).  The optimizer state mirrors
the parameter pytree leaf-for-leaf, so whatever sharding the parameters carry
propagates to the moments — FSDP/ZeRO sharding of optimizer state falls out
of GSPMD for free.

Interface expected by repro.models.transformer.train_step_fn:
    opt = AdamW(lr=..., ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)   # params += updates
    opt.last_grad_norm(state) -> f32 scalar (pre-clip global norm)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "GradientTransform", "clip_by_global_norm"]

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class GradientTransform:
    """Optional hook applied to gradients before the optimizer (e.g. the
    compression transform from repro.distributed.compression)."""

    fn: Callable[[Any, Any], tuple]  # (grads, transform_state) -> (grads, state)
    init: Callable[[Any], Any]       # params -> transform_state


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Schedule = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_transform: Optional[GradientTransform] = None

    def init(self, params) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
            "grad_norm": jnp.zeros((), jnp.float32),
        }
        if self.grad_transform is not None:
            state["transform"] = self.grad_transform.init(params)
        return state

    def _lr_at(self, count):
        return self.lr(count) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state, params):
        if self.grad_transform is not None:
            grads, tstate = self.grad_transform.fn(grads, state["transform"])
        else:
            tstate = None

        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        count = state["count"] + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self._lr_at(count)

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = self.b1 * mu + (1 - self.b1) * g32
            nu = self.b2 * nu + (1 - self.b2) * jnp.square(g32)
            mhat = mu / b1c
            nhat = nu / b2c
            step = mhat / (jnp.sqrt(nhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), mu, nu

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_state = {
            "mu": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
            "nu": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
            "count": count,
            "grad_norm": gnorm,
        }
        if tstate is not None:
            new_state["transform"] = tstate
        return updates, new_state

    @staticmethod
    def last_grad_norm(state) -> jax.Array:
        return state["grad_norm"]
