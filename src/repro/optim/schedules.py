"""Learning-rate schedules (callables: step -> lr)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["linear_warmup", "cosine_schedule"]


def linear_warmup(base_lr: float, warmup_steps: int):
    def lr(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(1, warmup_steps), 1.0)
        return base_lr * frac

    return lr


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(1, warmup_steps), 1.0)
        progress = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return base_lr * warm * cos

    return lr
