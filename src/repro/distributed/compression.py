"""Gradient compression with error feedback (distributed-optimization trick).

Two layers:

1. ``compression_transform(bits)`` — a GradientTransform for the optimizer:
   quantizes gradients to int8 (per-leaf scale) and carries the quantization
   residual in an error-feedback buffer (1-bit-Adam-style), so the long-run
   bias vanishes.  This is the numerics of compressed data-parallel training,
   independent of where the collective runs.

2. ``compressed_psum(x, axis)`` — a shard_map building block that all-reduces
   an int8-quantized tensor over a mesh axis and rescales, cutting DP
   gradient-sync bytes 4x vs f32 (2x vs bf16).  Used by the shard_map DP
   demo in tests/test_compression.py; the jit+GSPMD path keeps XLA's fused
   all-reduces and applies (1) only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import GradientTransform

__all__ = ["compression_transform", "quantize_int8", "dequantize_int8", "compressed_psum"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compression_transform(enabled: bool = True) -> GradientTransform:
    """Int8 gradient quantization with per-leaf error feedback."""

    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def fn(grads, err):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, scale = quantize_int8(g32)
            deq = dequantize_int8(q, scale)
            return deq.astype(g.dtype), g32 - deq

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(err)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_g, new_e

    return GradientTransform(fn=fn, init=init)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """All-reduce mean with int8 payload (inside shard_map).

    Each participant quantizes locally; scales are maxed across the axis so
    the int8 sum cannot overflow int32 accumulation.
    """
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    scale = jax.lax.pmax(scale, axis)          # shared scale
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(x.dtype)
