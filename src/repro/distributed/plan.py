"""`ShardingPlan` — the distributed layer as a first-class, declarative object.

Historically the distributed stack was three loosely-coupled pieces: mesh
construction in ``launch/mesh.py``, a ``ShardingPolicy`` whose
``param_pspec`` walked template leaf *names* through an if/elif ladder, and
``with_sharding_constraint`` hooks threaded as bare callbacks.  This module
unifies them:

* **Mesh construction** — :func:`make_production_mesh` / :func:`make_local_mesh`
  live here (``repro.launch`` re-exports them for launcher convenience).
* **Per-weight partition decisions** — the leaf-name ladder is now the
  declarative :data:`LAYER_RULES` table (name -> role); a role resolves to a
  concrete :class:`WeightPlan` (column / row / replicated + the mesh axes it
  uses) against this plan's mesh.
* **Plan metadata on the weights themselves** — :meth:`ShardingPlan.attach_params`
  stamps each ``DipWeight`` / ``QuantizedDipWeight`` with its
  :class:`WeightPlan` (static pytree aux data), so the decision survives
  ``jit`` / ``scan`` / checkpoint round-trips and ``api.matmul`` can dispatch
  on ``(weight.plan, backend, epilogue)``: the explicit ``shard_map``
  backends (``dip_tp`` / ``dip_sp`` / ``dip_fsdp`` / ``dip_ep``, see
  ``kernels/dip_matmul_sharded.py``) consume it, and a weight with no plan
  decomposes to the implicit GSPMD-on-xla path unchanged.

Mesh convention (unchanged):
    single-pod : (16, 16)      axes ("data", "model")
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model")

Parallelism mapping:
    batch          -> ("pod", "data")   pure DP across pods (DCN), DP within
                                        a pod (ICI)
    FSDP (ZeRO-3)  -> "data"            params + optimizer moments sharded on
                                        a non-TP dim; all-gathers stay on ICI
    TP             -> "model"           column/row-parallel pairs; MoE
                                        experts (EP) also live on "model"
    SP             -> "model"           sequence sharding for decode KV caches
                                        and archs whose head count does not
                                        divide the TP size

Divisibility fallbacks are *surfaced*: a leaf whose dimension does not
divide the mesh axis replicates (as before) but now warns once with the
leaf name and axis sizes; ``strict=True`` raises instead.  See
``docs/distributed.md``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Set, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import DipWeight, QuantizedDipWeight

__all__ = [
    "WeightPlan",
    "LAYER_RULES",
    "ShardingPlan",
    "make_plan",
    "make_production_mesh",
    "make_local_mesh",
    "STRATEGIES",
]

# plan strategies an ArchConfig.sharding field can declare
STRATEGIES = ("gspmd", "tp", "fsdp", "sp", "ep", "pp")


# --------------------------------------------------------------------------
# mesh construction
def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Target topology: one v5e pod slice of 256 chips (16x16), or two pods.

    Axes: "data" carries DP+FSDP, "model" carries TP/EP/SP; "pod" (multi-pod)
    carries pure DP across the DCN link.  Kept as a function (never a
    module-level constant) so importing this module never touches jax device
    state.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, stage: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples).

    ``stage > 1`` prepends a pipeline "stage" axis (GPipe microbatching via
    ``distributed.pipeline``); the 2-axis shape is kept when absent so
    existing checkpoint manifests round-trip unchanged."""
    if stage > 1:
        return jax.make_mesh((stage, data, model), ("stage", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# --------------------------------------------------------------------------
# per-weight partition decisions
@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class WeightPlan:
    """One weight's partition decision, carried as static pytree metadata.

    ``kind`` is the tensor-parallel role of the 2-D (d_in, d_out) storage:

        column      d_out sharded over ``axis``  (wq/wk/wv/w_gate/w_up/...)
        row         d_in  sharded over ``axis``  (wo/w_down/out_proj/...)
        replicated  no TP sharding
        expert      MoE expert banks: the EXPERT dim sharded over ``axis``;
                    ``models.moe.moe_ffn`` keys its all-to-all token
                    dispatch/combine off this kind (expert parallelism)

    ``fsdp`` names the ZeRO-3 axis the complementary dim (and the ``dip_fsdp``
    backend's K split) shards over.  ``mesh`` is the mesh the decision was
    made against — hashable, so the whole object rides as jit-static aux data
    on ``DipWeight`` / ``QuantizedDipWeight`` and survives ``jit`` / ``scan``
    / ``grad``; checkpoints serialize :meth:`describe` (devices excluded) and
    restore validates it against the live mesh.
    """

    kind: str = "replicated"
    axis: Optional[str] = None
    fsdp: Optional[str] = None
    mesh: Optional[Mesh] = None

    def __post_init__(self):
        if self.kind not in ("column", "row", "replicated", "expert"):
            raise ValueError(
                f"WeightPlan.kind must be column | row | replicated | "
                f"expert, got {self.kind!r}"
            )

    def axis_size(self, name: Optional[str]) -> int:
        if name is None or self.mesh is None or name not in self.mesh.shape:
            return 1
        return int(self.mesh.shape[name])

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.axis)

    @property
    def fsdp_size(self) -> int:
        return self.axis_size(self.fsdp)

    def describe(self) -> Dict[str, Any]:
        """JSON-safe manifest form (mesh reduced to its axis sizes)."""
        return {
            "kind": self.kind,
            "axis": self.axis,
            "fsdp": self.fsdp,
            "mesh_axes": (
                {str(k): int(v) for k, v in self.mesh.shape.items()}
                if self.mesh is not None else None
            ),
        }

    def __repr__(self) -> str:  # keep DipWeight reprs readable
        parts = [self.kind]
        if self.axis:
            parts.append(f"axis={self.axis}:{self.tp_size}")
        if self.fsdp:
            parts.append(f"fsdp={self.fsdp}:{self.fsdp_size}")
        return f"WeightPlan({', '.join(parts)})"


# Declarative per-layer rules: template leaf name -> partition role.  This
# table IS the old ``ShardingPolicy.param_pspec`` name ladder, lifted into
# data; :meth:`ShardingPlan.param_pspec` interprets a role against the mesh.
# ``w_gate``/``w_up``/``w_down`` with a 4-D (stacked expert-bank) shape
# resolve to "expert_bank" regardless of this table.
LAYER_RULES: Dict[str, str] = {
    # non-stacked globals
    "embed": "embed",
    "lm_head": "lm_head",
    "final_norm": "replicated",
    # column-parallel projections (d_out over TP, d_in over FSDP)
    "wq": "column", "wk": "column", "wv": "column",
    "w_gate": "column", "w_up": "column",
    "in_proj": "column", "w_dkv": "column", "w_krope": "column",
    "w_uk": "column", "w_uv": "column",
    "shared_w_gate": "column", "shared_w_up": "column",
    # row-parallel projections (d_in over TP, d_out over FSDP)
    "wo": "row", "w_down": "row",
    "out_proj": "row", "shared_w_down": "row",
    # MoE router: FSDP only (tiny, but mirrors the residual stream width)
    "router": "router",
    # biases follow their matmul's output sharding
    "bq": "bias_out", "bk": "bias_out", "bv": "bias_out",
    # SSM per-channel / per-head vectors
    "conv_w": "conv",
    "conv_b": "vector_tp", "norm": "vector_tp",
    "dt_bias": "vector_tp", "A_log": "vector_tp", "D": "vector_tp",
}

_TP_KINDS = {"column": "column", "row": "row"}


def _rule_for(name: Optional[str], shape: Tuple[int, ...]) -> str:
    if name in ("w_gate", "w_up", "w_down") and len(shape) == 4:
        return "expert_bank"
    return LAYER_RULES.get(name, "replicated")


# warn-once registry for divisibility fallbacks (satellite bugfix: the old
# policy replicated mis-sized leaves silently)
_WARNED: Set[Tuple] = set()


def _surface_fallback(leaf: str, dim: int, axis: str, size: int,
                      strict: bool) -> None:
    msg = (
        f"ShardingPlan: leaf {leaf!r} dim {dim} does not divide mesh axis "
        f"{axis!r}={size}; replicating instead of sharding"
    )
    if strict:
        raise ValueError(msg + " (strict=True)")
    key = (leaf, dim, axis, size)
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(msg, UserWarning, stacklevel=3)


# --------------------------------------------------------------------------
@dataclasses.dataclass
class ShardingPlan:
    """Mesh + declarative partition rules + activation constraints, unified.

    The one object the runtime layers thread: models take ``plan=`` (its
    :meth:`constrain` replaces the bare callback), trainers/servers attach it
    to parameters (:meth:`attach_params`), the dry-run lowers against its
    shardings, and checkpoints validate against it on restore.

    ``strategy`` (from ``cfg.sharding``) declares how DiP projections
    execute: ``"gspmd"`` (implicit — XLA partitions the plain dot),
    ``"tp"`` (explicit column/row shard_map kernels via the ``dip_tp``
    backend), ``"fsdp"`` (explicit K-sharded all-gather-on-load via
    ``dip_fsdp``), ``"sp"`` (sequence parallel: ``dip_sp`` ring-streamed
    column loads + reduce_scatter rows), ``"ep"`` (expert parallel: dense
    projections via ``dip_ep`` — same placement as ``dip_tp`` — and MoE
    expert banks dispatched over the model axis with paired all-to-alls,
    keyed off :attr:`expert_plan`), ``"pp"`` (pipeline stages over a
    "stage" mesh axis — GPipe microbatching through
    ``distributed.pipeline``).  ``strict=True`` turns divisibility
    fallbacks into errors.
    """

    mesh: Mesh
    cfg: Any
    mode: str                     # train | prefill | decode
    seq_parallel: bool = True     # Megatron-SP residual-stream sharding
    strict: bool = False          # raise (not warn) on divisibility fallback
    # derived axis groupings
    dp: Tuple[str, ...] = ()      # batch axes
    fsdp: Optional[str] = None    # parameter shard axis
    tp: Optional[str] = None      # tensor/expert axis
    stage: Optional[str] = None   # pipeline stage axis
    stages: int = 1               # pipeline depth (1 = no pipelining)

    def __post_init__(self):
        names = self.mesh.axis_names
        self.dp = tuple(a for a in ("pod", "data") if a in names)
        self.fsdp = "data" if "data" in names else None
        self.tp = "model" if "model" in names else None
        self.stage = "stage" if "stage" in names else None
        self.stages = int(self.mesh.shape[self.stage]) if self.stage else 1
        strategy = self.strategy
        if strategy not in STRATEGIES:
            raise ValueError(
                f"unknown sharding strategy {strategy!r} "
                f"(cfg.sharding); supported: {STRATEGIES}"
            )
        if strategy == "pp" and self.stages < 2:
            raise ValueError(
                "sharding='pp' needs a mesh with a 'stage' axis of size "
                ">= 2 (make_local_mesh(stage=...))"
            )

    # ---------------------------------------------------------- strategy ---
    @property
    def strategy(self) -> str:
        return getattr(self.cfg, "sharding", "gspmd") or "gspmd"

    @property
    def explicit_backend(self) -> Optional[str]:
        """Registered sharded backend this strategy routes DiP projections
        through (None for the implicit GSPMD path; pp stages run whatever
        backend the config names inside each stage)."""
        return {"tp": "dip_tp", "fsdp": "dip_fsdp", "sp": "dip_sp",
                "ep": "dip_ep", "pp": None, "gspmd": None}[self.strategy]

    @property
    def expert_plan(self) -> Optional[WeightPlan]:
        """The ``WeightPlan(kind="expert")`` MoE expert banks dispatch on
        under the ep strategy (expert dim over the model axis); None
        otherwise, which keeps ``moe_ffn`` on its dense-style path."""
        if self.strategy != "ep" or not self.tp:
            return None
        return WeightPlan(kind="expert", axis=self.tp, fsdp=None,
                          mesh=self.mesh)

    # ---------------------------------------------------------- helpers ----
    def _tp_if(self, n: int, leaf: Optional[str] = None) -> Optional[str]:
        return self._axis_if(self.tp, n, leaf)

    def _fsdp_if(self, n: int, leaf: Optional[str] = None) -> Optional[str]:
        return self._axis_if(self.fsdp, n, leaf)

    def _axis_if(self, axis: Optional[str], n: int,
                 leaf: Optional[str]) -> Optional[str]:
        if not axis or axis not in self.mesh.shape:
            return None
        if n % self.mesh.shape[axis] == 0:
            return axis
        # mis-sized: replicate, but SAY so for named param leaves (activation
        # / cache fallbacks are expected steady-state, e.g. ragged heads)
        if leaf is not None:
            _surface_fallback(leaf, n, axis, self.mesh.shape[axis], self.strict)
        return None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def dp_for(self, n: int) -> Tuple[str, ...]:
        """Largest prefix of the DP axes whose product divides ``n``
        (batch=1 long-context cells replicate instead of failing)."""
        axes = []
        prod = 1
        for a in self.dp:
            if n % (prod * self.mesh.shape[a]) == 0:
                axes.append(a)
                prod *= self.mesh.shape[a]
        return tuple(axes)

    @property
    def heads_on_tp(self) -> bool:
        """Can attention shard heads over the TP axis (both q and kv)?"""
        cfg = self.cfg
        if not cfg.n_heads or not self.tp:
            return False
        tp = self.mesh.shape[self.tp]
        if self.mode == "decode":
            return cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0
        return cfg.n_heads % tp == 0

    # ------------------------------------------------------------ params ---
    def param_pspec(self, name: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for a template leaf, resolved through LAYER_RULES
        (layer-stacked shapes included)."""
        rule = _rule_for(name, shape)
        stacked = rule not in ("embed", "lm_head") and name != "final_norm" \
            and len(shape) >= 1
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape  # strip layer axis

        if rule == "embed":
            return P(self._tp_if(shape[0], name), self._fsdp_if(shape[1], name))
        if rule == "lm_head":
            # vocab over BOTH axes: fully-sharded weight AND no contraction
            # psum (the d dim stays unsharded) — the logits come out already
            # vocab-sharded.  padded_vocab guarantees divisibility.
            combo = tuple(a for a in (self.fsdp, self.tp) if a)
            size = 1
            for a in combo:
                size *= self.mesh.shape[a]
            if combo and shape[1] % size == 0:
                return P(None, combo)
            return P(self._fsdp_if(shape[0], name), self._tp_if(shape[1], name))
        if rule == "expert_bank":   # (L, E, d, ffe) / (L, E, ffe, d)
            return P(*lead, self._tp_if(body[0], name),
                     self._fsdp_if(body[1], name), None)
        if rule == "router":
            return P(*lead, self._fsdp_if(body[0], name), None)
        if rule == "column":
            if len(body) != 2:
                return P(*lead, *([None] * len(body)))
            return P(*lead, self._fsdp_if(body[0], name),
                     self._tp_if(body[1], name))
        if rule == "row":
            if len(body) != 2:
                return P(*lead, *([None] * len(body)))
            return P(*lead, self._tp_if(body[0], name),
                     self._fsdp_if(body[1], name))
        if rule == "bias_out":
            return P(*lead, self._tp_if(body[0], name))
        if rule == "conv":
            return P(*lead, None, self._tp_if(body[1], name))
        if rule == "vector_tp":
            return P(*lead, self._tp_if(body[0], name))
        # norms and anything unknown: replicated (layer-stacked)
        return P(*lead, *([None] * len(body)))

    def weight_plan(self, name: str, storage_shape: Tuple[int, ...],
                    perm_tile: int) -> WeightPlan:
        """The :class:`WeightPlan` a DiP-stored linear should carry.

        The explicit backends shard *storage* dims (Kp / Np, padded to the
        permutation-tile grid), so the decision is checked against those:
        the sharded dim must divide the axis AND leave perm-tile-aligned
        shards (each shard must itself be valid permutated storage).  A
        mis-sized dim degrades to ``replicated`` — warned once, or raised
        under ``strict``.
        """
        rule = _rule_for(name, storage_shape)
        # lm_head is column-parallel for the explicit backends (vocab is its
        # N dim); the GSPMD pspec keeps the richer vocab-over-both-axes rule
        kind = _TP_KINDS.get(rule, "column" if rule == "lm_head" else "replicated")
        kp, np_ = int(storage_shape[-2]), int(storage_shape[-1])
        if kind != "replicated" and self.tp:
            tp = self.mesh.shape[self.tp]
            dim = np_ if kind == "column" else kp
            if dim % tp != 0 or (dim // tp) % perm_tile != 0:
                _surface_fallback(name, dim, self.tp, tp, self.strict)
                kind = "replicated"
        fsdp = self.fsdp
        if fsdp and kp % self.mesh.shape[fsdp] != 0:
            _surface_fallback(name, kp, fsdp, self.mesh.shape[fsdp], self.strict)
            fsdp = None
        return WeightPlan(
            kind=kind,
            axis=self.tp if kind != "replicated" else None,
            fsdp=fsdp,
            mesh=self.mesh,
        )

    def attach_params(self, tree: Any) -> Any:
        """Stamp every ``DipWeight`` / ``QuantizedDipWeight`` node with its
        :class:`WeightPlan` (payloads untouched — works on params, specs, or
        shardings).  Run once at init / checkpoint load; the metadata then
        rides through jit/scan/checkpoint, and ``api.matmul`` dispatches the
        explicit sharded backends off it."""
        dip_types = (DipWeight, QuantizedDipWeight)

        def walk(t, name=None):
            if isinstance(t, dict):
                return {k: walk(v, k) for k, v in t.items()}
            if isinstance(t, dip_types):
                return t.with_plan(
                    self.weight_plan(name, tuple(t.data.shape), t.perm_tile)
                )
            return t

        return walk(tree)

    def param_shardings(self, template: Dict[str, Any]) -> Dict[str, Any]:
        """NamedSharding pytree matching repro.models.transformer.param_template.

        Accepts the template (tuple leaves, DiP linears carrying a
        ``dip_meta`` 4th element), materialized params, or spec pytrees.
        ``DipWeight`` nodes come back as ``DipWeight``-wrapped shardings with
        identical metadata (the attached :class:`WeightPlan` included), so
        ``tree_map(device_put, params, shardings)`` traverses both trees in
        lockstep.  The DiP permutation is tile-local (64x64), so the storage
        dims shard exactly like natural dims.
        """

        def walk(t, name=None):
            if isinstance(t, dict):
                return {k: walk(v, k) for k, v in t.items()}
            if isinstance(t, QuantizedDipWeight):
                spec = self.param_pspec(name, tuple(t.data.shape))
                # per-output-channel scales follow the storage's N sharding;
                # the broadcast K dim (width 1) stays unsharded
                scale_spec = P(*spec[:-2], None, spec[-1])
                return t.with_data(self.named(spec), self.named(scale_spec),
                                   checksum=self._checksum_shardings(t))
            if isinstance(t, DipWeight):
                return t.with_data(
                    self.named(self.param_pspec(name, tuple(t.data.shape))),
                    checksum=self._checksum_shardings(t),
                )
            if isinstance(t, tuple):
                shape = t[0]
                dip = t[3] if len(t) > 3 else None
                ns = self.named(self.param_pspec(name, tuple(shape)))
                return DipWeight(ns, *dip) if dip is not None else ns
            return self.named(self.param_pspec(name, tuple(t.shape)))

        return walk(template)

    def _checksum_shardings(self, w):
        """Replicated shardings matching an attached ABFT checksum child (its
        vectors are O(K)+O(N) — not worth sharding) so checksum-carrying
        weights traverse ``tree_map(device_put, params, shardings)`` in
        lockstep; ``None`` stays ``None``."""
        if getattr(w, "checksum", None) is None:
            return None
        return jax.tree_util.tree_map(lambda _: self.named(P()), w.checksum)

    # ------------------------------------------------------------- batch ---
    def batch_pspec(self) -> Dict[str, P]:
        dp = P(self.dp) if self.dp else P()
        return {
            "tokens": P(self.dp, None),
            "labels": P(self.dp, None),
            "embeddings": P(self.dp, None, None),
            "_dp": dp,
        }

    # ------------------------------------------------------------- cache ---
    def cache_pspec(self, name: str, shape: Tuple[int, ...]) -> P:
        """KV/SSM cache leaves (layer-stacked: leading n_layers axis)."""
        bspec = self.dp_for(shape[1]) or None  # batch dim follows the layer axis

        if name in ("k", "v"):  # (L, B, S, KV, hd)
            if self.heads_on_tp:
                return P(None, bspec, None, self.tp, None)
            # sequence-parallel cache (flash-decode): shard the seq dim
            return P(None, bspec, self._tp_if(shape[2]), None, None)
        if name in ("c_kv", "k_rope"):  # (L, B, S, r)
            return P(None, bspec, self._tp_if(shape[2]), None)
        if name == "state":  # (L, B, H, P, N)
            return P(None, bspec, self._tp_if(shape[2]), None, None)
        if name == "conv":  # (L, B, K-1, conv_dim)
            return P(None, bspec, None, self._tp_if(shape[3]))
        return P(*([None] * len(shape)))

    def cache_shardings(self, cache_shapes: Dict[str, Any]) -> Dict[str, Any]:
        def walk(t, name=None):
            if isinstance(t, dict):
                return {k: walk(v, k) for k, v in t.items()}
            return self.named(self.cache_pspec(name, tuple(t.shape)))

        return walk(cache_shapes)

    def paged_cache_pspec(self, name: str, shape: Tuple[int, ...]) -> P:
        """Paged serving-cache leaves (``transformer.init_paged_cache``).

        Pools are (L, num_blocks, block_size, ...): the block and in-block
        token dims are *addresses*, never sharded — each device holds every
        block's rows for its head shard, so a decode step's gather is purely
        local.  KV heads shard over TP exactly like the contiguous decode
        cache when divisible; the MLA latent (no head axis) and the
        per-slot SSM pools follow their contiguous rules.
        """
        if name in ("k", "v"):            # (L, nb, bs, KV, hd)
            if self.heads_on_tp:
                return P(None, None, None, self.tp, None)
            return P(*([None] * len(shape)))
        if name in ("k_scale", "v_scale"):  # (L, nb, bs, KV)
            if self.heads_on_tp:
                return P(None, None, None, self.tp)
            return P(*([None] * len(shape)))
        if name == "state":               # (L, slots, H, P, N)
            return P(None, None, self._tp_if(shape[2]), None, None)
        if name == "conv":                # (L, slots, K-1, conv_dim)
            return P(None, None, None, self._tp_if(shape[3]))
        # c_kv / k_rope latents and their scales: replicated (rank is small
        # and the absorbed einsums want the full latent per device)
        return P(*([None] * len(shape)))

    def paged_cache_shardings(self, cache_shapes: Dict[str, Any]) -> Dict[str, Any]:
        def walk(t, name=None):
            if isinstance(t, dict):
                return {k: walk(v, k) for k, v in t.items()}
            return self.named(self.paged_cache_pspec(name, tuple(t.shape)))

        return walk(cache_shapes)

    # -------------------------------------------------------- activations --
    def constrain(self, x: jax.Array, tag: str) -> jax.Array:
        mesh = self.mesh
        if mesh.empty or not self.dp:
            return x
        tp = self.tp
        dp = self.dp_for(x.shape[0]) or None
        try:
            if tag == "act_btd":
                # Megatron-style sequence parallelism: the residual stream
                # (saved per scanned layer for backward) is sharded along seq
                # over the TP axis in train/prefill — 16x less live activation
                # memory; GSPMD inserts the all-gather at each projection.
                if self.seq_parallel and self.mode != "decode" and self._tp_if(x.shape[1]):
                    spec = P(dp, self.tp, None)
                else:
                    spec = P(dp, None, None)
            elif tag == "q_bthd":
                heads = x.shape[2]
                if heads % mesh.shape[tp] == 0:
                    spec = P(dp, None, tp, None)
                else:
                    spec = P(dp, self._tp_if(x.shape[1]), None, None)  # SP fallback
            elif tag == "kv_bthd":
                heads = x.shape[2]
                if heads % mesh.shape[tp] == 0:
                    spec = P(dp, None, tp, None)
                else:
                    # small kv tensors replicate over TP; the broadcast-to-h
                    # expansion in attention_core re-shards them on the head
                    # axis locally (no collective)
                    spec = P(dp, None, None, None)
            elif tag == "cache_bshd":
                if self.heads_on_tp:
                    spec = P(dp, None, tp, None)
                else:
                    spec = P(dp, self._tp_if(x.shape[1]), None, None)
            elif tag == "cache_bsr":
                spec = P(dp, self._tp_if(x.shape[1]), None)
            elif tag == "logits":
                # leave to propagation: the lm_head weight's vocab sharding
                # (data x model) determines the cheapest logits layout, and
                # the loss reduction is sharding-agnostic
                return x
            elif tag == "ffn_hidden":
                spec = P(dp, None, self._tp_if(x.shape[-1]))
            elif tag in ("expert_buf", "expert_hidden"):
                # (B, E, C, d/ffe): groups over DP, experts over TP
                spec = P(dp, self._tp_if(x.shape[1]), None, None)
            elif tag == "ssm_inner":
                spec = P(dp, None, self._tp_if(x.shape[-1]))
            elif tag == "scores":
                # (b, h, sq, sk): shard heads when divisible, else q-positions
                h = x.shape[1]
                if h % mesh.shape[tp] == 0:
                    spec = P(dp, tp, None, None)
                else:
                    spec = P(dp, None, self._tp_if(x.shape[2]), None)
            else:
                return x
        except (KeyError, TypeError):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_plan(mesh: Mesh, cfg, mode: str, **opts) -> ShardingPlan:
    """Build the plan for one (mesh, config, phase) triple.

    ``opts``: ``seq_parallel`` / ``strict`` — see :class:`ShardingPlan`.
    """
    return ShardingPlan(mesh=mesh, cfg=cfg, mode=mode, **opts)
