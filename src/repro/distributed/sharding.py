"""Sharding policy: DP / FSDP / TP / EP / SP rules for every pytree in the
system (params, optimizer state, batches, KV caches, activations).

Mesh convention (launch/mesh.py):
    single-pod : (16, 16)      axes ("data", "model")
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model")

Parallelism mapping:
    batch          -> ("pod", "data")          pure DP across pods (DCN), DP
                                               within a pod (ICI)
    FSDP (ZeRO-3)  -> "data"                   params + optimizer moments
                                               sharded on a non-TP dim;
                                               all-gathers stay on ICI
    TP             -> "model"                  column/row-parallel pairs;
                                               MoE experts (EP) also live on
                                               "model"
    SP             -> "model"                  sequence sharding for decode KV
                                               caches (flash-decode combine)
                                               and for archs whose head count
                                               does not divide the TP size

The policy is *declarative*: `param_pspec` maps template leaf names to
PartitionSpecs; `constrain` maps semantic activation tags (see
repro.models.attention) to with_sharding_constraint calls.  All rules degrade
to divisibility-checked fallbacks (replicate rather than fail).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import DipWeight, QuantizedDipWeight

__all__ = ["ShardingPolicy", "make_policy"]


def _divisible(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    if axis is None:
        return True
    return axis in mesh.shape and n % mesh.shape[axis] == 0


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    cfg: Any
    mode: str                     # train | prefill | decode
    seq_parallel: bool = True     # Megatron-SP residual-stream sharding
    # derived axis groupings
    dp: Tuple[str, ...] = ()      # batch axes
    fsdp: Optional[str] = None    # parameter shard axis
    tp: Optional[str] = None      # tensor/expert axis

    def __post_init__(self):
        names = self.mesh.axis_names
        self.dp = tuple(a for a in ("pod", "data") if a in names)
        self.fsdp = "data" if "data" in names else None
        self.tp = "model" if "model" in names else None

    # ---------------------------------------------------------- helpers ----
    def _tp_if(self, n: int) -> Optional[str]:
        return self.tp if self.tp and _divisible(n, self.mesh, self.tp) else None

    def _fsdp_if(self, n: int) -> Optional[str]:
        return self.fsdp if self.fsdp and _divisible(n, self.mesh, self.fsdp) else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def dp_for(self, n: int) -> Tuple[str, ...]:
        """Largest prefix of the DP axes whose product divides ``n``
        (batch=1 long-context cells replicate instead of failing)."""
        axes = []
        prod = 1
        for a in self.dp:
            if n % (prod * self.mesh.shape[a]) == 0:
                axes.append(a)
                prod *= self.mesh.shape[a]
        return tuple(axes)

    @property
    def heads_on_tp(self) -> bool:
        """Can attention shard heads over the TP axis (both q and kv)?"""
        cfg = self.cfg
        if not cfg.n_heads or not self.tp:
            return False
        tp = self.mesh.shape[self.tp]
        if self.mode == "decode":
            return cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0
        return cfg.n_heads % tp == 0

    # ------------------------------------------------------------ params ---
    def param_pspec(self, name: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for a template leaf (layer-stacked shapes included)."""
        cfg = self.cfg
        stacked = name not in ("embed", "lm_head", "final_norm") and len(shape) >= 1
        lead = (None,) if stacked and name not in ("attn_norm_shared",) else ()

        def col(d_in, d_out):  # column-parallel matmul weight (d_in, d_out)
            return P(*lead, self._fsdp_if(d_in), self._tp_if(d_out))

        def row(d_in, d_out):  # row-parallel
            return P(*lead, self._tp_if(d_in), self._fsdp_if(d_out))

        if name == "embed":
            return P(self._tp_if(shape[0]), self._fsdp_if(shape[1]))
        if name == "lm_head":
            # vocab over BOTH axes: fully-sharded weight AND no contraction
            # psum (the d dim stays unsharded) — the logits come out already
            # vocab-sharded.  padded_vocab guarantees divisibility.
            combo = tuple(a for a in (self.fsdp, self.tp) if a)
            size = 1
            for a in combo:
                size *= self.mesh.shape[a]
            if combo and shape[1] % size == 0:
                return P(None, combo)
            return P(self._fsdp_if(shape[0]), self._tp_if(shape[1]))
        if name == "final_norm":
            return P(None)

        body = shape[1:] if stacked else shape  # strip layer axis
        # --- MoE expert banks: (L, E, d, ffe) / (L, E, ffe, d) ---
        if name in ("w_gate", "w_up", "w_down") and len(shape) == 4:
            e = body[0]
            return P(*lead, self._tp_if(e), self._fsdp_if(body[1]), None)
        if name == "router":
            return P(*lead, self._fsdp_if(body[0]), None)
        # --- column-parallel projections ---
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_dkv",
                    "w_krope", "w_uk", "w_uv", "shared_w_gate", "shared_w_up"):
            if len(body) != 2:
                return P(*lead, *([None] * len(body)))
            return col(*body)
        # --- row-parallel projections ---
        if name in ("wo", "w_down", "out_proj", "shared_w_down"):
            if len(body) != 2:
                return P(*lead, *([None] * len(body)))
            return row(*body)
        # --- biases follow their matmul's output sharding ---
        if name in ("bq", "bk", "bv"):
            return P(*lead, self._tp_if(body[0]))
        # --- SSM per-channel / per-head vectors ---
        if name in ("conv_w",):
            return P(*lead, None, self._tp_if(body[1]))
        if name in ("conv_b", "norm"):
            return P(*lead, self._tp_if(body[0]))
        if name in ("dt_bias", "A_log", "D"):
            return P(*lead, self._tp_if(body[0]))
        # norms and anything unknown: replicated (layer-stacked)
        return P(*lead, *([None] * len(body)))

    def param_shardings(self, template: Dict[str, Any]) -> Dict[str, Any]:
        """NamedSharding pytree matching repro.models.transformer.param_template.

        Accepts the template (tuple leaves, DiP linears carrying a
        ``dip_meta`` 4th element), materialized params, or spec pytrees.
        ``DipWeight`` nodes come back as ``DipWeight``-wrapped shardings with
        identical metadata, so ``tree_map(device_put, params, shardings)``
        traverses both trees in lockstep.  The DiP permutation is tile-local
        (64x64), so the storage dims shard exactly like natural dims.
        """

        def walk(t, name=None):
            if isinstance(t, dict):
                return {k: walk(v, k) for k, v in t.items()}
            if isinstance(t, QuantizedDipWeight):
                spec = self.param_pspec(name, tuple(t.data.shape))
                # per-output-channel scales follow the storage's N sharding;
                # the broadcast K dim (width 1) stays unsharded
                scale_spec = P(*spec[:-2], None, spec[-1])
                return t.with_data(self.named(spec), self.named(scale_spec))
            if isinstance(t, DipWeight):
                return t.with_data(
                    self.named(self.param_pspec(name, tuple(t.data.shape)))
                )
            if isinstance(t, tuple):
                shape = t[0]
                dip = t[3] if len(t) > 3 else None
                ns = self.named(self.param_pspec(name, tuple(shape)))
                return DipWeight(ns, *dip) if dip is not None else ns
            return self.named(self.param_pspec(name, tuple(t.shape)))

        return walk(template)

    # ------------------------------------------------------------- batch ---
    def batch_pspec(self) -> Dict[str, P]:
        dp = P(self.dp) if self.dp else P()
        return {
            "tokens": P(self.dp, None),
            "labels": P(self.dp, None),
            "embeddings": P(self.dp, None, None),
            "_dp": dp,
        }

    # ------------------------------------------------------------- cache ---
    def cache_pspec(self, name: str, shape: Tuple[int, ...]) -> P:
        """KV/SSM cache leaves (layer-stacked: leading n_layers axis)."""
        cfg = self.cfg
        bspec = self.dp_for(shape[1]) or None  # batch dim follows the layer axis

        if name in ("k", "v"):  # (L, B, S, KV, hd)
            if self.heads_on_tp:
                return P(None, bspec, None, self.tp, None)
            # sequence-parallel cache (flash-decode): shard the seq dim
            return P(None, bspec, self._tp_if(shape[2]), None, None)
        if name in ("c_kv", "k_rope"):  # (L, B, S, r)
            return P(None, bspec, self._tp_if(shape[2]), None)
        if name == "state":  # (L, B, H, P, N)
            return P(None, bspec, self._tp_if(shape[2]), None, None)
        if name == "conv":  # (L, B, K-1, conv_dim)
            return P(None, bspec, None, self._tp_if(shape[3]))
        return P(*([None] * len(shape)))

    def cache_shardings(self, cache_shapes: Dict[str, Any]) -> Dict[str, Any]:
        def walk(t, name=None):
            if isinstance(t, dict):
                return {k: walk(v, k) for k, v in t.items()}
            return self.named(self.cache_pspec(name, tuple(t.shape)))

        return walk(cache_shapes)

    # -------------------------------------------------------- activations --
    def constrain(self, x: jax.Array, tag: str) -> jax.Array:
        mesh, cfg = self.mesh, self.cfg
        if mesh.empty or not self.dp:
            return x
        tp = self.tp
        dp = self.dp_for(x.shape[0]) or None
        try:
            if tag == "act_btd":
                # Megatron-style sequence parallelism: the residual stream
                # (saved per scanned layer for backward) is sharded along seq
                # over the TP axis in train/prefill — 16x less live activation
                # memory; GSPMD inserts the all-gather at each projection.
                if self.seq_parallel and self.mode != "decode" and self._tp_if(x.shape[1]):
                    spec = P(dp, self.tp, None)
                else:
                    spec = P(dp, None, None)
            elif tag == "q_bthd":
                heads = x.shape[2]
                if heads % mesh.shape[tp] == 0:
                    spec = P(dp, None, tp, None)
                else:
                    spec = P(dp, self._tp_if(x.shape[1]), None, None)  # SP fallback
            elif tag == "kv_bthd":
                heads = x.shape[2]
                if heads % mesh.shape[tp] == 0:
                    spec = P(dp, None, tp, None)
                else:
                    # small kv tensors replicate over TP; the broadcast-to-h
                    # expansion in attention_core re-shards them on the head
                    # axis locally (no collective)
                    spec = P(dp, None, None, None)
            elif tag == "cache_bshd":
                if self.heads_on_tp:
                    spec = P(dp, None, tp, None)
                else:
                    spec = P(dp, self._tp_if(x.shape[1]), None, None)
            elif tag == "cache_bsr":
                spec = P(dp, self._tp_if(x.shape[1]), None)
            elif tag == "logits":
                # leave to propagation: the lm_head weight's vocab sharding
                # (data x model) determines the cheapest logits layout, and
                # the loss reduction is sharding-agnostic
                return x
            elif tag == "ffn_hidden":
                spec = P(dp, None, self._tp_if(x.shape[-1]))
            elif tag in ("expert_buf", "expert_hidden"):
                # (B, E, C, d/ffe): groups over DP, experts over TP
                spec = P(dp, self._tp_if(x.shape[1]), None, None)
            elif tag == "ssm_inner":
                spec = P(dp, None, self._tp_if(x.shape[-1]))
            elif tag == "scores":
                # (b, h, sq, sk): shard heads when divisible, else q-positions
                h = x.shape[1]
                if h % mesh.shape[tp] == 0:
                    spec = P(dp, tp, None, None)
                else:
                    spec = P(dp, None, self._tp_if(x.shape[2]), None)
            else:
                return x
        except (KeyError, TypeError):
            return x
        if any(s is not None for s in jax.tree_util.tree_leaves(spec)) or True:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        return x


def make_policy(mesh: Mesh, cfg, mode: str, **opts) -> ShardingPolicy:
    return ShardingPolicy(mesh=mesh, cfg=cfg, mode=mode, **opts)
