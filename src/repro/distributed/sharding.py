"""Deprecation shim (one PR): the sharding policy is now the first-class
``ShardingPlan`` in ``repro.distributed.plan``.

The old ``ShardingPolicy`` — mesh-coupled ``param_pspec`` leaf-name ladder +
``constrain`` activation hooks — was absorbed into :class:`ShardingPlan`:
the leaf walk became the declarative ``plan.LAYER_RULES`` table, mesh
construction moved in from ``launch/mesh.py``, and per-weight partition
decisions are now stamped on the weights themselves
(:meth:`ShardingPlan.attach_params`) so the explicit ``dip_tp`` /
``dip_fsdp`` backends can dispatch on them.  ``ShardingPolicy`` /
``make_policy`` remain importable aliases for existing call sites; new code
should import from ``repro.distributed.plan`` (or ``repro.distributed``).
"""

from __future__ import annotations

from repro.distributed.plan import ShardingPlan, make_plan

__all__ = ["ShardingPolicy", "make_policy"]

ShardingPolicy = ShardingPlan


def make_policy(mesh, cfg, mode: str, **opts) -> ShardingPlan:
    """Deprecated alias for :func:`repro.distributed.plan.make_plan`."""
    return make_plan(mesh, cfg, mode, **opts)
