"""Distribution layer: sharding policy (DP/FSDP/TP/EP/SP), pipeline
parallelism, and gradient compression."""

from repro.distributed.compression import compressed_psum, compression_transform
from repro.distributed.pipeline import pipeline_apply
from repro.distributed.sharding import ShardingPolicy, make_policy

__all__ = [
    "ShardingPolicy",
    "make_policy",
    "pipeline_apply",
    "compression_transform",
    "compressed_psum",
]
