"""Distribution layer: the first-class ShardingPlan (mesh construction +
declarative per-weight partition rules + activation constraints), pipeline
parallelism, and gradient compression.

See ``docs/distributed.md``: ``make_plan`` builds the plan,
``plan.attach_params`` stamps per-weight ``WeightPlan`` metadata, and the
explicit ``dip_tp`` / ``dip_fsdp`` matmul backends dispatch on it.
"""

from repro.distributed.compression import compressed_psum, compression_transform
from repro.distributed.pipeline import pipeline_apply, pipeline_train_step_fn
from repro.distributed.plan import (
    LAYER_RULES,
    ShardingPlan,
    WeightPlan,
    make_local_mesh,
    make_plan,
    make_production_mesh,
)

__all__ = [
    "ShardingPlan",
    "WeightPlan",
    "LAYER_RULES",
    "make_plan",
    "make_production_mesh",
    "make_local_mesh",
    "pipeline_apply",
    "pipeline_train_step_fn",
    "compression_transform",
    "compressed_psum",
]
