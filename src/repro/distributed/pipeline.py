"""Pipeline parallelism: GPipe-style microbatched execution over a "stage"
mesh axis using shard_map + collective_permute, with the boundary transfer
overlapped against the stage compute.

Plan-integrated: ``ShardingPlan(strategy="pp")`` carries a ``stage`` mesh
axis (``plan.stages`` devices) and :func:`pipeline_train_step_fn` builds a
drop-in replacement for ``transformer.train_step_fn`` that runs the layer
stack through :func:`pipeline_apply`.  ``runtime.Trainer`` selects it
automatically whenever ``plan.stages > 1``.

Schedule — overlapped GPipe.  The classic loop computes a microbatch and
*then* sends it, serialising the boundary transfer behind the stage compute.
Here every tick issues the ``ppermute`` of the PREVIOUS tick's output FIRST,
before the stage compute that the transfer does not depend on — so the
send/recv streams while the MXU-bound stage body runs, the same
communication-hiding argument the DiP paper makes for eliminating FIFO
stalls inside the array (docs/architecture.md).  The price is one extra
tick of latency per hop: stage ``s`` computes microbatch ``m`` at tick
``m + 2s`` (vs ``m + s`` unoverlapped), so with S stages and M microbatches
the loop runs ``M + 2(S-1)`` ticks and the bubble fraction is
``2(S-1)/(M + 2(S-1))`` — bandwidth-free ticks traded for zero exposed
transfer time on every productive tick.

The tick loop is a ``lax.scan`` (not ``fori_loop``) so the whole pipeline is
reverse-differentiable: training backprops through the scan, and each
``ppermute`` transposes to the reverse-ring ppermute, which gives the
backward pass the same overlapped boundary-transfer structure for free.

Numerics: every stage applies ``stage_fn`` exactly once per microbatch to
exactly the activation its upstream stage produced — inactive (bubble)
ticks compute on zeros and are masked out — so the output is bit-identical
to sequential application of the stages (asserted in
tests/test_multidevice.py).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.common import shard_map

__all__ = ["pipeline_apply", "pipeline_train_step_fn"]


def pipeline_apply(
    mesh: Mesh,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,            # pytree, leaves with leading axis = n_stages
    x: jax.Array,                 # (microbatches, mb_size, ...) microbatched input
    *,
    axis: str = "stage",
) -> jax.Array:
    """Run ``x`` through ``n_stages`` sequential stages, pipelined.

    ``stage_fn(params_for_stage, activation) -> activation`` must be
    shape-preserving (standard transformer-block stack semantics).
    Returns the final activations, microbatch-major, bit-identical to
    sequential application of all stages.

    Each tick's jaxpr opens with the ``ppermute`` that forwards the previous
    tick's output — issued before the stage compute so the transfer overlaps
    it (the compute reads the *prior* tick's arrival, never this tick's).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xs):
        # params: this stage's slice (leading axis 1) ; xs: (n_micro, mb, ...)
        params = jax.tree_util.tree_map(lambda t: t[0], params)
        stage_id = jax.lax.axis_index(axis)
        n_ticks = n_micro + 2 * (n_stages - 1)

        def tick(carry, t):
            recv, send, outs = carry
            # boundary transfer FIRST: forwards last tick's output, which
            # nothing below depends on — the ring hop streams under the
            # stage compute and its result is consumed only next tick
            arrived = jax.lax.ppermute(send, axis, perm_fwd)
            # stage 0 ingests microbatch t from its local input copy; every
            # other stage reads what arrived during the PREVIOUS tick
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False
            )
            cur = jnp.where(stage_id == 0, inject, recv)
            mb_idx = t - 2 * stage_id     # 2 ticks/hop: compute + in-flight
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # bubble ticks compute on zeros, not stale ring garbage: keeps
            # every masked-out value finite in forward AND backward
            cur = jnp.where(active, cur, jnp.zeros_like(cur))
            y = stage_fn(params, cur)
            y = jnp.where(active, y, cur)
            # last stage records its finished microbatch (unconditional
            # read-modify-write keeps the scan transpose simple)
            idx = jnp.clip(mb_idx, 0, n_micro - 1)
            prev = jax.lax.dynamic_index_in_dim(outs, idx, 0, keepdims=False)
            rec = jnp.where(active & (stage_id == n_stages - 1), y, prev)
            outs = jax.lax.dynamic_update_index_in_dim(outs, rec, idx, 0)
            return (arrived, y, outs), None

        zero = jnp.zeros_like(xs[0])
        (_, _, outs), _ = jax.lax.scan(
            tick,
            (zero, zero, jnp.zeros_like(xs)),
            jnp.arange(n_ticks, dtype=jnp.int32),
        )
        # broadcast the last stage's finished outputs to every stage so the
        # out_spec can be replicated over the axis (masked psum = broadcast)
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    pspec = P(axis)
    rep = P(*([None] * x.ndim))
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: pspec, stage_params), rep),
        out_specs=rep,
        check_rep=False,
    )
    return fn(stage_params, x)


def pipeline_train_step_fn(cfg, optimizer, plan, *, n_micro: int,
                           guard: bool = False):
    """Pipelined ``step(state, batch) -> (state, metrics)`` — the
    ``transformer.train_step_fn`` contract with the layer stack executed
    through :func:`pipeline_apply` over ``plan``'s stage axis.

    The embedding lookup, final norm and lm_head + cross-entropy stay
    replicated outside the stage loop (they are a sliver of the FLOPs); the
    ``n_layers`` blocks are regrouped ``L -> (stages, L/stages)`` and each
    stage scans its contiguous slice.  Covers the dense attention+FFN scan
    families; MoE stacks pipeline their dense projections but dispatch
    experts with ``dip_ep`` (mixing both axes is out of scope), and SSM
    stacks carry recurrent state that would have to thread the ring.
    """
    if getattr(plan, "stages", 1) < 2 or plan.mesh is None or plan.stage is None:
        raise ValueError(
            "pipeline_train_step_fn needs a plan with a stage axis of >= 2 "
            "devices (ShardingPlan(strategy='pp', ...))"
        )
    if cfg.ssm_state or cfg.is_moe:
        raise ValueError(
            f"{cfg.name}: pipeline stages cover the dense attention+FFN scan "
            "families (SSM state / MoE dispatch do not thread the stage ring)"
        )
    n_stages, axis, mesh = plan.stages, plan.stage, plan.mesh
    if cfg.n_layers % n_stages:
        raise ValueError(
            f"n_layers={cfg.n_layers} does not divide into {n_stages} stages"
        )
    per_stage = cfg.n_layers // n_stages

    # lazy: models.transformer imports nothing from distributed.pipeline, but
    # keeping the import inside the factory makes the no-cycle claim local
    from repro.models import layers, transformer as tf_model

    cd = jnp.dtype(cfg.compute_dtype)

    def stage_fn(sp, a):
        # positions/RoPE rebuilt per stage from the (static) microbatch
        # shape: a scan constant per stage, and no closure over tracers
        # crosses the shard_map boundary
        s = a.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        rope_dim = cfg.qk_rope_head_dim if cfg.use_mla else cfg.resolved_head_dim
        rope = layers.rope_tables(positions, rope_dim, cfg.rope_theta)

        def blk(h, lp):
            h, _, _ = tf_model._transformer_block(
                h, lp, cfg, positions=positions, rope=rope, cache=None,
                kv_chunk=0, constrain=lambda v, _name: v, plan=None,
            )
            return h, None

        h, _ = jax.lax.scan(blk, a, sp)
        return h

    def loss(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        if b % n_micro:
            raise ValueError(f"batch={b} does not divide into {n_micro} "
                             "pipeline microbatches")
        x = params["embed"].astype(cd)[tokens]
        xm = x.reshape((n_micro, b // n_micro) + x.shape[1:])
        sp = jax.tree_util.tree_map(
            lambda t: t.reshape((n_stages, per_stage) + t.shape[1:]),
            params["layers"],
        )
        h = pipeline_apply(mesh, stage_fn, sp, xm, axis=axis)
        h = h.reshape((b,) + h.shape[2:])
        h = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        if cfg.tie_embeddings:
            logits = jnp.matmul(
                h, head.astype(cd), preferred_element_type=jnp.float32
            ).astype(jnp.float32)
        else:
            logits = layers.linear(
                h, head, backend=cfg.matmul_backend, compute_dtype=cd,
            ).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            lane = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1
            )
            logits = jnp.where(lane < cfg.vocab_size, logits, -1e30)
        mask = batch.get("loss_mask")
        shift_mask = None if mask is None else mask[:, 1:]
        return layers.cross_entropy_loss(
            logits[:, :-1], batch["labels"][:, 1:], mask=shift_mask
        )

    def step(state, batch):
        params, opt_state, step_no = (
            state["params"], state["opt_state"], state["step"]
        )
        loss_v, grads = jax.value_and_grad(lambda p: loss(p, batch))(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        gnorm = optimizer.last_grad_norm(opt_state)
        new_state = {"params": params, "opt_state": opt_state,
                     "step": step_no + 1}
        return new_state, {"loss": loss_v, "grad_norm": gnorm,
                           "step": step_no + 1}

    if guard:
        from repro.reliability import guard as guard_lib  # lazy: no cycle

        return guard_lib.guarded_step_fn(step)
    return step
