"""Pipeline parallelism: GPipe-style microbatched execution over a "stage"
mesh axis using shard_map + collective_permute.

Opt-in feature (the 40-cell dry-run grid uses DP×TP, which compiles cleaner
for these depths); included because 1000+-node deployments of the deepest
assigned archs (qwen3-moe 94L) would pipeline across pods.  Tested for
equivalence against sequential execution in tests/test_pipeline.py.

Schedule: classic GPipe loop with S stages and M microbatches (M >= S).
At tick t, stage s processes microbatch t - s (if in range); activations move
stage s -> s+1 between ticks via jax.lax.ppermute.  Bubble fraction
(S-1)/(M+S-1), as usual.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(
    mesh: Mesh,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,            # pytree, leaves with leading axis = n_stages
    x: jax.Array,                 # (microbatches, mb_size, ...) microbatched input
    *,
    axis: str = "stage",
) -> jax.Array:
    """Run ``x`` through ``n_stages`` sequential stages, pipelined.

    ``stage_fn(params_for_stage, activation) -> activation`` must be
    shape-preserving (standard transformer-block stack semantics).
    Returns the final activations, microbatch-major, numerically equal to
    sequential application of all stages.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xs):
        # params: this stage's slice (leading axis 1) ; xs: (n_micro, mb, ...)
        params = jax.tree_util.tree_map(lambda t: t[0], params)
        stage_id = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1

        buf = jnp.zeros_like(xs[0])          # activation arriving this tick
        outs = jnp.zeros_like(xs)            # only stage S-1's copy is real

        def tick(t, carry):
            buf, outs = carry
            mb_idx = t - stage_id
            # stage 0 ingests microbatch t from its local input copy
            inject = jax.lax.dynamic_index_in_dim(xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            cur = jnp.where(stage_id == 0, inject, buf)
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            y = stage_fn(params, cur)
            y = jnp.where(active, y, cur)
            # last stage records its finished microbatch
            outs = jax.lax.cond(
                active & (stage_id == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_idx, 0, n_micro - 1), 0
                ),
                lambda o: o,
                outs,
            )
            # rotate activations to the next stage
            buf = jax.lax.ppermute(y, axis, perm_fwd)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # broadcast the last stage's finished outputs to every stage so the
        # out_spec can be replicated over the axis (masked psum = broadcast)
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    pspec = P(axis)
    rep = P(*([None] * x.ndim))
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: pspec, stage_params), rep),
        out_specs=rep,
        check_rep=False,
    )
    return fn(stage_params, x)
