"""Typed accelerator-abstraction boundary for the DiP reproduction.

Two first-class concepts (see ``docs/api.md``):

* :class:`DipWeight` — the paper's permutated weight layout as a registered
  pytree (storage + logical-shape metadata), consumed by checkpointing,
  sharding, autodiff, and kernel dispatch.
* :class:`QuantizedDipWeight` — the same layout at reduced precision
  (int8 / fp8 permutated storage + per-output-channel scales); built by
  ``api.quant.quantize`` and consumed natively by the ``dip_int8w`` /
  ``dip_fp8`` backends (see ``docs/quantization.md``).
* the matmul-backend registry — ``matmul(x, w, backend=...)`` dispatches to
  named, pluggable implementations (``xla`` / ``ws`` / ``pallas_dip`` /
  ``pallas_systolic`` / ``dip_int8w`` / ``dip_fp8`` / ``dip_tp`` /
  ``dip_fsdp``) with block sizes drawn from a per-shape/dtype tuning table;
  dispatch is weight-type aware, so a quantized weight routes to its
  scheme's kernel with zero call-site changes, and plan-aware, so a weight
  carrying a ``WeightPlan`` (``repro.distributed.plan``) routes to the
  explicit multi-chip shard_map backends — see ``docs/distributed.md``.
  ``matmul(..., epilogue=...)`` fuses bias / activation / SwiGLU /
  residual into the kernels' accumulator flush where the backend supports
  it and decomposes (same semantics, unfused) where it does not — see
  ``docs/api.md`` §Fused epilogues and ``kernels/epilogue.py``.
  ``matmul(..., prologue="rmsnorm")`` mirrors that on the load stage:
  the RMSNorm of x folds into the kernels' x-block load (one pallas
  launch for norm + matmul + epilogue) — see ``docs/api.md`` §Fused
  prologues and ``kernels/prologue.py``.
* the attention-backend registry — ``attention(q, k, v, backend=...)``
  dispatches flash attention (``kernels/flash_attention.py``) or the dense
  ``xla`` oracle behind one flat-layout contract with per-row traced
  ``q_offset``/``kv_len`` — see ``docs/api.md`` §The attention registry.

The tuning table is self-optimizing: ``repro.api.autotune`` (a module-level
CLI, not imported here to keep this package light) measures candidate block
geometries on the live device and persists winners to a per-device cache
that ``repro.api.tuning`` reloads on first lookup — see ``docs/tuning.md``.
"""

from repro.api.registry import (
    DEFAULT_BACKEND,
    EPILOGUES,
    PROLOGUES,
    MatmulBackend,
    backend_epilogues,
    backend_layout,
    backend_prologues,
    default_interpret,
    get_backend,
    list_backends,
    matmul,
    register_backend,
)
from repro.api.tuning import (
    BlockConfig,
    clamp_blocks,
    lookup_blocks,
    register_measured,
    register_tuning,
)
from repro.api.attention import (
    DEFAULT_ATTENTION_BACKEND,
    AttentionBackend,
    attention,
    get_attention_backend,
    list_attention_backends,
    register_attention_backend,
)
from repro.api import quant
from repro.api.quant import QuantizedDipWeight
from repro.api.weights import PERM_TILE, DipWeight, as_dip_weight

__all__ = [
    "PERM_TILE",
    "DEFAULT_BACKEND",
    "DipWeight",
    "as_dip_weight",
    "quant",
    "QuantizedDipWeight",
    "EPILOGUES",
    "PROLOGUES",
    "MatmulBackend",
    "register_backend",
    "get_backend",
    "list_backends",
    "backend_layout",
    "backend_epilogues",
    "backend_prologues",
    "matmul",
    "default_interpret",
    "AttentionBackend",
    "DEFAULT_ATTENTION_BACKEND",
    "attention",
    "register_attention_backend",
    "get_attention_backend",
    "list_attention_backends",
    "BlockConfig",
    "register_tuning",
    "register_measured",
    "lookup_blocks",
    "clamp_blocks",
]
