"""Typed accelerator-abstraction boundary for the DiP reproduction.

Two first-class concepts (see ``docs/api.md``):

* :class:`DipWeight` — the paper's permutated weight layout as a registered
  pytree (storage + logical-shape metadata), consumed by checkpointing,
  sharding, autodiff, and kernel dispatch.
* the matmul-backend registry — ``matmul(x, w, backend=...)`` dispatches to
  named, pluggable implementations (``xla`` / ``ws`` / ``pallas_dip`` /
  ``pallas_systolic``) with block sizes drawn from a per-shape/dtype tuning
  table.

The tuning table is self-optimizing: ``repro.api.autotune`` (a module-level
CLI, not imported here to keep this package light) measures candidate block
geometries on the live device and persists winners to a per-device cache
that ``repro.api.tuning`` reloads on first lookup — see ``docs/tuning.md``.
"""

from repro.api.registry import (
    DEFAULT_BACKEND,
    MatmulBackend,
    backend_layout,
    default_interpret,
    get_backend,
    list_backends,
    matmul,
    register_backend,
)
from repro.api.tuning import (
    BlockConfig,
    clamp_blocks,
    lookup_blocks,
    register_measured,
    register_tuning,
)
from repro.api.weights import PERM_TILE, DipWeight, as_dip_weight

__all__ = [
    "PERM_TILE",
    "DEFAULT_BACKEND",
    "DipWeight",
    "as_dip_weight",
    "MatmulBackend",
    "register_backend",
    "get_backend",
    "list_backends",
    "backend_layout",
    "matmul",
    "default_interpret",
    "BlockConfig",
    "register_tuning",
    "register_measured",
    "lookup_blocks",
    "clamp_blocks",
]
