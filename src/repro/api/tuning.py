"""Per-shape/dtype block-size tuning table for the matmul backends.

Replaces the hardcoded ``block_m/n/k = 256`` defaults that every kernel
wrapper used to carry.  Lookup order:

1. explicit caller override (``api.matmul(..., block_m=...)``) — never touched
2. registered tuning entries, most recently registered first, matched on
   (backend, dtype, shape bounds)
3. the built-in heuristic

Whatever the table yields is then *clamped to the problem*: a block is never
larger than the padded dimension it tiles (no point padding a (8, 64) matmul
to 256x256), never smaller than the hardware minimum (8 sublanes for M, one
permutation tile for K/N — the de-shear operates per 64-wide tile).

The autotuner (``repro.api.autotune``) writes *measured* entries through
:func:`register_measured`: exact-shape rules (min == max == the measured
problem) that outrank the heuristic built-ins, mirrored to a JSON cache on
disk (:func:`cache_path`) that reloads lazily on the first lookup so tuned
entries survive restarts.  See ``docs/tuning.md``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import warnings
from typing import List, NamedTuple, Optional, Union

import jax.numpy as jnp

from repro.api.weights import PERM_TILE

__all__ = [
    "BlockConfig",
    "TuningEntry",
    "register_tuning",
    "register_measured",
    "lookup_blocks",
    "clamp_blocks",
    "cache_path",
    "load_cache",
    "save_cache_record",
]


class BlockConfig(NamedTuple):
    block_m: int
    block_n: int
    block_k: int


@dataclasses.dataclass(frozen=True)
class TuningEntry:
    """One tuning rule: applies when every non-None constraint matches.

    ``max_*`` bound the rule from above (applies while m <= max_m, ...);
    ``min_*`` from below.  Measured entries pin both to the benchmarked
    problem so they never leak onto shapes that were not timed.

    ``epilogue`` is part of the key: a fused epilogue shifts the VMEM
    working set (a dual-weight ``swiglu`` doubles the streamed weight bytes
    and adds a second accumulator; ``residual`` streams an extra (bm, bn)
    block), so a block geometry measured unfused must not leak onto fused
    dispatches.  ``None`` matches any epilogue (heuristic built-ins);
    measured entries pin the exact epilogue they were timed with.
    """

    blocks: BlockConfig
    backend: Optional[str] = None       # None = any backend
    dtype: Optional[str] = None         # operand dtype name, None = any
    max_m: Optional[int] = None
    max_k: Optional[int] = None
    max_n: Optional[int] = None
    min_m: Optional[int] = None
    min_k: Optional[int] = None
    min_n: Optional[int] = None
    epilogue: Optional[str] = None      # None = any epilogue
    source: str = "user"                # user | measured | cache | builtin

    def matches(self, backend: str, dtype: str, m: int, k: int, n: int,
                epilogue: str = "none") -> bool:
        return (
            (self.backend is None or self.backend == backend)
            and (self.dtype is None or self.dtype == dtype)
            and (self.epilogue is None or self.epilogue == epilogue)
            and (self.max_m is None or m <= self.max_m)
            and (self.max_k is None or k <= self.max_k)
            and (self.max_n is None or n <= self.max_n)
            and (self.min_m is None or m >= self.min_m)
            and (self.min_k is None or k >= self.min_k)
            and (self.min_n is None or n >= self.min_n)
        )


_TABLE: List[TuningEntry] = []


def register_tuning(
    blocks,
    *,
    backend: Optional[str] = None,
    dtype: Optional[str] = None,
    max_m: Optional[int] = None,
    max_k: Optional[int] = None,
    max_n: Optional[int] = None,
    min_m: Optional[int] = None,
    min_k: Optional[int] = None,
    min_n: Optional[int] = None,
    epilogue: Optional[str] = None,
    source: str = "user",
) -> TuningEntry:
    """Add a tuning rule (most recently registered wins on overlap).

    The default ``source="user"`` keeps explicitly registered rules ahead
    of lazily loaded cache entries (see :func:`load_cache` precedence).
    """
    entry = TuningEntry(
        blocks=BlockConfig(*blocks), backend=backend, dtype=dtype,
        max_m=max_m, max_k=max_k, max_n=max_n,
        min_m=min_m, min_k=min_k, min_n=min_n, epilogue=epilogue,
        source=source,
    )
    _TABLE.insert(0, entry)
    return entry


def _pow2_ceil(v: int) -> int:
    return 1 << max(0, int(v) - 1).bit_length()


def clamp_blocks(
    blocks: BlockConfig, m: int, k: int, n: int, perm_tile: int = PERM_TILE
) -> BlockConfig:
    """Shrink blocks to the problem: never over-block a tiny dimension.

    K/N blocks stay multiples of the permutation tile (the in-kernel
    de-shear is per-tile) — a table entry that isn't is rounded up rather
    than poisoning every dispatch with a kernel-side ValueError; M keeps
    the 8-sublane floor.
    """
    tile_up = lambda v: v + (-v) % perm_tile
    bm = max(8, min(blocks.block_m, _pow2_ceil(m)))
    bn = tile_up(max(perm_tile, min(blocks.block_n, _pow2_ceil(n))))
    bk = tile_up(max(perm_tile, min(blocks.block_k, _pow2_ceil(k))))
    return BlockConfig(bm, bn, bk)


def lookup_blocks(
    backend: str, m: int, k: int, n: int, dtype, *, perm_tile: int = PERM_TILE,
    epilogue: str = "none",
) -> BlockConfig:
    """Resolve block sizes for one dispatch (before caller overrides)."""
    _ensure_cache_loaded()
    dtype_name = jnp.dtype(dtype).name
    for entry in _TABLE:
        if entry.matches(backend, dtype_name, m, k, n, epilogue):
            return clamp_blocks(entry.blocks, m, k, n, perm_tile)
    # heuristic fallback: MXU-aligned 256 cube, shrunk to the problem
    return clamp_blocks(BlockConfig(256, 256, 256), m, k, n, perm_tile)


# ---------------------------------------------------------------------------
# Measured-entry persistence.  The autotuner (repro.api.autotune) registers
# winners through register_measured(), which mirrors them to a JSON cache so
# a fresh process starts from the measured table instead of the heuristics.
CACHE_VERSION = 1
_CACHE_DIR_ENV = "REPRO_DIP_CACHE_DIR"        # override the cache directory
_CACHE_DISABLE_ENV = "REPRO_DIP_NO_TUNING_CACHE"  # set to skip import-time load


def _device_tag() -> str:
    """Filename-safe identifier for the device the entries were measured on
    (block-size winners do not transfer across device generations)."""
    import jax  # deferred: keep module import free of backend initialization

    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # uninitializable backend — still want a usable path
        kind = jax.default_backend()
    tag = "".join(c if c.isalnum() else "-" for c in kind.lower()).strip("-")
    return tag or "unknown"


def cache_dir() -> pathlib.Path:
    env = os.environ.get(_CACHE_DIR_ENV)
    if env:
        return pathlib.Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = pathlib.Path(xdg) if xdg else pathlib.Path.home() / ".cache"
    return base / "repro-dip"


def cache_path(path: Union[str, pathlib.Path, None] = None) -> pathlib.Path:
    """The tuning-cache file for this device (``tuning-<device>.json``)."""
    if path is not None:
        return pathlib.Path(path)
    return cache_dir() / f"tuning-{_device_tag()}.json"


def _record_key(rec: dict) -> tuple:
    # older caches predate the epilogue axis; their records were measured on
    # the unfused path, so they key (and match) as epilogue="none"
    return (rec["backend"], rec["dtype"], rec.get("epilogue", "none"),
            rec["m"], rec["k"], rec["n"])


def _read_cache(path: pathlib.Path) -> List[dict]:
    if not path.exists():
        return []
    payload = json.loads(path.read_text())
    if payload.get("version") != CACHE_VERSION:
        raise ValueError(
            f"tuning cache {path} has version {payload.get('version')!r}, "
            f"expected {CACHE_VERSION}"
        )
    return list(payload.get("entries", []))


def save_cache_record(
    rec: dict, path: Union[str, pathlib.Path, None] = None
) -> pathlib.Path:
    """Insert-or-replace one measured record (keyed on backend/dtype/shape)."""
    p = cache_path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    try:
        existing = _read_cache(p)
    except Exception as exc:
        # self-heal: a corrupt/foreign-version cache must not make every
        # future autotune run crash at persist time — start a fresh file
        warnings.warn(f"replacing unreadable tuning cache {p}: {exc}")
        existing = []
    entries = [e for e in existing if _record_key(e) != _record_key(rec)]
    entries.append(rec)
    entries.sort(key=_record_key)
    tmp = p.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(
        {"version": CACHE_VERSION, "device": _device_tag(), "entries": entries},
        indent=2, sort_keys=True,
    ) + "\n")
    tmp.replace(p)  # atomic: a concurrent reader never sees a torn file
    return p


def register_measured(
    blocks,
    *,
    backend: str,
    dtype: str,
    m: int,
    k: int,
    n: int,
    epilogue: str = "none",
    time_us: Optional[float] = None,
    persist: bool = True,
    path: Union[str, pathlib.Path, None] = None,
) -> TuningEntry:
    """Register an autotuned winner: an exact-shape (and exact-epilogue)
    rule, optionally mirrored to the on-disk cache so it survives restarts."""
    entry = register_tuning(
        blocks, backend=backend, dtype=dtype,
        max_m=m, max_k=k, max_n=n, min_m=m, min_k=k, min_n=n,
        epilogue=epilogue, source="measured",
    )
    if persist:
        bc = entry.blocks
        rec = {
            "backend": backend, "dtype": dtype, "m": m, "k": k, "n": n,
            "epilogue": epilogue,
            "block_m": bc.block_m, "block_n": bc.block_n, "block_k": bc.block_k,
        }
        if time_us is not None:
            rec["time_us"] = round(float(time_us), 3)
        save_cache_record(rec, path)
    return entry


def load_cache(path: Union[str, pathlib.Path, None] = None) -> int:
    """Register every record from the on-disk cache (newest-registered wins);
    returns the number of entries loaded.  Runs lazily on first table access
    (not at import: resolving the cache filename initializes the JAX backend,
    which importers like launch/dryrun must control themselves)."""
    p = cache_path(path)
    entries = [
        TuningEntry(
            blocks=BlockConfig(rec["block_m"], rec["block_n"], rec["block_k"]),
            backend=rec["backend"], dtype=rec["dtype"],
            max_m=rec["m"], max_k=rec["k"], max_n=rec["n"],
            min_m=rec["m"], min_k=rec["k"], min_n=rec["n"],
            epilogue=rec.get("epilogue", "none"),
            source="cache",
        )
        for rec in _read_cache(p)
    ]
    # precedence: explicitly registered rules > cached winners > built-ins
    idx = next(
        (i for i, e in enumerate(_TABLE) if e.source == "builtin"), len(_TABLE)
    )
    _TABLE[idx:idx] = entries
    return len(entries)


_CACHE_LOADED = False


def _ensure_cache_loaded() -> None:
    """Load persisted measured entries once, on the first lookup.

    Deliberately NOT at import: resolving the cache filename queries the
    device kind, which initializes the JAX backend — importers (e.g.
    launch/dryrun's XLA_FLAGS games) must stay in control of that.  Cached
    entries splice in behind explicitly registered rules, so lazy loading
    never demotes a rule the caller added before the first lookup.
    """
    global _CACHE_LOADED
    if _CACHE_LOADED or os.environ.get(_CACHE_DISABLE_ENV):
        return
    _CACHE_LOADED = True  # set first so a load failure is not retried per call
    try:
        load_cache()
    except Exception as exc:  # a corrupt cache must not break dispatch
        warnings.warn(f"ignoring unreadable tuning cache: {exc}")


# ---------------------------------------------------------------------------
# Built-in entries.  Narrower operands afford deeper K blocks at the same
# VMEM budget (acc scratch is f32/i32 at block_m x block_n regardless);
# the wavefront-emulation path tiles K/N at the physical array dimension.
register_tuning((256, 256, 256), dtype="float32", source="builtin")
register_tuning((256, 256, 512), dtype="bfloat16", source="builtin")
register_tuning((256, 256, 512), dtype="int8", source="builtin")
register_tuning((128, PERM_TILE, PERM_TILE), backend="pallas_systolic",
                source="builtin")
# quantized backends (keyed on the ACTIVATION dtype at dispatch): int8
# weight blocks are 4x narrower than f32 at the same geometry, but the
# accumulator stays int32/f32 at full (block_m x block_n) width — deepen K,
# keep the output tile at the f32 default.
register_tuning((256, 256, 512), backend="dip_int8w", source="builtin")
register_tuning((256, 256, 512), backend="dip_fp8", source="builtin")
