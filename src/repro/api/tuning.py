"""Per-shape/dtype block-size tuning table for the matmul backends.

Replaces the hardcoded ``block_m/n/k = 256`` defaults that every kernel
wrapper used to carry.  Lookup order:

1. explicit caller override (``api.matmul(..., block_m=...)``) — never touched
2. registered tuning entries, most recently registered first, matched on
   (backend, dtype, shape bounds)
3. the built-in heuristic

Whatever the table yields is then *clamped to the problem*: a block is never
larger than the padded dimension it tiles (no point padding a (8, 64) matmul
to 256x256), never smaller than the hardware minimum (8 sublanes for M, one
permutation tile for K/N — the de-shear operates per 64-wide tile).

A future autotuner (ROADMAP) writes measured entries through
:func:`register_tuning`; nothing else needs to change.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional

import jax.numpy as jnp

from repro.api.weights import PERM_TILE

__all__ = ["BlockConfig", "TuningEntry", "register_tuning", "lookup_blocks", "clamp_blocks"]


class BlockConfig(NamedTuple):
    block_m: int
    block_n: int
    block_k: int


@dataclasses.dataclass(frozen=True)
class TuningEntry:
    """One tuning rule: applies when every non-None constraint matches."""

    blocks: BlockConfig
    backend: Optional[str] = None       # None = any backend
    dtype: Optional[str] = None         # operand dtype name, None = any
    max_m: Optional[int] = None         # rule applies while m <= max_m, etc.
    max_k: Optional[int] = None
    max_n: Optional[int] = None

    def matches(self, backend: str, dtype: str, m: int, k: int, n: int) -> bool:
        return (
            (self.backend is None or self.backend == backend)
            and (self.dtype is None or self.dtype == dtype)
            and (self.max_m is None or m <= self.max_m)
            and (self.max_k is None or k <= self.max_k)
            and (self.max_n is None or n <= self.max_n)
        )


_TABLE: List[TuningEntry] = []


def register_tuning(
    blocks,
    *,
    backend: Optional[str] = None,
    dtype: Optional[str] = None,
    max_m: Optional[int] = None,
    max_k: Optional[int] = None,
    max_n: Optional[int] = None,
) -> TuningEntry:
    """Add a tuning rule (most recently registered wins on overlap)."""
    entry = TuningEntry(
        blocks=BlockConfig(*blocks), backend=backend, dtype=dtype,
        max_m=max_m, max_k=max_k, max_n=max_n,
    )
    _TABLE.insert(0, entry)
    return entry


def _pow2_ceil(v: int) -> int:
    return 1 << max(0, int(v) - 1).bit_length()


def clamp_blocks(
    blocks: BlockConfig, m: int, k: int, n: int, perm_tile: int = PERM_TILE
) -> BlockConfig:
    """Shrink blocks to the problem: never over-block a tiny dimension.

    K/N blocks stay multiples of the permutation tile (the in-kernel
    de-shear is per-tile) — a table entry that isn't is rounded up rather
    than poisoning every dispatch with a kernel-side ValueError; M keeps
    the 8-sublane floor.
    """
    tile_up = lambda v: v + (-v) % perm_tile
    bm = max(8, min(blocks.block_m, _pow2_ceil(m)))
    bn = tile_up(max(perm_tile, min(blocks.block_n, _pow2_ceil(n))))
    bk = tile_up(max(perm_tile, min(blocks.block_k, _pow2_ceil(k))))
    return BlockConfig(bm, bn, bk)


def lookup_blocks(
    backend: str, m: int, k: int, n: int, dtype, *, perm_tile: int = PERM_TILE
) -> BlockConfig:
    """Resolve block sizes for one dispatch (before caller overrides)."""
    dtype_name = jnp.dtype(dtype).name
    for entry in _TABLE:
        if entry.matches(backend, dtype_name, m, k, n):
            return clamp_blocks(entry.blocks, m, k, n, perm_tile)
    # heuristic fallback: MXU-aligned 256 cube, shrunk to the problem
    return clamp_blocks(BlockConfig(256, 256, 256), m, k, n, perm_tile)


# ---------------------------------------------------------------------------
# Built-in entries.  Narrower operands afford deeper K blocks at the same
# VMEM budget (acc scratch is f32/i32 at block_m x block_n regardless);
# the wavefront-emulation path tiles K/N at the physical array dimension.
register_tuning((256, 256, 256), dtype="float32")
register_tuning((256, 256, 512), dtype="bfloat16")
register_tuning((256, 256, 512), dtype="int8")
register_tuning((128, PERM_TILE, PERM_TILE), backend="pallas_systolic")
