"""Pluggable matmul-backend registry and the single dispatching entry point.

``matmul(x, w, *, backend=None, epilogue=None)`` is the one matmul surface
the rest of the system calls — models, serving, training, benchmarks.
Backends are registered under a name (``register_backend``) and declare the
weight layout they consume:

    layout="natural"   plain (K, N) weights; a ``DipWeight`` argument is
                       de-sheared first (a jnp gather — the distributed /
                       GSPMD-friendly path)
    layout="dip"       DiP-permutated storage; a natural array argument is
                       permutated on the fly (one-off convenience — models
                       hoist this through ``DipWeight`` at parameter init)
    layout="dip_q"     quantized DiP-permutated storage + per-output-channel
                       scales (``QuantizedDipWeight``); a float weight
                       argument is quantized on the fly with the backend's
                       declared scheme
    layout="sharded"   explicit multi-chip dispatch: consumes the
                       ``WeightPlan`` carried on a ``DipWeight`` /
                       ``QuantizedDipWeight`` (see repro.distributed.plan)
                       and runs a ``shard_map`` over the tiled kernels; a
                       weight with NO plan attached decomposes to the
                       implicit GSPMD path (``backend=None`` dispatch)

Built-in backends:

    xla              XLA/GSPMD dot (default; layout-adaptive, natively
                     differentiable)
    ws               weight-stationary tiled Pallas kernel (baseline)
    pallas_dip       fused de-shear + MXU Pallas kernel (the paper's fast
                     path)
    pallas_systolic  wavefront-emulation Pallas kernel (dataflow-faithful
                     validation path)
    dip_int8w        W8A8-dynamic int8 kernel (int32 accumulation, fused
                     scale-on-output — ADiP-style mixed precision)
    dip_fp8          fp8-e4m3-weight kernel (device-gated compute width,
                     emulated fallback)
    dip_tp           explicit tensor-parallel shard_map backend: column /
                     row per the weight's plan, collectives placed by hand
                     (zero for column, ONE psum for row — fused past the
                     epilogue; see kernels/dip_matmul_sharded.py)
    dip_fsdp         explicit ZeRO-3 shard_map backend: K-sharded storage,
                     all-gather-on-load, batch-sharded compute

Multi-chip dispatch is plan-aware: ``matmul`` keys on **(weight.plan,
backend, epilogue)** — the sharded backends consume the ``WeightPlan`` a
``ShardingPlan.attach_params`` stamped on the weight, and decompose to the
implicit GSPMD path when no plan is attached (so the same call site serves
single-device, GSPMD, and explicit-collective execution).

Dispatch is weight-type aware with zero call-site changes: a
``QuantizedDipWeight`` with ``backend=None`` routes to its scheme's default
quantized backend, and any *other* backend given a quantized weight
dequantizes it to the layout it consumes (the GSPMD/XLA path for serving
quantized checkpoints through plain dots).

Fused epilogues (``kernels/epilogue.py``): backends declare which epilogues
their kernels fuse into the accumulator flush (``MatmulBackend.epilogues``).
``matmul(..., epilogue="bias_silu", epilogue_operands=(b,))`` dispatches the
fused kernel when the backend supports it and **decomposes** otherwise —
the unfused matmul(s) followed by the same f32 epilogue arithmetic — so the
``xla``/GSPMD path keeps working unchanged and results agree across paths.
``epilogue="swiglu"`` takes a weight *pair* ``w=(w_gate, w_up)`` and fuses
both projections plus the gating product into one kernel launch.

Fused prologues (``kernels/prologue.py``) mirror the epilogue story on the
*load* side: ``matmul(..., prologue="rmsnorm", prologue_operands=(g,))``
folds the RMSNorm of x into the kernels' x-block load (the O(M) inverse-rms
reduction runs as plain XLA in the dispatch wrapper; the O(M*K) elementwise
rescale happens in VMEM), still ONE pallas launch per dispatch.  Backends
declare support via ``MatmulBackend.prologues`` and ``matmul`` decomposes
to ``rms_norm -> unfused matmul`` with identical semantics otherwise.

Tiled backends share one padding/batching shim and a per-backend
``custom_vjp`` (Pallas kernels have no JVP rule; the backward runs plain XLA
matmuls, with the cotangent re-permutated for dip-layout storage — the
permutation is orthogonal, so ``d/dP f(unperm(P)) = perm(d/dW f(W))``).
Fused-epilogue/prologue backwards recompute the pre-activation from the
saved matmul residuals (one extra XLA matmul per weight) and differentiate
the epilogue/prologue exactly — gradients match the decomposed path to f32
tolerance.  Block sizes come from the tuning table (repro.api.tuning, keyed
on the epilogue too) unless the caller pins them.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import quant, tuning
from repro.api.quant import QuantizedDipWeight
from repro.api.weights import PERM_TILE, DipWeight, as_dip_weight
from repro.core import permute
from repro.kernels import epilogue as epilogue_lib
from repro.kernels import prologue as prologue_lib

__all__ = [
    "MatmulBackend",
    "register_backend",
    "get_backend",
    "list_backends",
    "backend_layout",
    "backend_epilogues",
    "backend_prologues",
    "matmul",
    "default_interpret",
    "DEFAULT_BACKEND",
    "EPILOGUES",
    "PROLOGUES",
]

DEFAULT_BACKEND = "xla"

EPILOGUES = epilogue_lib.EPILOGUES
PROLOGUES = prologue_lib.PROLOGUES

_LAYOUTS = ("natural", "dip", "dip_q", "sharded")


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere (CPU CI)."""
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# shared tiled-dispatch machinery
def _pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _flatten_batch(x: jax.Array):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


def _f32(t: jax.Array) -> jax.Array:
    return t.astype(jnp.float32)


def _epilogue_recompute(epilogue: str, x32, wns32, eops32):
    """Recompute ``epilogue(x @ w ...)`` from the saved matmul residuals in
    f32 — the backward differentiates THIS with jax.vjp, so fused gradients
    are the exact gradients of the fused math (pre-activations recomputed,
    never stored)."""
    zs = [jnp.matmul(x32, wn) for wn in wns32]
    if epilogue_lib.spec(epilogue).dual_weight:
        return epilogue_lib.apply(epilogue, zs[0], zs[1])
    return epilogue_lib.apply(epilogue, zs[0], *eops32)


def _fused_recompute(prologue, epilogue, k_true, eps, x32, pops32, wns32, eops32):
    """The full fused composition ``epilogue(prologue(x) @ w ...)`` in f32,
    recomputed from the saved residuals — both fused backwards differentiate
    this one definition, so prologue and epilogue gradients stay exact and
    mutually consistent."""
    if prologue_lib.spec(prologue).normalize:
        (g32,) = pops32
        inv = jax.lax.rsqrt(
            jnp.sum(x32 * x32, axis=-1, keepdims=True) / k_true + eps
        )
        x32 = x32 * inv * g32.reshape(1, -1)
    return _epilogue_recompute(epilogue, x32, wns32, eops32)


def _build_tiled_caller(fn: Callable, layout: str):
    """custom_vjp wrapper around one 2-D padded kernel invocation.

    ``ws`` is the tuple of weight storages (two for the dual-weight
    ``swiglu`` epilogue), ``pops`` the tuple of prologue operands (the
    (1, Kp) norm gain row) and ``eops`` the tuple of non-weight epilogue
    operands (bias row / residual block), all already padded.  Pallas calls
    with scratch accumulators have no jvp rule, so the backward recomputes
    the pre-activation(s) with plain XLA matmuls and differentiates the
    shared prologue/epilogue definitions.  For dip-layout storage the weight
    cotangent is the permutated gradient of the natural weight.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
    def call(x2, ws, pops, eops, opts):
        block_m, block_n, block_k, perm_tile, interpret, epilogue = opts[:6]
        prologue, k_true, p_eps = opts[6:]
        kw = dict(
            block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret,
        )
        if epilogue != "none":
            kw["epilogue"] = epilogue
        if prologue != "none":
            kw.update(prologue=prologue, prologue_operands=tuple(pops),
                      prologue_k=k_true, prologue_eps=p_eps)
        return fn(x2, ws[0], *ws[1:], *eops, **kw)

    def fwd(x2, ws, pops, eops, opts):
        return call(x2, ws, pops, eops, opts), (x2, ws, pops, eops)

    def bwd(opts, res, g):
        perm_tile, epilogue = opts[3], opts[5]
        prologue, k_true, p_eps = opts[6:]
        x2, ws, pops, eops = res
        wns32 = tuple(
            _f32(permute.unpermute_tiled(w, perm_tile) if layout == "dip" else w)
            for w in ws
        )
        pops32 = tuple(_f32(p) for p in pops)
        eops32 = tuple(_f32(e) for e in eops)
        _, vjp = jax.vjp(
            lambda x, po, wns, eo: _fused_recompute(
                prologue, epilogue, k_true, p_eps, x, po, wns, eo
            ),
            _f32(x2), pops32, wns32, eops32,
        )
        dx, dpops, dwns, deops = vjp(_f32(g))
        dws = tuple(
            (permute.permute_tiled(dwn, perm_tile) if layout == "dip" else dwn
             ).astype(w.dtype)
            for dwn, w in zip(dwns, ws)
        )
        return (
            dx.astype(x2.dtype),
            dws,
            tuple(d.astype(p.dtype) for d, p in zip(dpops, pops)),
            tuple(d.astype(e.dtype) for d, e in zip(deops, eops)),
        )

    call.defvjp(fwd, bwd)
    return call


def _build_quantized_caller(fn: Callable):
    """custom_vjp wrapper for quantized (dip_q) kernels.

    ``qws`` is a tuple of ``(storage, scale)`` pairs (two for ``swiglu``).
    Forward runs the quantized kernel; backward differentiates through the
    *dequantized* weight (straight-through w.r.t. the activations — the
    standard inference-time treatment) and through the prologue/epilogue
    exactly.  The quantized storage and its scales are frozen artifacts of
    an offline calibration, so their cotangents are zero: float0 for integer
    storage (JAX's tangent dtype for ints), zeros of the storage dtype for
    fp8.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
    def call(x2, qws, pops, eops, opts):
        block_m, block_n, block_k, perm_tile, interpret, epilogue = opts[:6]
        prologue, k_true, p_eps = opts[6:]
        kw = dict(
            block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret,
        )
        if epilogue != "none":
            kw["epilogue"] = epilogue
        if prologue != "none":
            kw.update(prologue=prologue, prologue_operands=tuple(pops),
                      prologue_k=k_true, prologue_eps=p_eps)
        (q0, s0), rest = qws[0], qws[1:]
        extra = tuple(t for pair in rest for t in pair) + tuple(eops)
        return fn(x2, q0, s0, *extra, **kw)

    def fwd(x2, qws, pops, eops, opts):
        return call(x2, qws, pops, eops, opts), (x2, qws, pops, eops)

    def bwd(opts, res, g):
        perm_tile, epilogue = opts[3], opts[5]
        prologue, k_true, p_eps = opts[6:]
        x2, qws, pops, eops = res
        wns32 = tuple(
            _f32(permute.unpermute_tiled(q, perm_tile)) * _f32(s)
            for q, s in qws
        )
        pops32 = tuple(_f32(p) for p in pops)
        eops32 = tuple(_f32(e) for e in eops)
        _, vjp = jax.vjp(
            lambda x, po, eo: _fused_recompute(
                prologue, epilogue, k_true, p_eps, x, po, wns32, eo
            ),
            _f32(x2), pops32, eops32,
        )
        dx, dpops, deops = vjp(_f32(g))

        def zero_storage(q):
            if jnp.issubdtype(q.dtype, jnp.integer):
                return np.zeros(q.shape, jax.dtypes.float0)
            return jnp.zeros(q.shape, q.dtype)

        dqws = tuple(
            (zero_storage(q), jnp.zeros(s.shape, s.dtype)) for q, s in qws
        )
        return (
            dx.astype(x2.dtype),
            dqws,
            tuple(d.astype(p.dtype) for d, p in zip(dpops, pops)),
            tuple(d.astype(e.dtype) for d, e in zip(deops, eops)),
        )

    call.defvjp(fwd, bwd)
    return call


# --------------------------------------------------------------------------
# registry
@dataclasses.dataclass(frozen=True)
class MatmulBackend:
    """One registered matmul implementation.

    ``fn`` contract for tiled backends (``tiled=True``)::

        fn(x2, w2, *epilogue_operands, block_m, block_n, block_k,
           perm_tile, interpret[, epilogue]) -> out2

    with 2-D operands already padded to block multiples.  ``epilogue`` is
    only passed when it is not ``"none"`` (so epilogue-unaware backends keep
    the historical contract); ``epilogue_operands`` then carries the second
    weight for ``swiglu``, the (1, Np) bias row, or the (Mp, Np) residual.
    Quantized backends (``layout="dip_q"``) take the scale after the
    storage::

        fn(x2, q2, w_scale, *epilogue_operands, ...) -> out2

    where for ``swiglu`` the operands are ``(q_up, w_scale_up)``.  Non-tiled
    backends (``tiled=False``, e.g. ``xla``) receive ``fn(x, w_natural)``
    with the original leading batch dims and must be natively
    differentiable; they cannot fuse epilogues (``matmul`` decomposes for
    them).
    """

    name: str
    layout: str                       # "natural" | "dip" | "dip_q"
    fn: Callable
    tiled: bool = True
    description: str = ""
    caller: Optional[Callable] = None  # custom_vjp'd tiled invocation
    scheme: Optional[str] = None       # quantization scheme (dip_q layouts)
    epilogues: FrozenSet[str] = frozenset({"none"})  # fused-epilogue support
    prologues: FrozenSet[str] = frozenset({"none"})  # fused-prologue support
    # ABFT capability: True means the backend computes an exact matmul (to
    # its dtype's rounding), so the output-row-sum probe is mathematically
    # valid; approximate/sketching plugins register abft=False and
    # ``matmul(..., verify=...)`` decomposes to the storage-integrity rung
    # of the ladder for them (see repro.reliability.abft)
    abft: bool = True


_REGISTRY: Dict[str, MatmulBackend] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    # Deferred: the built-in backends live in repro.kernels; registering
    # lazily on first registry access keeps this module import-light and
    # immune to api<->kernels import cycles.
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        _register_builtins()


def register_backend(
    name: str,
    fn: Optional[Callable] = None,
    *,
    layout: str = "natural",
    tiled: bool = True,
    description: str = "",
    scheme: Optional[str] = None,
    epilogues: Sequence[str] = ("none",),
    prologues: Sequence[str] = ("none",),
    abft: bool = True,
    overwrite: bool = False,
):
    """Register a matmul backend (usable as a decorator).

    New kernels and precisions plug in here instead of growing another
    ``elif`` ladder at every call site.  Quantized backends declare
    ``layout="dip_q"`` plus the ``scheme`` they consume (see
    ``repro.api.quant.SCHEMES``).  ``epilogues`` lists the fused-epilogue
    variants the kernel applies in its flush (``kernels/epilogue.py``);
    ``prologues`` the fused-prologue variants it applies at its load stage
    (``kernels/prologue.py``); ``matmul`` decomposes any variant the
    backend does not declare.
    """
    if fn is None:
        return functools.partial(
            register_backend, name, layout=layout, tiled=tiled,
            description=description, scheme=scheme, epilogues=epilogues,
            prologues=prologues, abft=abft, overwrite=overwrite,
        )
    if layout not in _LAYOUTS:
        raise ValueError(f"layout must be one of {_LAYOUTS}, got {layout!r}")
    if layout in ("dip", "dip_q") and not tiled:
        raise ValueError(
            f"{layout}-layout backends must be tiled=True: the dispatcher "
            "drives them through the shared padding/custom-VJP shim (see the "
            "MatmulBackend.fn contract)"
        )
    if layout == "sharded" and tiled:
        raise ValueError(
            "sharded-layout backends run through shard_map dispatch, not "
            "the tiled shim; register with tiled=False"
        )
    if layout == "dip_q":
        quant.scheme_info(scheme)  # raises on unknown/missing schemes
    elif scheme is not None:
        raise ValueError(
            f"scheme={scheme!r} is only meaningful for dip_q-layout backends"
        )
    for e in epilogues:
        epilogue_lib.spec(e)  # raises on unknown names
    epilogue_set = frozenset(epilogues) | {"none"}
    if not tiled and layout != "sharded" and epilogue_set != {"none"}:
        # sharded backends DO honour epilogues (fused per shard / applied
        # once past the psum), so they are exempt from this check
        raise ValueError(
            "non-tiled backends cannot fuse epilogues (there is no flush "
            "stage to fuse into) — matmul decomposes for them; drop the "
            "epilogues declaration"
        )
    for p in prologues:
        prologue_lib.spec(p)  # raises on unknown names
    prologue_set = frozenset(prologues) | {"none"}
    if not tiled and layout != "sharded" and prologue_set != {"none"}:
        # sharded backends honour prologues too (fused into the per-shard
        # kernels on the full-K paths, applied once before the K split)
        raise ValueError(
            "non-tiled backends cannot fuse prologues (there is no load "
            "stage to fuse into) — matmul decomposes for them; drop the "
            "prologues declaration"
        )
    _ensure_builtins()
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered (overwrite=True to replace)")
    if not tiled:
        caller = None
    elif layout == "dip_q":
        caller = _build_quantized_caller(fn)
    else:
        caller = _build_tiled_caller(fn, layout)
    _REGISTRY[name] = MatmulBackend(
        name=name, layout=layout, fn=fn, tiled=tiled,
        description=description, caller=caller, scheme=scheme,
        epilogues=epilogue_set, prologues=prologue_set, abft=abft,
    )
    return fn


def get_backend(name: Optional[str] = None) -> MatmulBackend:
    _ensure_builtins()
    name = name or DEFAULT_BACKEND
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown matmul backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_backends() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


def backend_layout(name: Optional[str] = None) -> str:
    """Weight layout the named backend consumes ("natural" | "dip" |
    "dip_q" | "sharded")."""
    return get_backend(name).layout


def backend_epilogues(name: Optional[str] = None) -> List[str]:
    """Epilogues the named backend fuses in-kernel (always includes
    "none"); anything else is decomposed by ``matmul``."""
    return sorted(get_backend(name).epilogues)


def backend_prologues(name: Optional[str] = None) -> List[str]:
    """Prologues the named backend fuses into its load stage (always
    includes "none"); anything else is decomposed by ``matmul``."""
    return sorted(get_backend(name).prologues)


# --------------------------------------------------------------------------
# dispatch
def _tiled_dispatch(
    be: MatmulBackend,
    x: jax.Array,
    ws: Tuple[jax.Array, ...],
    out_cols: int,
    perm_tile: int,
    block_m: Optional[int],
    block_n: Optional[int],
    block_k: Optional[int],
    interpret: Optional[bool],
    epilogue: str,
    operands: Tuple[jax.Array, ...],
    prologue: str = "none",
    pro_operands: Tuple[jax.Array, ...] = (),
    k_true: Optional[int] = None,
    prologue_eps: float = prologue_lib.DEFAULT_EPS,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    x2, lead = _flatten_batch(x)
    m, k, n = x2.shape[0], ws[0].shape[-2], ws[0].shape[-1]
    blocks = tuning.lookup_blocks(
        be.name, m, k, n, x2.dtype, perm_tile=perm_tile, epilogue=epilogue
    )
    bm = block_m or blocks.block_m
    bn = block_n or blocks.block_n
    bk = block_k or blocks.block_k
    x2 = _pad_dim(_pad_dim(x2, 0, bm), 1, bk)
    ws2 = tuple(_pad_dim(_pad_dim(w, 0, bk), 1, bn) for w in ws)
    pops2 = _padded_prologue_operands(prologue, pro_operands, x2.shape[1])
    eops2 = _padded_epilogue_operands(epilogue, operands, out_cols, bm, bn)
    out = be.caller(
        x2, ws2, pops2, eops2,
        (bm, bn, bk, perm_tile, interpret, epilogue, prologue,
         k_true if k_true is not None else k, prologue_eps),
    )
    return out[:m, :out_cols].reshape(lead + (out_cols,))


def _padded_prologue_operands(
    prologue: str, pro_operands: Tuple[jax.Array, ...], k_padded: int,
) -> Tuple[jax.Array, ...]:
    """The rmsnorm gain rides as a (1, Kp) row; padding is zeros (the padded
    x columns are zero too, so the normalized block stays zero there and
    contributes nothing to the dot)."""
    if not prologue_lib.spec(prologue).normalize:
        return ()
    g = pro_operands[0].reshape(1, -1)
    return (jnp.pad(g, ((0, 0), (0, k_padded - g.shape[1]))),)


def _padded_epilogue_operands(
    epilogue: str, operands: Tuple[jax.Array, ...], out_cols: int,
    bm: int, bn: int,
) -> Tuple[jax.Array, ...]:
    """Bias rides as a (1, Np) row, residual as an (Mp, Np) block; padding
    is zeros (cropped from the output; the activation of a padded region is
    computed and discarded — no NaN sources at 0)."""
    spec = epilogue_lib.spec(epilogue)
    if spec.bias:
        b = operands[0].reshape(1, out_cols)
        return (_pad_dim(b, 1, bn),)
    if spec.residual:
        r2 = operands[0].reshape(-1, out_cols)
        return (_pad_dim(_pad_dim(r2, 0, bm), 1, bn),)
    return ()


def _validated_dip_x(x: jax.Array, dw) -> jax.Array:
    """Check x's contraction against the LOGICAL d_in and pad it to the
    stored K padding.  Validating against d_in (not the padded storage)
    matters: padding rows are zero, so accepting a wider or narrower x would
    silently compute with dropped or zero-imputed features."""
    storage = dw.data
    if storage.ndim != 2:
        raise ValueError(
            f"matmul weight must be 2-D (got storage {storage.shape}); "
            "index the stacked axis first"
        )
    xdim = x.shape[-1]
    if xdim != dw.d_in:
        raise ValueError(
            f"x contraction {xdim} does not match {type(dw).__name__} "
            f"d_in={dw.d_in} (storage {storage.shape})"
        )
    xk = _pad_dim(x, -1, dw.perm_tile)  # match the stored padding of K
    if xk.shape[-1] != storage.shape[-2]:
        raise ValueError(
            f"x contraction {xdim} does not match dip storage "
            f"{storage.shape} (d_in={dw.d_in})"
        )
    return xk


def _quantized_dispatch(
    be: MatmulBackend,
    x: jax.Array,
    qws: Tuple[QuantizedDipWeight, ...],
    block_m: Optional[int],
    block_n: Optional[int],
    block_k: Optional[int],
    interpret: Optional[bool],
    epilogue: str,
    operands: Tuple[jax.Array, ...],
    prologue: str = "none",
    pro_operands: Tuple[jax.Array, ...] = (),
    k_true: Optional[int] = None,
    prologue_eps: float = prologue_lib.DEFAULT_EPS,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    qw = qws[0]
    x2, lead = _flatten_batch(x)
    q2 = qw.data
    m, k, n = x2.shape[0], q2.shape[-2], q2.shape[-1]
    # keyed on the ACTIVATION dtype: that is what varies per call site; the
    # storage dtype is fixed by the backend's scheme
    blocks = tuning.lookup_blocks(
        be.name, m, k, n, x2.dtype, perm_tile=qw.perm_tile, epilogue=epilogue
    )
    bm = block_m or blocks.block_m
    bn = block_n or blocks.block_n
    bk = block_k or blocks.block_k
    x2 = _pad_dim(_pad_dim(x2, 0, bm), 1, bk)
    pairs = tuple(
        # padded columns are zero storage; scale value moot
        (_pad_dim(_pad_dim(w.data, 0, bk), 1, bn), _pad_dim(w.scale, 1, bn))
        for w in qws
    )
    pops2 = _padded_prologue_operands(prologue, pro_operands, x2.shape[1])
    eops2 = _padded_epilogue_operands(epilogue, operands, qw.d_out, bm, bn)
    out = be.caller(
        x2, pairs, pops2, eops2,
        (bm, bn, bk, qw.perm_tile, interpret, epilogue, prologue,
         k_true if k_true is not None else qw.d_in, prologue_eps),
    )
    return out[:m, : qw.d_out].reshape(lead + (qw.d_out,))


def _logical_dims(w) -> Tuple[int, int]:
    if isinstance(w, (DipWeight, QuantizedDipWeight)):
        return w.d_in, w.d_out
    if getattr(w, "ndim", None) != 2:
        raise ValueError(f"matmul weight must be 2-D, got shape {getattr(w, 'shape', None)}")
    return int(w.shape[-2]), int(w.shape[-1])


def _check_epilogue_inputs(x, weights, epilogue: str, operands) -> None:
    """Shape/type validation shared by the fused and decomposed paths."""
    spec = epilogue_lib.spec(epilogue)
    if spec.dual_weight:
        wg, wu = weights
        if type(wg) is not type(wu):
            raise ValueError(
                f"epilogue {epilogue!r} weight pair must share a type, got "
                f"{type(wg).__name__} / {type(wu).__name__}"
            )
        if _logical_dims(wg) != _logical_dims(wu):
            raise ValueError(
                f"epilogue {epilogue!r} weight pair must share logical dims, "
                f"got {_logical_dims(wg)} / {_logical_dims(wu)}"
            )
        if isinstance(wg, QuantizedDipWeight) and wg.scheme != wu.scheme:
            raise ValueError(
                f"epilogue {epilogue!r} weight pair must share a quantization "
                f"scheme, got {wg.scheme!r} / {wu.scheme!r}"
            )
    d_out = _logical_dims(weights[0])[1]
    if spec.bias:
        b = operands[0]
        if b.shape not in ((d_out,), (1, d_out)):
            raise ValueError(
                f"epilogue {epilogue!r} bias must be ({d_out},) or (1, {d_out}), "
                f"got {b.shape}"
            )
    if spec.residual:
        r = operands[0]
        want = tuple(x.shape[:-1]) + (d_out,)
        if tuple(r.shape) != want:
            raise ValueError(
                f"epilogue {epilogue!r} residual must match the output shape "
                f"{want}, got {r.shape}"
            )


def _check_prologue_inputs(x, weights, prologue: str, pro_operands) -> None:
    """Shape validation shared by the fused and decomposed prologue paths:
    the rmsnorm gain must span x's (logical) contraction dim."""
    spec = prologue_lib.spec(prologue)
    if len(pro_operands) != spec.n_operands:
        raise ValueError(
            f"prologue {prologue!r} takes {spec.n_operands} "
            f"prologue_operands, got {len(pro_operands)}"
        )
    if spec.normalize:
        d_in = _logical_dims(weights[0])[0]
        g = pro_operands[0]
        if g.shape not in ((d_in,), (1, d_in)):
            raise ValueError(
                f"prologue {prologue!r} gain must be ({d_in},) or "
                f"(1, {d_in}), got {g.shape}"
            )


def _decomposed_prologue(
    be: MatmulBackend,
    x: jax.Array,
    w,
    prologue: str,
    pro_operands,
    prologue_eps: float,
    epilogue, operands, block_m, block_n, block_k, interpret,
) -> jax.Array:
    """Unfused fallback for backends without in-kernel prologue support:
    the SAME f32 normalize-and-cast arithmetic (kernels/prologue.py —
    identical to ``layers.rms_norm``) as an ordinary jnp expression, then
    the matmul through that same backend with any epilogue still in play;
    semantics and gradients match the fused path."""
    xn = prologue_lib.apply(
        prologue, x, *(g.reshape(-1) for g in pro_operands), eps=prologue_eps
    )
    return matmul(
        x=xn, w=w, backend=be.name,
        epilogue=epilogue if epilogue != "none" else None,
        epilogue_operands=operands, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )


def _decomposed_epilogue(
    be: MatmulBackend,
    x: jax.Array,
    weights,
    epilogue: str,
    operands,
    block_m, block_n, block_k, interpret,
    prologue="none", pro_operands=(), prologue_eps=prologue_lib.DEFAULT_EPS,
) -> jax.Array:
    """Unfused fallback for backends without in-kernel epilogue support:
    the plain matmul(s) through the same backend (any supported prologue
    stays fused in them), then the SAME f32 epilogue arithmetic
    (kernels/epilogue.py) as an ordinary jnp expression — XLA is free to
    fuse it; semantics and gradients match the fused path."""
    outs = [
        matmul(
            x, w, backend=be.name, block_m=block_m, block_n=block_n,
            block_k=block_k, interpret=interpret,
            prologue=prologue if prologue != "none" else None,
            prologue_operands=pro_operands, prologue_eps=prologue_eps,
        )
        for w in weights
    ]
    if epilogue_lib.spec(epilogue).dual_weight:
        aux = (_f32(outs[1]),)
    else:
        aux = tuple(_f32(op) for op in operands)
    # same output-dtype rule as the fused kernels: the epilogue computes in
    # f32, so an integer-accumulating matmul yields a FLOAT result (casting
    # back to int here would silently truncate and diverge from fused paths)
    out_dtype = (
        outs[0].dtype if jnp.issubdtype(outs[0].dtype, jnp.floating)
        else jnp.float32
    )
    return epilogue_lib.apply(epilogue, _f32(outs[0]), *aux).astype(out_dtype)


def matmul(
    x: jax.Array,
    w: Union[jax.Array, DipWeight, QuantizedDipWeight, tuple, list],
    *,
    backend: Optional[str] = None,
    epilogue: Optional[str] = None,
    epilogue_operands: Sequence[jax.Array] = (),
    prologue: Optional[str] = None,
    prologue_operands: Sequence[jax.Array] = (),
    prologue_eps: float = prologue_lib.DEFAULT_EPS,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
    verify: Union[bool, str] = False,
) -> jax.Array:
    """``epilogue(prologue(x) @ w)`` through a registered backend.

    ``x``: (..., d_in); ``w``: natural (d_in, d_out) array, ``DipWeight``,
    or ``QuantizedDipWeight`` — or a pair of those for the dual-weight
    ``swiglu`` epilogue.  Returns (..., d_out).  The weight is adapted to
    the backend's declared layout (a ``QuantizedDipWeight`` with no explicit
    backend dispatches to its scheme's quantized kernel; other backends
    receive it dequantized); block sizes default to the tuning table (keyed
    on the epilogue too); ``interpret`` defaults to compiled-on-TPU /
    interpreted-elsewhere.

    ``epilogue`` (default ``"none"``) selects a fused flush-stage epilogue
    (``kernels/epilogue.py``): ``bias`` / ``bias_gelu`` / ``bias_silu``
    take ``epilogue_operands=(b,)``; ``residual`` takes ``(r,)`` of the
    output's shape; ``swiglu`` takes the weight pair through ``w`` and no
    operands.  Backends that do not fuse the requested epilogue decompose
    to the unfused path with identical semantics.

    ``prologue`` (default ``"none"``) selects a fused load-stage prologue
    (``kernels/prologue.py``): ``rmsnorm`` takes
    ``prologue_operands=(g,)`` — the (d_in,) norm gain — and normalizes
    each x row with ``prologue_eps`` inside the kernel's x-block load, so
    the normalized activations never round-trip HBM.  Backends that do not
    fuse it decompose to ``rms_norm -> matmul`` with identical semantics.

    ``verify`` (default off) turns on ABFT checksum verification
    (``repro.reliability.abft``; docs/reliability.md): the dispatch runs
    unchanged and a post-hoc audit checks the output row sums against the
    weight's precomputed checksum column under a dtype-aware tolerance
    (``True``/``"auto"`` picks the strongest applicable mode; ``"probe"``
    demands the full output audit and raises where it is invalid —
    nonlinear epilogues, fused prologues, or an ``abft=False`` backend —
    ``"storage"`` pins the weight-integrity rung).  Returns ``(out,
    report)`` instead of ``out``; the output is **bit-identical** to the
    unverified dispatch.
    """
    epilogue = epilogue or "none"
    prologue = prologue or "none"
    spec = epilogue_lib.spec(epilogue)
    prologue_lib.spec(prologue)  # raises on unknown names
    operands = tuple(epilogue_operands)
    pro_operands = tuple(prologue_operands)

    if spec.dual_weight:
        if not (isinstance(w, (tuple, list)) and len(w) == 2):
            raise ValueError(
                f"epilogue {epilogue!r} consumes a (w_gate, w_up) weight pair"
            )
        weights = tuple(w)
    else:
        if isinstance(w, (tuple, list)):
            raise ValueError(
                f"a weight pair is only valid with the dual-weight 'swiglu' "
                f"epilogue (got epilogue={epilogue!r})"
            )
        weights = (w,)
    n_expected = 0 if spec.dual_weight else spec.n_operands
    if len(operands) != n_expected:
        raise ValueError(
            f"epilogue {epilogue!r} takes {n_expected} epilogue_operands, "
            f"got {len(operands)}"
        )

    if backend is None and isinstance(weights[0], QuantizedDipWeight):
        backend = weights[0].default_backend
    be = get_backend(backend)

    if verify:
        # verified dispatch = the ordinary dispatch (bit-identical output)
        # + a post-hoc ABFT audit at the wrapper level, which makes the
        # probe backend-agnostic: tiled, quantized, sharded and plain-XLA
        # paths all flow through here.  Lazy import: reliability sits above
        # the api layer in the dependency order.
        from repro.reliability import abft as _abft

        out = matmul(
            x, w, backend=be.name,
            epilogue=None if epilogue == "none" else epilogue,
            epilogue_operands=operands,
            prologue=None if prologue == "none" else prologue,
            prologue_operands=pro_operands, prologue_eps=prologue_eps,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )
        report = _abft.verify_matmul(
            x, weights, out, epilogue=epilogue, operands=operands,
            prologue=prologue, backend_abft=be.abft,
            mode=verify if isinstance(verify, str) else "auto",
        )
        return out, report

    if prologue != "none":
        _check_prologue_inputs(x, weights, prologue, pro_operands)
        if prologue not in be.prologues:
            return _decomposed_prologue(
                be, x, w, prologue, pro_operands, prologue_eps,
                epilogue, operands, block_m, block_n, block_k, interpret,
            )

    if epilogue != "none":
        _check_epilogue_inputs(x, weights, epilogue, operands)
        if epilogue not in be.epilogues:
            return _decomposed_epilogue(
                be, x, weights, epilogue, operands,
                block_m, block_n, block_k, interpret,
                prologue=prologue, pro_operands=pro_operands,
                prologue_eps=prologue_eps,
            )

    if be.layout == "sharded":
        # plan-aware dispatch: (weight.plan, backend, epilogue).  A weight
        # with no plan (or a replicated one) decomposes to the implicit
        # GSPMD path — backend=None re-dispatch keeps the weight-type rules
        # (quantized weights route to their scheme's kernel, DipWeight to
        # the de-shear-as-gather xla path).
        plan = getattr(weights[0], "plan", None)
        # dip_tp/dip_sp/dip_ep split on the TP axis via the plan's kind;
        # dip_fsdp splits K on the plan's fsdp axis — each decomposes when
        # its split is absent
        needs_fsdp = be.name == "dip_fsdp"
        if (
            plan is None
            or getattr(plan, "mesh", None) is None
            or (not needs_fsdp and plan.kind == "replicated")
            or (needs_fsdp and plan.fsdp is None)
        ):
            return matmul(
                x, w, backend=None, epilogue=epilogue if epilogue != "none" else None,
                epilogue_operands=operands,
                prologue=prologue if prologue != "none" else None,
                prologue_operands=pro_operands, prologue_eps=prologue_eps,
                block_m=block_m, block_n=block_n,
                block_k=block_k, interpret=interpret,
            )
        return be.fn(
            x, weights, operands, plan=plan, epilogue=epilogue,
            prologue=prologue, prologue_operands=pro_operands,
            prologue_eps=prologue_eps,
            interpret=interpret, block_m=block_m, block_n=block_n,
            block_k=block_k,
        )

    if be.layout == "dip_q":
        qws = []
        for wi in weights:
            if isinstance(wi, QuantizedDipWeight):
                if wi.scheme != be.scheme:
                    raise ValueError(
                        f"backend {be.name!r} consumes scheme {be.scheme!r} but "
                        f"the weight is quantized as {wi.scheme!r} — requantize "
                        "from the float weight (api.quant.quantize)"
                    )
                qws.append(wi)
            else:
                # one-off convenience, mirroring the dip-layout path: models
                # hoist this through quantize() at parameter init instead
                qws.append(quant.quantize(wi, be.scheme))
        xk = _validated_dip_x(x, qws[0])
        return _quantized_dispatch(
            be, xk, tuple(qws), block_m, block_n, block_k, interpret,
            epilogue, operands, prologue, pro_operands,
            k_true=qws[0].d_in, prologue_eps=prologue_eps,
        )

    if any(isinstance(wi, QuantizedDipWeight) for wi in weights):
        # non-quantized backend: fold the scales back in once and take the
        # backend's normal path (the GSPMD/XLA route for quantized weights).
        # Dequantize AT the activation dtype — an unconditional f32 weight
        # would silently promote every output (and the residual stream
        # behind it) to f32.
        deq_dtype = (
            x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
        )
        weights = tuple(
            quant.dequantize(wi, deq_dtype)
            if isinstance(wi, QuantizedDipWeight) else wi
            for wi in weights
        )

    if be.layout == "dip":
        dws = tuple(as_dip_weight(wi) for wi in weights)
        xk = _validated_dip_x(x, dws[0])
        return _tiled_dispatch(
            be, xk, tuple(dw.data for dw in dws), dws[0].d_out,
            dws[0].perm_tile, block_m, block_n, block_k, interpret,
            epilogue, operands, prologue, pro_operands,
            k_true=dws[0].d_in, prologue_eps=prologue_eps,
        )

    wns = tuple(
        wi.to_natural() if isinstance(wi, DipWeight) else wi for wi in weights
    )
    for wn in wns:
        if wn.ndim != 2:
            raise ValueError(f"matmul weight must be 2-D, got {wn.shape}")
        if x.shape[-1] != wn.shape[-2]:
            raise ValueError(f"contraction mismatch: x {x.shape} @ w {wn.shape}")
    if not be.tiled:
        # non-tiled backends never fuse (registration enforces it), so any
        # epilogue was decomposed above
        return be.fn(x, wns[0])
    return _tiled_dispatch(
        be, x, wns, wns[0].shape[-1], PERM_TILE, block_m, block_n, block_k,
        interpret, epilogue, operands, prologue, pro_operands,
        k_true=x.shape[-1], prologue_eps=prologue_eps,
    )


# --------------------------------------------------------------------------
# built-in backends
def _register_builtins() -> None:
    from repro.kernels.dip_matmul import dip_matmul_pallas
    from repro.kernels.dip_matmul_q import dip_matmul_q_pallas
    from repro.kernels.dip_matmul_sharded import (
        dip_fsdp_matmul, dip_sp_matmul, dip_tp_matmul,
    )
    from repro.kernels.dip_systolic import dip_systolic_pallas
    from repro.kernels.ws_matmul import ws_matmul_pallas

    def xla_fn(x, wn):
        # NOTE: no preferred_element_type=f32 here — the MXU accumulates in
        # f32 internally regardless, while a f32 *output* forces f32 TP
        # all-reduces and f32 cotangents through the whole backward
        # (2x collective + activation bytes; §Perf iteration 3).
        return jnp.matmul(x, wn)

    def ws_fn(x2, w2, *eops, block_m, block_n, block_k, perm_tile, interpret,
              epilogue="none", prologue="none", prologue_operands=(),
              prologue_k=None, prologue_eps=prologue_lib.DEFAULT_EPS):
        del perm_tile
        return ws_matmul_pallas(
            x2, w2, *eops, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret, epilogue=epilogue, prologue=prologue,
            prologue_operands=prologue_operands, prologue_k=prologue_k,
            prologue_eps=prologue_eps,
        )

    def dip_fn(x2, p2, *eops, block_m, block_n, block_k, perm_tile, interpret,
               epilogue="none", prologue="none", prologue_operands=(),
               prologue_k=None, prologue_eps=prologue_lib.DEFAULT_EPS):
        return dip_matmul_pallas(
            x2, p2, *eops, block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret, epilogue=epilogue,
            prologue=prologue, prologue_operands=prologue_operands,
            prologue_k=prologue_k, prologue_eps=prologue_eps,
        )

    def systolic_fn(x2, p2, *eops, block_m, block_n, block_k, perm_tile,
                    interpret, epilogue="none", prologue="none",
                    prologue_operands=(), prologue_k=None,
                    prologue_eps=prologue_lib.DEFAULT_EPS):
        del block_n, block_k
        return dip_systolic_pallas(
            x2, p2, *eops, block_m=block_m, array_n=perm_tile,
            interpret=interpret, epilogue=epilogue, prologue=prologue,
            prologue_operands=prologue_operands, prologue_k=prologue_k,
            prologue_eps=prologue_eps,
        )

    def quant_fn(x2, q2, ws, *eops, block_m, block_n, block_k, perm_tile,
                 interpret, epilogue="none", prologue="none",
                 prologue_operands=(), prologue_k=None,
                 prologue_eps=prologue_lib.DEFAULT_EPS):
        return dip_matmul_q_pallas(
            x2, q2, ws, *eops, block_m=block_m, block_n=block_n,
            block_k=block_k, perm_tile=perm_tile, interpret=interpret,
            epilogue=epilogue, prologue=prologue,
            prologue_operands=prologue_operands, prologue_k=prologue_k,
            prologue_eps=prologue_eps,
        )

    register_backend(
        "xla", xla_fn, layout="natural", tiled=False,
        description="XLA/GSPMD dot (default; de-shears DipWeight as a gather)",
    )
    register_backend(
        "ws", ws_fn, layout="natural", epilogues=EPILOGUES,
        prologues=PROLOGUES,
        description="weight-stationary tiled Pallas kernel (baseline)",
    )
    register_backend(
        "pallas_dip", dip_fn, layout="dip", epilogues=EPILOGUES,
        prologues=PROLOGUES,
        description="fused de-shear + MXU Pallas kernel (paper fast path)",
    )
    register_backend(
        "pallas_systolic", systolic_fn, layout="dip",
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="wavefront-emulation Pallas kernel (validation path)",
    )
    register_backend(
        "dip_int8w", quant_fn, layout="dip_q", scheme="int8",
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="W8A8-dynamic int8 kernel: per-row int8 acts x "
                    "per-column int8 weights, int32 accumulation, fused "
                    "scale-on-output (ADiP-style mixed precision)",
    )
    register_backend(
        "dip_fp8", quant_fn, layout="dip_q", scheme="fp8_e4m3",
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="fp8-e4m3-weight kernel: device-gated compute width "
                    "with emulated (f32) fallback, fused scale-on-output",
    )
    register_backend(
        "dip_tp", dip_tp_matmul, layout="sharded", tiled=False,
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="explicit tensor-parallel shard_map backend: column/row "
                    "per the weight's WeightPlan; zero collectives for "
                    "column, ONE psum (past the epilogue) for row",
    )
    register_backend(
        "dip_fsdp", dip_fsdp_matmul, layout="sharded", tiled=False,
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="explicit ZeRO-3 shard_map backend: K-sharded storage, "
                    "all-gather-on-load, batch(M)-sharded compute",
    )
    register_backend(
        "dip_sp", dip_sp_matmul, layout="sharded", tiled=False,
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="sequence-parallel shard_map backend: column streams "
                    "the M-sharded x around a ppermute ring inside the "
                    "dispatch (transfer overlaps the launch), row combines "
                    "with psum_scatter back to sequence-sharded",
    )
    register_backend(
        # dense projections under expert parallelism place collectives
        # exactly like dip_tp; the MoE-specific all-to-all dispatch lives in
        # models.moe.moe_ffn, keyed off ShardingPlan.expert_plan
        "dip_ep", dip_tp_matmul, layout="sharded", tiled=False,
        epilogues=EPILOGUES, prologues=PROLOGUES,
        description="expert-parallel strategy backend: dip_tp placement for "
                    "dense projections; MoE expert banks dispatch tokens "
                    "over the model axis with paired all-to-alls (moe_ffn)",
    )
