"""Pluggable matmul-backend registry and the single dispatching entry point.

``matmul(x, w, *, backend=None)`` is the one matmul surface the rest of the
system calls — models, serving, training, benchmarks.  Backends are
registered under a name (``register_backend``) and declare the weight layout
they consume:

    layout="natural"   plain (K, N) weights; a ``DipWeight`` argument is
                       de-sheared first (a jnp gather — the distributed /
                       GSPMD-friendly path)
    layout="dip"       DiP-permutated storage; a natural array argument is
                       permutated on the fly (one-off convenience — models
                       hoist this through ``DipWeight`` at parameter init)
    layout="dip_q"     quantized DiP-permutated storage + per-output-channel
                       scales (``QuantizedDipWeight``); a float weight
                       argument is quantized on the fly with the backend's
                       declared scheme

Built-in backends:

    xla              XLA/GSPMD dot (default; layout-adaptive, natively
                     differentiable)
    ws               weight-stationary tiled Pallas kernel (baseline)
    pallas_dip       fused de-shear + MXU Pallas kernel (the paper's fast
                     path)
    pallas_systolic  wavefront-emulation Pallas kernel (dataflow-faithful
                     validation path)
    dip_int8w        W8A8-dynamic int8 kernel (int32 accumulation, fused
                     scale-on-output — ADiP-style mixed precision)
    dip_fp8          fp8-e4m3-weight kernel (device-gated compute width,
                     emulated fallback)

Dispatch is weight-type aware with zero call-site changes: a
``QuantizedDipWeight`` with ``backend=None`` routes to its scheme's default
quantized backend, and any *other* backend given a quantized weight
dequantizes it to the layout it consumes (the GSPMD/XLA path for serving
quantized checkpoints through plain dots).

Tiled backends share one padding/batching shim and a per-backend
``custom_vjp`` (Pallas kernels have no JVP rule; the backward runs plain XLA
matmuls, with the cotangent re-permutated for dip-layout storage — the
permutation is orthogonal, so ``d/dP f(unperm(P)) = perm(d/dW f(W))``).
Block sizes come from the tuning table (repro.api.tuning) unless the caller
pins them.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import quant, tuning
from repro.api.quant import QuantizedDipWeight
from repro.api.weights import PERM_TILE, DipWeight, as_dip_weight
from repro.core import permute

__all__ = [
    "MatmulBackend",
    "register_backend",
    "get_backend",
    "list_backends",
    "backend_layout",
    "matmul",
    "default_interpret",
    "DEFAULT_BACKEND",
]

DEFAULT_BACKEND = "xla"

_LAYOUTS = ("natural", "dip", "dip_q")


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere (CPU CI)."""
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# shared tiled-dispatch machinery
def _pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _flatten_batch(x: jax.Array):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


def _build_tiled_caller(fn: Callable, layout: str):
    """custom_vjp wrapper around one 2-D padded kernel invocation.

    Pallas calls with scratch accumulators have no jvp rule, so the backward
    runs plain XLA matmuls.  For dip-layout storage the weight cotangent is
    the permutated gradient of the natural weight.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
    def call(x2, w2, opts):
        block_m, block_n, block_k, perm_tile, interpret = opts
        return fn(
            x2, w2, block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret,
        )

    def fwd(x2, w2, opts):
        return call(x2, w2, opts), (x2, w2)

    def bwd(opts, res, g):
        perm_tile = opts[3]
        x2, w2 = res
        wn = permute.unpermute_tiled(w2, perm_tile) if layout == "dip" else w2
        g32 = g.astype(jnp.float32)
        dx = jnp.matmul(g32, wn.astype(jnp.float32).T).astype(x2.dtype)
        dwn = jnp.matmul(x2.astype(jnp.float32).T, g32)
        dw = (
            permute.permute_tiled(dwn, perm_tile) if layout == "dip" else dwn
        ).astype(w2.dtype)
        return dx, dw

    call.defvjp(fwd, bwd)
    return call


def _build_quantized_caller(fn: Callable):
    """custom_vjp wrapper for quantized (dip_q) kernels.

    Forward runs the quantized kernel; backward differentiates through the
    *dequantized* weight (straight-through w.r.t. the activations — the
    standard inference-time treatment).  The quantized storage and its
    scales are frozen artifacts of an offline calibration, so their
    cotangents are zero: float0 for integer storage (JAX's tangent dtype for
    ints), zeros of the storage dtype for fp8.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
    def call(x2, q2, ws, opts):
        block_m, block_n, block_k, perm_tile, interpret = opts
        return fn(
            x2, q2, ws, block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret,
        )

    def fwd(x2, q2, ws, opts):
        return call(x2, q2, ws, opts), (x2, q2, ws)

    def bwd(opts, res, g):
        perm_tile = opts[3]
        x2, q2, ws = res
        wn = permute.unpermute_tiled(q2, perm_tile).astype(jnp.float32) * ws
        dx = jnp.matmul(g.astype(jnp.float32), wn.T).astype(x2.dtype)
        dq = (
            np.zeros(q2.shape, jax.dtypes.float0)
            if jnp.issubdtype(q2.dtype, jnp.integer)
            else jnp.zeros(q2.shape, q2.dtype)
        )
        return dx, dq, jnp.zeros(ws.shape, ws.dtype)

    call.defvjp(fwd, bwd)
    return call


# --------------------------------------------------------------------------
# registry
@dataclasses.dataclass(frozen=True)
class MatmulBackend:
    """One registered matmul implementation.

    ``fn`` contract for tiled backends (``tiled=True``)::

        fn(x2, w2, *, block_m, block_n, block_k, perm_tile, interpret) -> out2

    with 2-D operands already padded to block multiples.  Quantized backends
    (``layout="dip_q"``) take one extra positional operand::

        fn(x2, q2, w_scale, *, block_m, block_n, block_k, perm_tile,
           interpret) -> out2

    with ``q2`` the quantized permutated storage and ``w_scale`` the (1, Np)
    per-output-channel scales.  Non-tiled backends (``tiled=False``, e.g.
    ``xla``) receive ``fn(x, w_natural)`` with the original leading batch
    dims and must be natively differentiable.
    """

    name: str
    layout: str                       # "natural" | "dip" | "dip_q"
    fn: Callable
    tiled: bool = True
    description: str = ""
    caller: Optional[Callable] = None  # custom_vjp'd tiled invocation
    scheme: Optional[str] = None       # quantization scheme (dip_q layouts)


_REGISTRY: Dict[str, MatmulBackend] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    # Deferred: the built-in backends live in repro.kernels; registering
    # lazily on first registry access keeps this module import-light and
    # immune to api<->kernels import cycles.
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        _register_builtins()


def register_backend(
    name: str,
    fn: Optional[Callable] = None,
    *,
    layout: str = "natural",
    tiled: bool = True,
    description: str = "",
    scheme: Optional[str] = None,
    overwrite: bool = False,
):
    """Register a matmul backend (usable as a decorator).

    New kernels and precisions plug in here instead of growing another
    ``elif`` ladder at every call site.  Quantized backends declare
    ``layout="dip_q"`` plus the ``scheme`` they consume (see
    ``repro.api.quant.SCHEMES``).
    """
    if fn is None:
        return functools.partial(
            register_backend, name, layout=layout, tiled=tiled,
            description=description, scheme=scheme, overwrite=overwrite,
        )
    if layout not in _LAYOUTS:
        raise ValueError(f"layout must be one of {_LAYOUTS}, got {layout!r}")
    if layout in ("dip", "dip_q") and not tiled:
        raise ValueError(
            f"{layout}-layout backends must be tiled=True: the dispatcher "
            "drives them through the shared padding/custom-VJP shim (see the "
            "MatmulBackend.fn contract)"
        )
    if layout == "dip_q":
        quant.scheme_info(scheme)  # raises on unknown/missing schemes
    elif scheme is not None:
        raise ValueError(
            f"scheme={scheme!r} is only meaningful for dip_q-layout backends"
        )
    _ensure_builtins()
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered (overwrite=True to replace)")
    if not tiled:
        caller = None
    elif layout == "dip_q":
        caller = _build_quantized_caller(fn)
    else:
        caller = _build_tiled_caller(fn, layout)
    _REGISTRY[name] = MatmulBackend(
        name=name, layout=layout, fn=fn, tiled=tiled,
        description=description, caller=caller, scheme=scheme,
    )
    return fn


def get_backend(name: Optional[str] = None) -> MatmulBackend:
    _ensure_builtins()
    name = name or DEFAULT_BACKEND
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown matmul backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_backends() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


def backend_layout(name: Optional[str] = None) -> str:
    """Weight layout the named backend consumes ("natural" | "dip")."""
    return get_backend(name).layout


# --------------------------------------------------------------------------
# dispatch
def _tiled_dispatch(
    be: MatmulBackend,
    x: jax.Array,
    w2: jax.Array,
    out_cols: int,
    perm_tile: int,
    block_m: Optional[int],
    block_n: Optional[int],
    block_k: Optional[int],
    interpret: Optional[bool],
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    x2, lead = _flatten_batch(x)
    m, k, n = x2.shape[0], w2.shape[-2], w2.shape[-1]
    blocks = tuning.lookup_blocks(be.name, m, k, n, x2.dtype, perm_tile=perm_tile)
    bm = block_m or blocks.block_m
    bn = block_n or blocks.block_n
    bk = block_k or blocks.block_k
    x2 = _pad_dim(_pad_dim(x2, 0, bm), 1, bk)
    w2 = _pad_dim(_pad_dim(w2, 0, bk), 1, bn)
    out = be.caller(x2, w2, (bm, bn, bk, perm_tile, interpret))
    return out[:m, :out_cols].reshape(lead + (out_cols,))


def _validated_dip_x(x: jax.Array, dw) -> jax.Array:
    """Check x's contraction against the LOGICAL d_in and pad it to the
    stored K padding.  Validating against d_in (not the padded storage)
    matters: padding rows are zero, so accepting a wider or narrower x would
    silently compute with dropped or zero-imputed features."""
    storage = dw.data
    if storage.ndim != 2:
        raise ValueError(
            f"matmul weight must be 2-D (got storage {storage.shape}); "
            "index the stacked axis first"
        )
    xdim = x.shape[-1]
    if xdim != dw.d_in:
        raise ValueError(
            f"x contraction {xdim} does not match {type(dw).__name__} "
            f"d_in={dw.d_in} (storage {storage.shape})"
        )
    xk = _pad_dim(x, -1, dw.perm_tile)  # match the stored padding of K
    if xk.shape[-1] != storage.shape[-2]:
        raise ValueError(
            f"x contraction {xdim} does not match dip storage "
            f"{storage.shape} (d_in={dw.d_in})"
        )
    return xk


def _quantized_dispatch(
    be: MatmulBackend,
    x: jax.Array,
    qw: QuantizedDipWeight,
    block_m: Optional[int],
    block_n: Optional[int],
    block_k: Optional[int],
    interpret: Optional[bool],
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    x2, lead = _flatten_batch(x)
    q2, ws = qw.data, qw.scale
    m, k, n = x2.shape[0], q2.shape[-2], q2.shape[-1]
    # keyed on the ACTIVATION dtype: that is what varies per call site; the
    # storage dtype is fixed by the backend's scheme
    blocks = tuning.lookup_blocks(be.name, m, k, n, x2.dtype, perm_tile=qw.perm_tile)
    bm = block_m or blocks.block_m
    bn = block_n or blocks.block_n
    bk = block_k or blocks.block_k
    x2 = _pad_dim(_pad_dim(x2, 0, bm), 1, bk)
    q2 = _pad_dim(_pad_dim(q2, 0, bk), 1, bn)
    ws = _pad_dim(ws, 1, bn)  # padded columns are zero storage; scale value moot
    out = be.caller(x2, q2, ws, (bm, bn, bk, qw.perm_tile, interpret))
    return out[:m, : qw.d_out].reshape(lead + (qw.d_out,))


def matmul(
    x: jax.Array,
    w: Union[jax.Array, DipWeight, QuantizedDipWeight],
    *,
    backend: Optional[str] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``x @ w`` through a registered backend.

    ``x``: (..., d_in); ``w``: natural (d_in, d_out) array, ``DipWeight``,
    or ``QuantizedDipWeight``.  Returns (..., d_out).  The weight is adapted
    to the backend's declared layout (a ``QuantizedDipWeight`` with no
    explicit backend dispatches to its scheme's quantized kernel; other
    backends receive it dequantized); block sizes default to the tuning
    table; ``interpret`` defaults to compiled-on-TPU / interpreted-elsewhere.
    """
    if backend is None and isinstance(w, QuantizedDipWeight):
        backend = w.default_backend
    be = get_backend(backend)

    if be.layout == "dip_q":
        if isinstance(w, QuantizedDipWeight):
            if w.scheme != be.scheme:
                raise ValueError(
                    f"backend {be.name!r} consumes scheme {be.scheme!r} but "
                    f"the weight is quantized as {w.scheme!r} — requantize "
                    "from the float weight (api.quant.quantize)"
                )
            qw = w
        else:
            # one-off convenience, mirroring the dip-layout path: models
            # hoist this through quantize() at parameter init instead
            qw = quant.quantize(w, be.scheme)
        xk = _validated_dip_x(x, qw)
        return _quantized_dispatch(be, xk, qw, block_m, block_n, block_k, interpret)

    if isinstance(w, QuantizedDipWeight):
        # non-quantized backend: fold the scales back in once and take the
        # backend's normal path (the GSPMD/XLA route for quantized weights).
        # Dequantize AT the activation dtype — an unconditional f32 weight
        # would silently promote every output (and the residual stream
        # behind it) to f32.
        deq_dtype = (
            x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
        )
        w = quant.dequantize(w, deq_dtype)

    if be.layout == "dip":
        dw = as_dip_weight(w)
        xk = _validated_dip_x(x, dw)
        return _tiled_dispatch(
            be, xk, dw.data, dw.d_out, dw.perm_tile,
            block_m, block_n, block_k, interpret,
        )

    wn = w.to_natural() if isinstance(w, DipWeight) else w
    if wn.ndim != 2:
        raise ValueError(f"matmul weight must be 2-D, got {wn.shape}")
    if x.shape[-1] != wn.shape[-2]:
        raise ValueError(f"contraction mismatch: x {x.shape} @ w {wn.shape}")
    if not be.tiled:
        return be.fn(x, wn)
    return _tiled_dispatch(
        be, x, wn, wn.shape[-1], PERM_TILE, block_m, block_n, block_k, interpret
    )


# --------------------------------------------------------------------------
# built-in backends
def _register_builtins() -> None:
    from repro.kernels.dip_matmul import dip_matmul_pallas
    from repro.kernels.dip_matmul_q import dip_matmul_q_pallas
    from repro.kernels.dip_systolic import dip_systolic_pallas
    from repro.kernels.ws_matmul import ws_matmul_pallas

    def xla_fn(x, wn):
        # NOTE: no preferred_element_type=f32 here — the MXU accumulates in
        # f32 internally regardless, while a f32 *output* forces f32 TP
        # all-reduces and f32 cotangents through the whole backward
        # (2x collective + activation bytes; §Perf iteration 3).
        return jnp.matmul(x, wn)

    def ws_fn(x2, w2, *, block_m, block_n, block_k, perm_tile, interpret):
        del perm_tile
        return ws_matmul_pallas(
            x2, w2, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )

    def dip_fn(x2, p2, *, block_m, block_n, block_k, perm_tile, interpret):
        return dip_matmul_pallas(
            x2, p2, block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret,
        )

    def systolic_fn(x2, p2, *, block_m, block_n, block_k, perm_tile, interpret):
        del block_n, block_k
        return dip_systolic_pallas(
            x2, p2, block_m=block_m, array_n=perm_tile, interpret=interpret
        )

    def quant_fn(x2, q2, ws, *, block_m, block_n, block_k, perm_tile, interpret):
        return dip_matmul_q_pallas(
            x2, q2, ws, block_m=block_m, block_n=block_n, block_k=block_k,
            perm_tile=perm_tile, interpret=interpret,
        )

    register_backend(
        "xla", xla_fn, layout="natural", tiled=False,
        description="XLA/GSPMD dot (default; de-shears DipWeight as a gather)",
    )
    register_backend(
        "ws", ws_fn, layout="natural",
        description="weight-stationary tiled Pallas kernel (baseline)",
    )
    register_backend(
        "pallas_dip", dip_fn, layout="dip",
        description="fused de-shear + MXU Pallas kernel (paper fast path)",
    )
    register_backend(
        "pallas_systolic", systolic_fn, layout="dip",
        description="wavefront-emulation Pallas kernel (validation path)",
    )
    register_backend(
        "dip_int8w", quant_fn, layout="dip_q", scheme="int8",
        description="W8A8-dynamic int8 kernel: per-row int8 acts x "
                    "per-column int8 weights, int32 accumulation, fused "
                    "scale-on-output (ADiP-style mixed precision)",
    )
    register_backend(
        "dip_fp8", quant_fn, layout="dip_q", scheme="fp8_e4m3",
        description="fp8-e4m3-weight kernel: device-gated compute width "
                    "with emulated (f32) fallback, fused scale-on-output",
    )
