"""`DipWeight` — the paper's permutated weight layout as a first-class pytree.

The DiP dataflow consumes weights stored *permutated* (offline software step,
paper Fig. 3): each 64x64 tile has column ``i`` rotated up by ``i``.  Before
this type existed, that layout was a bare ``jax.Array`` plus stringly-typed
flags (``weight_format="dip"``) and hand-threaded ``d_out`` padding metadata
scattered across every call site.  ``DipWeight`` bundles the permutated
storage with its metadata so checkpointing, sharding, autodiff, and kernel
dispatch all key off the *type*:

    storage   ``data``       (..., Kp, Np) permutated, zero-padded to the
                             permutation-tile grid; arbitrary leading batch
                             dims (layer-stacked params scan transparently)
    metadata  ``d_in``       logical contraction dim (K before padding)
              ``d_out``      logical output dim (N before padding)
              ``perm_tile``  the array dimension the permutation tiles over
                             (64 in the paper)
              ``plan``       optional partition decision (a hashable
                             ``repro.distributed.plan.WeightPlan``): which
                             mesh axes the storage dims shard over.  Carried
                             as static aux data, so it survives jit / scan /
                             grad / checkpoint round-trips; ``api.matmul``
                             dispatches the explicit sharded backends
                             (``dip_tp`` / ``dip_fsdp``) off it and falls
                             back to GSPMD when it is absent.

Registered as a pytree node **with keys**: ``jax.jit``, ``jax.grad``,
``jax.lax.scan``, optimizer ``tree_map``s, and ``tree_flatten_with_path``
(checkpoint manifests) all traverse into ``.data`` while the metadata rides
along as static aux data.  Gradients w.r.t. a ``DipWeight`` therefore come
back *as* a ``DipWeight`` holding the permutated-storage cotangent.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import permute

__all__ = ["PERM_TILE", "DipWeight", "as_dip_weight"]

PERM_TILE = 64  # the paper's systolic-array dimension


def _pad_up(v: int, multiple: int) -> int:
    return v + (-v) % multiple


@jax.tree_util.register_pytree_with_keys_class
class DipWeight:
    """Permutated weight storage + logical-shape metadata (see module doc).

    ``data`` is intentionally unvalidated: pytree transforms route tracers,
    ``ShapeDtypeStruct``s, ``NamedSharding``s, and optimizer moments through
    the same container, so the constructor must accept any payload.
    """

    __slots__ = ("data", "d_in", "d_out", "perm_tile", "plan", "checksum")

    def __init__(self, data: Any, d_in: int, d_out: int,
                 perm_tile: int = PERM_TILE, plan: Any = None,
                 checksum: Any = None):
        self.data = data
        self.d_in = int(d_in)
        self.d_out = int(d_out)
        self.perm_tile = int(perm_tile)
        self.plan = plan  # hashable WeightPlan or None (static aux data)
        # optional ABFT checksum child (repro.reliability.abft.AbftChecksum):
        # rides the pytree like quantization scales do; None flattens to an
        # empty subtree, so checksum-free weights keep their historical leaf
        # structure
        self.checksum = checksum

    # ------------------------------------------------------------- pytree --
    def tree_flatten_with_keys(self):
        return (
            (
                (jax.tree_util.GetAttrKey("data"), self.data),
                (jax.tree_util.GetAttrKey("checksum"), self.checksum),
            ),
            (self.d_in, self.d_out, self.perm_tile, self.plan),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux, checksum=children[1])

    # ------------------------------------------------------- construction --
    @staticmethod
    def storage_dims(d_in: int, d_out: int, perm_tile: int = PERM_TILE) -> Tuple[int, int]:
        """Padded (Kp, Np) trailing dims of the permutated storage."""
        return _pad_up(d_in, perm_tile), _pad_up(d_out, perm_tile)

    @classmethod
    def from_natural(cls, w: jax.Array, perm_tile: int = PERM_TILE,
                     plan: Any = None) -> "DipWeight":
        """Offline permutation (paper Fig. 3): pad the trailing two dims to
        the tile grid and permute each tile.  Leading batch dims (e.g. a
        layer-stacking axis) pass through untouched."""
        d_in, d_out = int(w.shape[-2]), int(w.shape[-1])
        return cls(permute.permute_tiled(w, perm_tile), d_in, d_out, perm_tile, plan)

    # ------------------------------------------------------------ queries --
    @property
    def dtype(self):
        return self.data.dtype

    @property
    def storage_shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def shape(self) -> Tuple[int, ...]:
        """Logical shape: leading batch dims + (d_in, d_out)."""
        return tuple(self.data.shape[:-2]) + (self.d_in, self.d_out)

    @property
    def ndim(self) -> int:
        return self.data.ndim

    # -------------------------------------------------------- conversions --
    def to_natural(self) -> jax.Array:
        """Recover the natural-layout weight (inverse permutation + crop)."""
        wn = permute.unpermute_tiled(self.data, self.perm_tile)
        return wn[..., : self.d_in, : self.d_out]

    def astype(self, dtype) -> "DipWeight":
        """Cast the permutated storage (a pure elementwise cast — the
        permutation commutes with it, so no re-permutation is needed).

        Float-to-float only: a bare cast to an integer target silently
        truncates toward zero with no scales, which is never what a
        quantization caller wants — they get pointed at the real thing.
        """
        dtype = jnp.dtype(dtype)
        if dtype == jnp.dtype(self.data.dtype):
            return self
        if not jnp.issubdtype(dtype, jnp.floating):
            raise TypeError(
                f"DipWeight.astype({dtype.name}) would truncate storage "
                "without scales; use repro.api.quant.quantize(w, "
                "scheme=...) to build a QuantizedDipWeight instead"
            )
        # a cast invalidates any attached checksum (it was computed from the
        # old storage); the caller re-attaches after the cast
        return DipWeight(self.data.astype(dtype), self.d_in, self.d_out,
                         self.perm_tile, self.plan)

    def with_data(self, data: Any, checksum: Any = None) -> "DipWeight":
        """Same metadata, different payload (shardings, specs, moments).
        The checksum child does NOT carry over by default — a new payload
        invalidates it; pass ``checksum=`` to thread a matching one."""
        return DipWeight(data, self.d_in, self.d_out, self.perm_tile,
                         self.plan, checksum)

    def with_plan(self, plan: Any) -> "DipWeight":
        """Same payload, different partition decision (see
        ``repro.distributed.plan.ShardingPlan.attach_params``)."""
        if plan == self.plan:
            return self
        return DipWeight(self.data, self.d_in, self.d_out, self.perm_tile,
                         plan, self.checksum)

    def with_checksum(self, checksum: Any) -> "DipWeight":
        """Same payload, with an ABFT checksum attached (see
        ``repro.reliability.abft.attach_checksums``)."""
        return DipWeight(self.data, self.d_in, self.d_out, self.perm_tile,
                         self.plan, checksum)

    def __repr__(self) -> str:
        data = self.data
        desc = (
            f"{getattr(data, 'shape', None)}:{getattr(data, 'dtype', type(data).__name__)}"
        )
        plan = f", plan={self.plan!r}" if self.plan is not None else ""
        return (
            f"DipWeight({desc}, d_in={self.d_in}, d_out={self.d_out}, "
            f"perm_tile={self.perm_tile}{plan})"
        )


def as_dip_weight(
    w: Any,
    *,
    d_out: Optional[int] = None,
    perm_tile: int = PERM_TILE,
) -> DipWeight:
    """Coerce to ``DipWeight``.

    * ``DipWeight`` passes through (``d_out`` must agree if given).
    * A natural-layout array is permutated via :meth:`DipWeight.from_natural`.

    To wrap storage that is *already* permutated (e.g. loaded from an
    external artifact), construct ``DipWeight(storage, d_in, d_out)``
    directly.
    """
    if isinstance(w, DipWeight):
        if d_out is not None and d_out != w.d_out:
            raise ValueError(f"d_out mismatch: requested {d_out}, weight has {w.d_out}")
        return w
    dw = DipWeight.from_natural(w, perm_tile)
    if d_out is not None and d_out != dw.d_out:
        raise ValueError(f"d_out mismatch: requested {d_out}, natural weight has {dw.d_out}")
    return dw
