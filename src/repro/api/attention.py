"""Attention-backend registry: named, pluggable fused-attention kernels.

The matmul registry's pattern applied one level up: ``attention(q, k, v,
backend=...)`` dispatches to a registered implementation with block sizes
drawn from the same per-shape tuning table the matmul backends use (backend
key ``"flash"``: ``block_m`` -> block_q, ``block_n`` -> block_k), so
autotuned winners persist and reload exactly like matmul geometries.

Layout contract (flat, kernel-shaped): ``q (BH, Sq, D)``, ``k (BH, Sk,
D)``, ``v (BH, Sk, Dv)`` -> ``(BH, Sq, Dv)``; GQA head broadcasting and
the (B, S, H, D) <-> (BH, S, D) moves belong to the model adapter
(``models.attention.attention_core``).  ``q_offset`` (None | int | (BH,))
gives each row's absolute key position of query 0 — the serving
chunked-prefill shape — and ``kv_len`` bounds the live keys per row.  Both
may be traced (one compile serves every prefill offset).

Builtins:

    flash   Pallas fused kernel (kernels/flash_attention.py): online
            softmax in VMEM, causal block skipping.  Forward-only.
    xla     dense reference: materializes the (BH, Sq, Sk) scores.  The
            conformance oracle, and the decompose target anywhere the
            fused kernel is unsupported.

Rows that end up fully masked (q_offset places every key in the future, or
kv_len == 0) return exactly 0, on every backend.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.api import tuning
from repro.api.registry import default_interpret
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = [
    "AttentionBackend",
    "DEFAULT_ATTENTION_BACKEND",
    "attention",
    "get_attention_backend",
    "list_attention_backends",
    "register_attention_backend",
]

NEG_INF = -1e30
DEFAULT_ATTENTION_BACKEND = "flash"


@dataclasses.dataclass(frozen=True)
class AttentionBackend:
    """One registered attention implementation.

    ``fn(q, k, v, *, causal, q_offset, kv_len, scale, block_q, block_k,
    interpret)`` with the flat layout above; block sizes arrive resolved
    (never None) and ``interpret`` resolved to a bool.
    """

    name: str
    fn: Callable
    description: str = ""


_REGISTRY: Dict[str, AttentionBackend] = {}


def register_attention_backend(
    name: str, fn: Callable, *, description: str = "", overwrite: bool = False
) -> AttentionBackend:
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"attention backend {name!r} already registered")
    be = AttentionBackend(name=name, fn=fn, description=description)
    _REGISTRY[name] = be
    return be


def get_attention_backend(name: str) -> AttentionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown attention backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def list_attention_backends():
    return sorted(_REGISTRY)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    backend: Optional[str] = None,
    causal: bool = True,
    q_offset=None,
    kv_len=None,
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Dispatch one attention call to a registered backend.

    Block sizes resolve caller-override -> tuning table -> heuristic, same
    precedence as ``api.matmul``; ``interpret=None`` follows
    :func:`repro.api.default_interpret`.
    """
    be = get_attention_backend(backend or DEFAULT_ATTENTION_BACKEND)
    bh, sq, d = q.shape
    sk = k.shape[1]
    if block_q is None or block_k is None:
        blocks = tuning.lookup_blocks(be.name, sq, d, sk, q.dtype)
        block_q = block_q if block_q is not None else blocks.block_m
        block_k = block_k if block_k is not None else blocks.block_n
    if interpret is None:
        interpret = default_interpret()
    return be.fn(
        q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
        scale=scale, block_q=int(block_q), block_k=int(block_k),
        interpret=bool(interpret),
    )


# ----------------------------------------------------------------- builtins --
def _flash_fn(q, k, v, *, causal, q_offset, kv_len, scale, block_q, block_k,
              interpret):
    return flash_attention_pallas(
        q, k, v, q_offset=q_offset, kv_len=kv_len, causal=causal,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _xla_fn(q, k, v, *, causal, q_offset, kv_len, scale, block_q, block_k,
            interpret):
    del block_q, block_k, interpret  # dense path: no tiling
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5 if scale is None else scale
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    k_pos = jnp.arange(sk, dtype=jnp.int32)[None, None, :]
    kvl = jnp.asarray(sk if kv_len is None else kv_len, jnp.int32)
    live = k_pos < kvl.reshape(-1, 1, 1)
    if causal:
        qo = jnp.asarray(0 if q_offset is None else q_offset, jnp.int32)
        q_pos = qo.reshape(-1, 1, 1) + jnp.arange(sq, dtype=jnp.int32)[None, :, None]
        live = jnp.logical_and(live, q_pos >= k_pos)
    s = jnp.where(live, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all -inf is uniform — force the fused
    # kernels' exact semantics (zero output) instead
    p = jnp.where(jnp.any(live, axis=-1, keepdims=True), p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


register_attention_backend(
    "flash", _flash_fn,
    description="Pallas fused online-softmax kernel, causal block skipping",
)
register_attention_backend(
    "xla", _xla_fn,
    description="dense reference (materializes scores); conformance oracle",
)

# flash block geometry: block_m -> block_q, block_n -> block_k (the k column
# is unused).  Long-sequence default matching the kernel's historical 512.
tuning.register_tuning((512, 512, 64), backend="flash", source="builtin")
