"""Measurement-based block-size autotuner for the matmul backends.

The paper's DSE (Tables 1-2) shows DiP's efficiency hinges on tile geometry;
``repro.api.tuning`` holds the per-(backend, dtype, shape) table, but its
built-in entries are heuristics.  This module fills the table from the live
device instead:

1. **candidate generation** — MXU/perm-tile-aligned ``BlockConfig``s for the
   problem, deduplicated through :func:`tuning.clamp_blocks` and filtered by
   a VMEM working-set estimate (operand blocks are double-buffered, the
   accumulator scratch is f32/i32 at ``block_m x block_n``);
2. **measurement** — each candidate is dispatched through the real
   ``api.matmul`` path (compile + warm first, then timed over ``iters``
   calls with ``block_until_ready`` fencing);
3. **persistence** — the winner is registered as an exact-shape entry via
   :func:`tuning.register_measured` and mirrored to the JSON cache that
   ``repro.api.tuning`` reloads on first lookup, so one autotune run
   benefits every later process on the same device.

CLI (shapes from a model config, or an explicit list)::

    python -m repro.api.autotune --backend pallas_dip --config llama3_8b
    python -m repro.api.autotune --shapes 256x1024x1024,256x1024x4096

On a CPU host the Pallas kernels run in interpret mode — absolute times are
Python-emulation numbers, but the full measure->register->persist loop is
exercised end to end (that is what CI runs).  See ``docs/tuning.md``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.api import registry, tuning
from repro.api.tuning import BlockConfig
from repro.api.weights import PERM_TILE, DipWeight

__all__ = [
    "Measurement",
    "ShapeResult",
    "estimate_vmem_bytes",
    "candidate_blocks",
    "measure_candidate",
    "autotune_shape",
    "autotune_shapes",
    "autotune_for_config",
    "main",
]

# Per-core VMEM on current TPU generations is ~16 MiB; leave headroom for
# the pipeline's own buffers and the de-shear temporaries.
VMEM_BYTES = 16 * 1024 * 1024
DEFAULT_VMEM_FRACTION = 0.75

_M_SIDES = (8, 32, 64, 128, 256, 512)
_KN_SIDES = (64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class Measurement:
    blocks: BlockConfig
    time_us: float


@dataclasses.dataclass(frozen=True)
class ShapeResult:
    """All measurements for one (backend, dtype, m, k, n) workload."""

    backend: str
    dtype: str
    m: int
    k: int
    n: int
    measurements: Tuple[Measurement, ...]
    incumbent: BlockConfig  # what lookup_blocks returned before this run
    epilogue: str = "none"  # fused epilogue the workload was timed with

    @property
    def best(self) -> Measurement:
        return min(self.measurements, key=lambda r: r.time_us)

    @property
    def incumbent_time_us(self) -> Optional[float]:
        for r in self.measurements:
            if r.blocks == self.incumbent:
                return r.time_us
        return None

    def speedup_vs_incumbent(self) -> Optional[float]:
        t = self.incumbent_time_us
        return None if t is None else t / self.best.time_us


def _timer() -> float:
    """Wall-clock source for the measurement loop (monkeypatchable in tests)."""
    return time.perf_counter()


def estimate_vmem_bytes(
    blocks: BlockConfig, dtype, out_dtype=None, epilogue: str = "none"
) -> int:
    """Working-set estimate for one tiled-kernel grid step.

    x (bm, bk) and w (bk, bn) operand blocks are double-buffered by the
    Pallas pipeline; the accumulator scratch is f32/i32 (4 bytes) at
    (bm, bn); the output block is written once per K sweep.  Fused epilogues
    shift the set: a dual-weight ``swiglu`` streams a second (bk, bn) weight
    block and keeps a second accumulator; ``residual`` streams an extra
    (bm, bn) operand block; the (1, bn) bias row is noise.
    """
    from repro.kernels import epilogue as _epi

    item = jnp.dtype(dtype).itemsize
    out_item = jnp.dtype(out_dtype).itemsize if out_dtype is not None else item
    bm, bn, bk = blocks.block_m, blocks.block_n, blocks.block_k
    operands = 2 * (bm * bk + bk * bn) * item
    acc = bm * bn * 4
    out = 2 * bm * bn * out_item
    spec = _epi.spec(epilogue)
    if spec.dual_weight:
        operands += 2 * bk * bn * item  # second weight stream
        acc += bm * bn * 4              # second accumulator
    if spec.residual:
        operands += 2 * bm * bn * out_item
    return operands + acc + out


def candidate_blocks(
    backend: str,
    dtype,
    m: int,
    k: int,
    n: int,
    *,
    perm_tile: int = PERM_TILE,
    vmem_budget: Optional[int] = None,
    max_candidates: Optional[int] = None,
    incumbent: Optional[BlockConfig] = None,
    epilogue: str = "none",
) -> List[BlockConfig]:
    """Aligned, VMEM-feasible candidates for one workload.

    The incumbent (whatever ``lookup_blocks`` currently resolves — a table
    entry or the heuristic) is always candidate 0, so a tuning run can only
    improve on the status quo.  ``pallas_systolic`` pins K/N at the physical
    array dimension (the kernel tiles the wavefront per 64-wide array), so
    only M varies there.  ``epilogue`` feeds the VMEM working-set filter
    (a fused swiglu/residual shrinks the feasible block space).
    """
    if max_candidates is not None and max_candidates < 1:
        raise ValueError(f"max_candidates must be >= 1, got {max_candidates}")
    dtype = jnp.dtype(dtype)
    # integer operands accumulate to (and emit) int32 — count the output
    # block at its real width or int8 working sets are undercounted 4x
    out_dtype = jnp.dtype(jnp.int32) if dtype.kind in "iu" else dtype
    budget = vmem_budget or int(VMEM_BYTES * DEFAULT_VMEM_FRACTION)
    if incumbent is None:
        incumbent = tuning.lookup_blocks(
            backend, m, k, n, dtype, perm_tile=perm_tile, epilogue=epilogue
        )

    raw: List[BlockConfig] = [incumbent]
    if registry.get_backend(backend).name == "pallas_systolic":
        for bm in _M_SIDES:
            raw.append(BlockConfig(bm, perm_tile, perm_tile))
    else:
        for bm in _M_SIDES:
            for bn in _KN_SIDES:
                for bk in _KN_SIDES:
                    raw.append(BlockConfig(bm, bn, bk))

    seen, out = set(), []
    for cand in raw:
        cand = tuning.clamp_blocks(cand, m, k, n, perm_tile)
        if cand in seen:
            continue
        seen.add(cand)
        if cand != incumbent and estimate_vmem_bytes(
            cand, dtype, out_dtype, epilogue
        ) > budget:
            continue
        out.append(cand)
    if max_candidates is not None and len(out) > max_candidates:
        # keep the incumbent plus the largest-working-set survivors (deep
        # blocks amortize the de-shear best; tiny blocks rarely win)
        rest = sorted(
            out[1:],
            key=lambda c: estimate_vmem_bytes(c, dtype, out_dtype, epilogue),
            reverse=True,
        )
        out = out[:1] + rest[: max_candidates - 1]
    return out


def _operands(backend: str, dtype, m: int, k: int, n: int, seed: int = 0,
              epilogue: str = "none"):
    """Random (activation, weight, epilogue_operands) triple in the layout
    the backend consumes.

    For quantized (dip_q) backends ``dtype`` is the *activation* dtype — the
    weight is quantized to the backend's declared scheme, exactly as a
    serving call site would hold it.  For the dual-weight ``swiglu``
    epilogue the weight is the (gate, up) pair ``api.matmul`` expects; for
    bias/residual epilogues representative operands are generated.
    """
    from repro.kernels import epilogue as _epi

    r = np.random.default_rng(seed)
    dtype = jnp.dtype(dtype)
    be = registry.get_backend(backend)
    spec = _epi.spec(epilogue)

    def one_weight(seed_w):
        rw = np.random.default_rng(seed_w)
        if be.layout == "dip_q":
            from repro.api import quant

            w = jnp.asarray(rw.normal(0, 1, (k, n)).astype(np.float32))
            return quant.quantize(w, be.scheme)
        if dtype == jnp.dtype(jnp.int8):
            w = jnp.asarray(rw.integers(-128, 128, (k, n)).astype(np.int8))
        else:
            w = jnp.asarray(rw.normal(0, 1, (k, n)).astype(dtype))
        return DipWeight.from_natural(w) if be.layout == "dip" else w

    if dtype == jnp.dtype(jnp.int8) and be.layout != "dip_q":
        x = jnp.asarray(r.integers(-128, 128, (m, k)).astype(np.int8))
    else:
        x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32)).astype(dtype)

    w = one_weight(seed + 1)
    if spec.dual_weight:
        w = (w, one_weight(seed + 2))
    eops = ()
    if spec.bias:
        eops = (jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32)),)
    elif spec.residual:
        out_dtype = dtype if dtype.kind == "f" else jnp.dtype(jnp.float32)
        eops = (jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32)).astype(out_dtype),)
    return x, w, eops


def measure_candidate(
    backend: str,
    x,
    w,
    blocks: BlockConfig,
    *,
    iters: int = 3,
    warmup: int = 1,
    interpret: Optional[bool] = None,
    epilogue: str = "none",
    epilogue_operands=(),
) -> float:
    """Mean wall time (us) over ``iters`` compiled-and-warmed dispatches."""
    def dispatch():
        return registry.matmul(
            x, w, backend=backend, epilogue=epilogue,
            epilogue_operands=epilogue_operands,
            block_m=blocks.block_m, block_n=blocks.block_n,
            block_k=blocks.block_k, interpret=interpret,
        )

    iters = max(1, iters)
    for _ in range(max(1, warmup)):  # compile + warm outside the timed loop
        dispatch().block_until_ready()
    t0 = _timer()
    for _ in range(iters):
        out = dispatch()
    out.block_until_ready()
    return (_timer() - t0) / iters * 1e6


def autotune_shape(
    backend: str,
    m: int,
    k: int,
    n: int,
    dtype="float32",
    *,
    epilogue: str = "none",
    iters: int = 3,
    warmup: int = 1,
    interpret: Optional[bool] = None,
    max_candidates: Optional[int] = 8,
    vmem_budget: Optional[int] = None,
    register: bool = True,
    persist: bool = True,
    cache_path=None,
    verbose: bool = False,
) -> ShapeResult:
    """Measure candidates for one workload; register + persist the winner.

    ``epilogue`` tunes the FUSED dispatch (and keys the measured entry on
    it): fused kernels shift the VMEM working set, so a geometry measured
    unfused must not be assumed optimal — or even feasible — fused.
    """
    be = registry.get_backend(backend)
    if not be.tiled:
        raise ValueError(
            f"backend {be.name!r} is not tiled — it has no block sizes to tune"
        )
    dtype_name = jnp.dtype(dtype).name
    lm, lk, ln = m, k, n
    if be.layout in ("dip", "dip_q"):
        # dispatch looks blocks up with the PADDED storage dims (the weight
        # carries K/N zero-padded to the perm-tile grid), so the entry must be
        # keyed — and candidates generated — in that domain or it never hits
        lk, ln = DipWeight.storage_dims(k, n)
    incumbent = tuning.lookup_blocks(be.name, lm, lk, ln, dtype, epilogue=epilogue)
    cands = candidate_blocks(
        be.name, dtype, lm, lk, ln,
        vmem_budget=vmem_budget, max_candidates=max_candidates,
        incumbent=incumbent, epilogue=epilogue,
    )
    x, w, eops = _operands(be.name, dtype, m, k, n, epilogue=epilogue)
    measurements = []
    for cand in cands:
        t = measure_candidate(
            be.name, x, w, cand, iters=iters, warmup=warmup,
            interpret=interpret, epilogue=epilogue, epilogue_operands=eops,
        )
        measurements.append(Measurement(cand, t))
        if verbose:
            print(f"  {tuple(cand)!s:>18}  {t:10.1f} us")
    result = ShapeResult(
        backend=be.name, dtype=dtype_name, m=m, k=k, n=n,
        measurements=tuple(measurements), incumbent=incumbent,
        epilogue=epilogue,
    )
    if register:
        tuning.register_measured(
            result.best.blocks, backend=be.name, dtype=dtype_name,
            m=lm, k=lk, n=ln, epilogue=epilogue,
            time_us=result.best.time_us,
            persist=persist, path=cache_path,
        )
    return result


def autotune_shapes(
    backend: str,
    shapes: Sequence[Tuple[int, int, int]],
    dtype="float32",
    *,
    verbose: bool = False,
    **kwargs,
) -> List[ShapeResult]:
    """Tune every (m, k, n) in ``shapes``; duplicates are collapsed."""
    results = []
    for m, k, n in dict.fromkeys(tuple(s) for s in shapes):
        if verbose:
            print(f"[autotune] {backend} {jnp.dtype(dtype).name} {m}x{k}x{n}")
        results.append(
            autotune_shape(backend, m, k, n, dtype, verbose=verbose, **kwargs)
        )
    return results


def autotune_for_config(
    cfg, *, tokens: int = 128, backend: Optional[str] = None, **kwargs
) -> List[ShapeResult]:
    """Tune every distinct linear projection of a model config.

    Used by the launchers' opt-in ``--autotune`` flag: registers measured
    entries before the first forward pass traces, so the jitted model picks
    them up.  No-op (with a notice) for non-tiled backends like ``xla``.
    """
    from repro.configs.shapes import matmul_shapes

    backend = backend or cfg.matmul_backend
    if not registry.get_backend(backend).tiled:
        print(f"[autotune] backend {backend!r} is not tiled; nothing to tune")
        return []
    shapes = [(s.m, s.k, s.n) for s in matmul_shapes(cfg, tokens=tokens)]
    return autotune_shapes(backend, shapes, cfg.compute_dtype, **kwargs)


# ---------------------------------------------------------------------------
# CLI
def _parse_shapes(spec: str) -> List[Tuple[int, int, int]]:
    shapes = []
    for part in spec.split(","):
        dims = part.lower().split("x")
        if len(dims) != 3:
            raise argparse.ArgumentTypeError(
                f"shape {part!r} is not of the form MxKxN"
            )
        shapes.append(tuple(int(d) for d in dims))
    return shapes


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api.autotune",
        description="Measure matmul block-size candidates on the live device "
                    "and persist the winners into the tuning table.",
    )
    ap.add_argument("--backend", default="pallas_dip",
                    help="registered tiled backend to tune (default: pallas_dip)")
    ap.add_argument("--config", default=None,
                    help="model config name (repro.configs) to derive shapes from")
    ap.add_argument("--reduced", action="store_true",
                    help="use the config's tiny CPU-scale variant")
    ap.add_argument("--shapes", type=_parse_shapes, default=None,
                    metavar="MxKxN[,MxKxN...]",
                    help="explicit workload shapes (overrides --config)")
    ap.add_argument("--tokens", type=int, default=128,
                    help="M dimension (tokens per dispatch) for --config shapes")
    ap.add_argument("--dtype", default=None,
                    help="operand dtype (default: config compute_dtype or float32)")
    ap.add_argument("--epilogue", default="none",
                    help="fused epilogue to tune the dispatch with (part of "
                         "the tuning key; default: none)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-candidates", type=int, default=None,
                    help="cap the candidate count per shape "
                         "(default: 4 in interpret mode, 8 compiled)")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="VMEM working-set budget in bytes")
    ap.add_argument("--cache-path", default=None,
                    help="tuning-cache file (default: "
                         "~/.cache/repro-dip/tuning-<device>.json)")
    ap.add_argument("--no-persist", action="store_true",
                    help="register winners in-process only; do not write the cache")
    ap.add_argument("--compiled", action="store_true",
                    help="force compiled (non-interpret) Pallas execution")
    args = ap.parse_args(argv)

    interpret = False if args.compiled else registry.default_interpret()
    max_candidates = args.max_candidates
    if max_candidates is None:
        max_candidates = 4 if interpret else 8

    dtype = args.dtype
    if args.shapes is not None:
        shapes = args.shapes
    elif args.config is not None:
        from repro.configs import get_config

        cfg = get_config(args.config)
        if args.reduced:
            cfg = cfg.reduced()
        dtype = dtype or cfg.compute_dtype
        from repro.configs.shapes import matmul_shapes

        named = matmul_shapes(cfg, tokens=args.tokens)
        print(f"[autotune] {len(named)} distinct projections in "
              f"{cfg.name}{' (reduced)' if args.reduced else ''}:")
        for s in named:
            print(f"  {s.m:>6} x {s.k:>6} x {s.n:>6}  ({s.name})")
        shapes = [(s.m, s.k, s.n) for s in named]
    else:
        # default smoke suite: small enough for CPU interpret mode
        shapes = [(64, 128, 128), (64, 128, 256)]
    dtype = dtype or "float32"

    if not registry.get_backend(args.backend).tiled:
        print(f"[autotune] backend {args.backend!r} is not tiled — it has no "
              f"block sizes to tune (tiled backends: "
              f"{[b for b in registry.list_backends() if registry.get_backend(b).tiled]})")
        return 2

    mode = "interpret" if interpret else "compiled"
    print(f"[autotune] backend={args.backend} dtype={jnp.dtype(dtype).name} "
          f"epilogue={args.epilogue} mode={mode} iters={args.iters} "
          f"shapes={len(shapes)}")
    results = autotune_shapes(
        args.backend, shapes, dtype, epilogue=args.epilogue,
        iters=args.iters, warmup=args.warmup, interpret=interpret,
        max_candidates=max_candidates, vmem_budget=args.vmem_budget,
        persist=not args.no_persist, cache_path=args.cache_path,
        verbose=True,
    )
    for res in results:
        speedup = res.speedup_vs_incumbent()
        note = f"{speedup:.2f}x vs incumbent" if speedup else "incumbent untimed"
        print(f"[autotune] {res.m}x{res.k}x{res.n}: best {tuple(res.best.blocks)} "
              f"@ {res.best.time_us:.1f} us ({note}, "
              f"{len(res.measurements)} candidates)")
    if not args.no_persist:
        print(f"[autotune] cache written: {tuning.cache_path(args.cache_path)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
