"""`QuantizedDipWeight` — reduced-precision permutated weight storage.

ADiP (arXiv:2510.10623) shows the diagonal-input/permutated-weight dataflow
pays off most when the PE array runs at reduced precision; MatrixFlow
(arXiv:2503.05290) leans on the same low-precision GEMM for transformer
serving.  This module makes that a first-class weight type on top of
:class:`~repro.api.weights.DipWeight`:

    storage   ``data``    (..., Kp, Np) *quantized* permutated storage
                          (int8 or fp8), zero-padded to the perm-tile grid
              ``scale``   (..., 1, Np) float32 per-output-channel dequant
                          scales (padding columns carry 1.0)
    metadata  ``d_in`` / ``d_out`` / ``perm_tile``  — as in ``DipWeight``
              ``scheme``  quantization scheme name (``int8`` / ``fp8_e4m3``)

The per-output-channel scale layout survives the DiP permutation for free:
the permutation rotates rows *within* a column (per 64-wide tile), so every
storage column holds exactly the elements of the corresponding logical
output channel and one scale per column dequantizes permutated and natural
layout alike.

Consumed by the ``dip_int8w`` / ``dip_fp8`` matmul backends (see
``kernels/dip_matmul_q.py``); any other registered backend accepts a
``QuantizedDipWeight`` too — ``api.matmul`` dequantizes it to the backend's
declared layout (the GSPMD/XLA serving path for quantized checkpoints).
See ``docs/quantization.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.api.weights import PERM_TILE, DipWeight
from repro.core import permute

__all__ = [
    "QuantScheme",
    "SCHEMES",
    "scheme_info",
    "QuantizedDipWeight",
    "quantize",
    "dequantize",
    "dequantize_natural",
    "quantize_rows",
    "dequantize_rows",
    "rows_error_bound",
]


@dataclasses.dataclass(frozen=True)
class QuantScheme:
    """One supported weight-quantization scheme."""

    name: str
    storage_dtype: Any          # jnp dtype of the quantized storage
    qmax: float                 # |q| ceiling the scale maps amax onto
    backend: str                # default matmul backend for this scheme

    @property
    def is_integer(self) -> bool:
        return jnp.issubdtype(jnp.dtype(self.storage_dtype), jnp.integer)


SCHEMES: Dict[str, QuantScheme] = {
    # symmetric int8: scale = amax/127, q = clip(round(w/scale)); the paper's
    # own PE datatype (DiP Table 3 evaluates an INT8 array)
    "int8": QuantScheme("int8", jnp.int8, 127.0, "dip_int8w"),
    # fp8 e4m3: scale maps amax onto the format's max normal (448); rounding
    # is the dtype cast itself
    "fp8_e4m3": QuantScheme("fp8_e4m3", jnp.float8_e4m3fn, 448.0, "dip_fp8"),
}

# guard against degenerate all-zero channels (their scale would be 0)
_AMAX_FLOOR = 1e-8


def scheme_info(scheme: str) -> QuantScheme:
    try:
        return SCHEMES[scheme]
    except KeyError:
        raise ValueError(
            f"unknown quantization scheme {scheme!r}; supported: {sorted(SCHEMES)}"
        ) from None


@jax.tree_util.register_pytree_with_keys_class
class QuantizedDipWeight:
    """Quantized permutated storage + per-output-channel scales (module doc).

    Like ``DipWeight``, payloads are unvalidated: pytree transforms route
    tracers, ``ShapeDtypeStruct``s, and shardings through the same container.
    """

    __slots__ = ("data", "scale", "d_in", "d_out", "perm_tile", "scheme",
                 "plan", "checksum")

    def __init__(
        self,
        data: Any,
        scale: Any,
        d_in: int,
        d_out: int,
        perm_tile: int = PERM_TILE,
        scheme: str = "int8",
        plan: Any = None,
        checksum: Any = None,
    ):
        self.data = data
        self.scale = scale
        self.d_in = int(d_in)
        self.d_out = int(d_out)
        self.perm_tile = int(perm_tile)
        self.scheme = str(scheme)
        self.plan = plan  # hashable WeightPlan or None (static aux data)
        # optional ABFT checksum child — rides like the scales do (see
        # repro.reliability.abft); None flattens to an empty subtree
        self.checksum = checksum

    # ------------------------------------------------------------- pytree --
    def tree_flatten_with_keys(self):
        return (
            (
                (jax.tree_util.GetAttrKey("data"), self.data),
                (jax.tree_util.GetAttrKey("scale"), self.scale),
                (jax.tree_util.GetAttrKey("checksum"), self.checksum),
            ),
            (self.d_in, self.d_out, self.perm_tile, self.scheme, self.plan),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux, checksum=children[2])

    # ------------------------------------------------------------ queries --
    @property
    def dtype(self):
        return self.data.dtype

    @property
    def storage_shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def shape(self) -> Tuple[int, ...]:
        """Logical shape: leading batch dims + (d_in, d_out)."""
        return tuple(self.data.shape[:-2]) + (self.d_in, self.d_out)

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def scheme_info(self) -> QuantScheme:
        return scheme_info(self.scheme)

    @property
    def default_backend(self) -> str:
        """The registered backend that consumes this scheme natively."""
        return self.scheme_info.backend

    # -------------------------------------------------------- conversions --
    def dequantize(self, dtype=jnp.float32) -> DipWeight:
        """Scales applied in the *permutated* domain (column scales commute
        with the per-column row rotation) — returns a float ``DipWeight``
        (the partition plan rides along)."""
        wd = (self.data.astype(jnp.float32) * self.scale).astype(dtype)
        return DipWeight(wd, self.d_in, self.d_out, self.perm_tile, self.plan)

    def to_natural(self, dtype=jnp.float32) -> jax.Array:
        """Dequantized natural-layout weight (inverse permutation + crop)."""
        return self.dequantize(dtype).to_natural()

    def with_data(self, data: Any, scale: Any,
                  checksum: Any = None) -> "QuantizedDipWeight":
        """Same metadata, different payloads (shardings, specs).  The
        checksum child does NOT carry over by default — new payloads
        invalidate it; pass ``checksum=`` to thread a matching one."""
        return QuantizedDipWeight(
            data, scale, self.d_in, self.d_out, self.perm_tile, self.scheme,
            self.plan, checksum,
        )

    def with_plan(self, plan: Any) -> "QuantizedDipWeight":
        """Same payloads, different partition decision (see
        ``repro.distributed.plan.ShardingPlan.attach_params``)."""
        if plan == self.plan:
            return self
        return QuantizedDipWeight(
            self.data, self.scale, self.d_in, self.d_out, self.perm_tile,
            self.scheme, plan, self.checksum,
        )

    def with_checksum(self, checksum: Any) -> "QuantizedDipWeight":
        """Same payloads, with an ABFT checksum attached (see
        ``repro.reliability.abft.attach_checksums``)."""
        return QuantizedDipWeight(
            self.data, self.scale, self.d_in, self.d_out, self.perm_tile,
            self.scheme, self.plan, checksum,
        )

    def __repr__(self) -> str:
        data = self.data
        desc = (
            f"{getattr(data, 'shape', None)}:{getattr(data, 'dtype', type(data).__name__)}"
        )
        plan = f", plan={self.plan!r}" if self.plan is not None else ""
        return (
            f"QuantizedDipWeight({desc}, scheme={self.scheme!r}, "
            f"d_in={self.d_in}, d_out={self.d_out}, perm_tile={self.perm_tile}{plan})"
        )


# ---------------------------------------------------------------------------
# quantization / dequantization
def _pad_cols(a: jax.Array, width: int, value: float) -> jax.Array:
    pad = width - a.shape[-1]
    if pad == 0:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, widths, constant_values=value)


def quantize(
    w: Union[jax.Array, DipWeight, "QuantizedDipWeight"],
    scheme: str = "int8",
    *,
    perm_tile: int = PERM_TILE,
) -> QuantizedDipWeight:
    """Quantize a weight to permutated reduced-precision storage.

    ``w``: a natural (..., d_in, d_out) float array or a ``DipWeight``
    (dequantized to natural layout first — the permutation is exactly
    invertible, so no precision is lost re-deriving it).  An already-matching
    ``QuantizedDipWeight`` passes through; re-quantizing to a *different*
    scheme raises (stacking two rounding steps silently degrades accuracy —
    requantize from the float checkpoint instead).
    """
    info = scheme_info(scheme)
    if isinstance(w, QuantizedDipWeight):
        if w.scheme == scheme:
            return w
        raise ValueError(
            f"weight is already quantized as {w.scheme!r}; requantizing to "
            f"{scheme!r} would stack two rounding errors — dequantize from "
            "the float checkpoint instead"
        )
    plan = None
    if isinstance(w, DipWeight):
        perm_tile = w.perm_tile
        plan = w.plan
        wn = w.to_natural()
    else:
        wn = w
    if not jnp.issubdtype(wn.dtype, jnp.floating):
        raise TypeError(
            f"quantize expects a floating-point weight, got {wn.dtype}"
        )
    d_in, d_out = int(wn.shape[-2]), int(wn.shape[-1])

    w32 = wn.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)          # (..., 1, d_out)
    scale = jnp.maximum(amax, _AMAX_FLOOR) / info.qmax
    if info.is_integer:
        q_nat = jnp.clip(
            jnp.round(w32 / scale), -info.qmax, info.qmax
        ).astype(info.storage_dtype)
    else:
        q_nat = (w32 / scale).astype(info.storage_dtype)

    storage = permute.permute_tiled(q_nat, perm_tile)              # padded grid
    np_cols = storage.shape[-1]
    scale_p = _pad_cols(scale, np_cols, 1.0)                       # (..., 1, Np)
    return QuantizedDipWeight(storage, scale_p, d_in, d_out, perm_tile, scheme,
                              plan)


def dequantize(qw: QuantizedDipWeight, dtype=jnp.float32) -> DipWeight:
    """Float ``DipWeight`` with the scales folded back in."""
    if not isinstance(qw, QuantizedDipWeight):
        raise TypeError(f"dequantize expects a QuantizedDipWeight, got {type(qw)}")
    return qw.dequantize(dtype)


def dequantize_natural(
    qw: QuantizedDipWeight, dtype=jnp.float32
) -> jax.Array:
    """Dequantized natural-layout (d_in, d_out) weight."""
    return dequantize(qw, dtype).to_natural()


# ---------------------------------------------------------------------------
# generic per-row (last-axis) symmetric quantization — the activation/KV-cache
# counterpart of the per-output-channel weight path above.  A "row" is one
# contiguous vector along the last axis (a head's K/V at one position, an MLA
# latent, an activation row); each gets its own float32 scale, so the paged
# serving KV cache stores int8 payloads + (..., 1) scales and dequantizes
# exactly like the weight machinery does.
def quantize_rows(
    x: jax.Array, scheme: str = "int8"
) -> Tuple[jax.Array, jax.Array]:
    """``(q, scale)`` with ``x ≈ q * scale``; scale shape ``x.shape[:-1] + (1,)``.

    Symmetric per-row quantization: ``scale = amax(|row|) / qmax`` (floored so
    all-zero rows stay exactly zero), integer schemes round-to-nearest, float
    schemes cast.  Used by the serving paged KV cache (``repro.serving``).
    """
    info = scheme_info(scheme)
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, _AMAX_FLOOR) / info.qmax
    if info.is_integer:
        q = jnp.clip(jnp.round(x32 / scale), -info.qmax, info.qmax).astype(
            info.storage_dtype
        )
    else:
        q = (x32 / scale).astype(info.storage_dtype)
    return q, scale


def dequantize_rows(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_rows` (scale broadcasts over the last axis)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def rows_error_bound(scale: jax.Array, scheme: str = "int8") -> jax.Array:
    """Worst-case elementwise |x - dequant(quant(x))| per row.

    Integer schemes: half a quantization step (``scale / 2``); float schemes:
    half a ulp at the row amax.  The serving tests assert the int8 KV cache
    honours this bound (documented in ``docs/serving.md``).
    """
    info = scheme_info(scheme)
    if info.is_integer:
        return 0.5 * scale
    m_bits = jnp.finfo(jnp.dtype(info.storage_dtype)).nmant
    return scale * info.qmax * (2.0 ** -float(m_bits))


def max_abs_error_bound(qw: QuantizedDipWeight) -> jax.Array:
    """Per-output-channel worst-case elementwise quantization error.

    For the symmetric integer scheme the round-to-nearest error is at most
    half a quantization step (``scale / 2``); for fp8 it is half a ulp at the
    channel amax (``amax * 2**-mantissa_bits``, amax = scale * qmax).  Used
    by the conformance suite to assert the documented accuracy expectation.
    """
    info = qw.scheme_info
    scale = qw.scale[..., 0, : qw.d_out]
    if info.is_integer:
        return 0.5 * scale
    m_bits = jnp.finfo(jnp.dtype(info.storage_dtype)).nmant
    return scale * info.qmax * (2.0 ** -float(m_bits))
