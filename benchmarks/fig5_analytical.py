"""Fig. 5 reproduction: latency / throughput / registers / TFPU, WS vs DiP,
array sizes 3x3..64x64 — analytical models cross-checked against the
cycle-accurate register-level simulators at the sizes that fit CPU time.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import analytical, simulator

SIZES = (3, 4, 8, 16, 32, 64)


def run(csv_rows):
    t0 = time.perf_counter()
    print("\n== Fig. 5: WS vs DiP scaling (S=2 pipeline stages) ==")
    print(f"{'N':>4} {'WS lat':>7} {'DiP lat':>8} {'saved%':>7} {'thr_imp':>8} "
          f"{'reg_saved%':>10} {'WS TFPU':>8} {'DiP TFPU':>9}")
    for n in SIZES:
        c = analytical.compare(n, s=2)
        print(f"{n:>4} {c.ws_latency:>7} {c.dip_latency:>8} "
              f"{100*c.latency_saving:>6.1f} {c.throughput_improvement:>8.3f} "
              f"{100*c.register_saving:>9.1f} {c.ws_tfpu:>8} {c.dip_tfpu:>9}")

    # simulator cross-check (register-level, numerically exact)
    rng = np.random.default_rng(0)
    for n in (3, 8, 16):
        x = rng.integers(-8, 8, (n, n))
        w = rng.integers(-8, 8, (n, n))
        for s in (1, 2):
            rd = simulator.simulate_dip(x, w, stages=s)
            rw = simulator.simulate_ws(x, w, stages=s)
            assert np.array_equal(rd.output, x @ w) and np.array_equal(rw.output, x @ w)
            assert rd.latency == analytical.dip_latency(n, s)
            assert rw.latency == analytical.ws_latency(n, s)
    print("simulator cross-check: exact outputs + eq.(1)/(5) latencies  [OK]")
    dt = (time.perf_counter() - t0) * 1e6

    c64 = analytical.compare(64, s=2)
    csv_rows.append(("fig5_throughput_imp_64", dt, f"{c64.throughput_improvement:.4f}"))
    csv_rows.append(("fig5_latency_saving_64", dt, f"{c64.latency_saving:.4f}"))
    csv_rows.append(("fig5_register_saving_64", dt, f"{c64.register_saving:.4f}"))
    csv_rows.append(("fig5_tfpu_imp_64", dt, f"{c64.tfpu_improvement:.4f}"))
