"""Reliability benchmark: what does ABFT verification cost, and does the
chaos detection machinery actually detect?

Two sections, written to machine-readable ``BENCH_reliability.json``:

* **verify_overhead** — median wall time of ``api.matmul(..., verify=True)``
  vs the unverified call on the same jitted shape.  The audit is O(M·N)
  reductions riding an O(M·K·N) matmul, so the structural expectation is
  "noise"; the schema turns that into the hard contract
  ``verified_us <= max_ratio * unverified_us`` (1.15x, enforced by
  :func:`validate_reliability_json` in CI's ``reliability`` job).
* **chaos_smoke** — the three detection paths exercised end-to-end at bench
  time (float weight bit flip via the row-sum probe, int8 code flip via the
  integer-exact storage compare, planted NaN via the finiteness screen);
  each must report detected.

Refresh the committed baseline with::

    PYTHONPATH=src python benchmarks/reliability_bench.py --out BENCH_reliability.json
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

RELIABILITY_SCHEMA_VERSION = 1
MAX_VERIFY_RATIO = 1.15
DEFAULT_JSON = (
    pathlib.Path(__file__).resolve().parent.parent / "BENCH_reliability.json"
)


def _interleaved_us(fns: Sequence[Any], *args, iters: int) -> List[List[float]]:
    """Per-round wall times for each fn, measured interleaved (A, B, A, B,
    ...) so host load and thermal drift hit both alike — the rounds are the
    paired samples the ratio estimator below needs."""
    import jax

    for fn in fns:
        jax.block_until_ready(fn(*args))  # compile outside the timed region
    times: List[List[float]] = [[] for _ in fns]
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times[i].append((time.perf_counter() - t0) * 1e6)
    return times


def measure_verify_overhead(m: int, k: int, n: int, *, iters: int,
                            backend: str = "xla") -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro import api

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))

    plain = jax.jit(lambda x: api.matmul(x, w, backend=backend))

    @jax.jit
    def verified(x):
        out, report = api.matmul(x, w, backend=backend, verify=True)
        # the report's "mode" is a static string — not a JAX type; the
        # array scalars (ok/finite/...) keep the audit from being DCE'd
        return out, {k: v for k, v in report.items() if k != "mode"}


    u_times, v_times = _interleaved_us([plain, verified], x, iters=iters)
    # the contract ratio is the MEDIAN OF PAIRED per-round ratios: each
    # round's verified/unverified samples are adjacent in time, so load
    # spikes cancel within a pair instead of landing on one side's min and
    # flapping the check (scheduler noise is one-sided and unpaired)
    ratio = float(np.median([v / max(u, 1e-9)
                             for u, v in zip(u_times, v_times)]))
    unverified_us, verified_us = min(u_times), min(v_times)
    return {
        "backend": backend,
        "shape": [m, k, n],
        "iters": iters,
        "unverified_us": round(unverified_us, 1),
        "verified_us": round(verified_us, 1),
        "ratio": round(ratio, 4),
        "max_ratio": MAX_VERIFY_RATIO,
    }


def run_chaos_smoke() -> Dict[str, bool]:
    import jax.numpy as jnp

    from repro import api
    from repro import reliability as rel

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    wn = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))

    dw = rel.attach_checksums(api.DipWeight.from_natural(wn))
    flipped = dw.with_data(rel.bitflip(dw.data, seed=3, bit=30),
                           checksum=dw.checksum)
    _, rep = api.matmul(x, flipped, backend="pallas_dip", verify=True)
    weight_flip_detected = not bool(rep["ok"])

    qw = rel.attach_checksums(api.quant.quantize(wn, "int8"))
    qflip = qw.with_data(rel.bitflip(qw.data, seed=5, bit=6), qw.scale,
                         checksum=qw.checksum)
    _, rep = api.matmul(x, qflip, backend="dip_int8w", verify=True)
    quant_flip_detected = not bool(rep["ok"])

    _, rep = api.matmul(rel.plant_nan(x, seed=0), wn, backend="xla",
                        verify=True)
    nan_detected = not bool(rep["finite"])

    return {
        "weight_flip_detected": weight_flip_detected,
        "quant_flip_detected": quant_flip_detected,
        "nan_detected": nan_detected,
    }


# ---------------------------------------------------------------------------
# schema validation (the acceptance contracts)
def validate_reliability_section(rel_payload: Dict[str, Any], need) -> None:
    """Contracts for the ``verify_overhead`` + ``chaos_smoke`` sections
    (shared with ``kernels_bench.validate_bench_json`` for fused payloads)."""
    vo = rel_payload.get("verify_overhead")
    need(isinstance(vo, dict), "verify_overhead missing")
    for key in ("backend", "shape", "unverified_us", "verified_us", "ratio",
                "max_ratio"):
        need(key in vo, f"verify_overhead missing {key!r}")
    need(isinstance(vo["shape"], list) and len(vo["shape"]) == 3,
         "verify_overhead.shape must be [m, k, n]")
    need(vo["ratio"] <= vo["max_ratio"],
         f"verified matmul is {vo['ratio']}x unverified wall time "
         f"(contract: <= {vo['max_ratio']}x)")
    cs = rel_payload.get("chaos_smoke")
    need(isinstance(cs, dict), "chaos_smoke missing")
    for key in ("weight_flip_detected", "quant_flip_detected", "nan_detected"):
        need(cs.get(key) is True, f"chaos_smoke.{key} is not True — an "
             "injected fault escaped detection")


def validate_reliability_json(path) -> Dict[str, Any]:
    """Schema check for BENCH_reliability.json; returns the parsed payload.
    Raises ValueError on any violation (run by the CI ``reliability`` job)."""
    payload = json.loads(pathlib.Path(path).read_text())

    def need(cond, msg):
        if not cond:
            raise ValueError(
                f"BENCH_reliability.json schema violation: {msg}")

    need(payload.get("schema_version") == RELIABILITY_SCHEMA_VERSION,
         f"schema_version != {RELIABILITY_SCHEMA_VERSION}")
    validate_reliability_section(payload, need)
    return payload


# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="small shape / few iters (CI smoke)")
    p.add_argument("--iters", type=int, default=None)
    p.add_argument("--out", type=pathlib.Path, default=DEFAULT_JSON)
    args = p.parse_args(argv)

    # --tiny trims iters but keeps the baseline shape: the ratio contract is
    # only meaningful where the O(M·(K+N)) audit amortizes against the
    # O(M·K·N) matmul — model-scale K/N (8B-class d_model), not toy shapes
    # where the memory-bound audit is a constant fraction of a small matmul
    # and the check flaps
    m, k, n = (512, 2048, 2048)
    iters = args.iters or (5 if args.tiny else 9)

    import jax

    payload = {
        "schema_version": RELIABILITY_SCHEMA_VERSION,
        "jax_backend": jax.default_backend(),
        "verify_overhead": measure_verify_overhead(m, k, n, iters=iters),
        "chaos_smoke": run_chaos_smoke(),
    }
    args.out.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    validate_reliability_json(args.out)
    vo = payload["verify_overhead"]
    print(f"verify overhead: {vo['unverified_us']}us -> {vo['verified_us']}us "
          f"({vo['ratio']}x, contract <= {vo['max_ratio']}x)")
    print(f"chaos smoke: {payload['chaos_smoke']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
