"""Table IV reproduction: DiP 64x64 peak performance and energy efficiency,
with the paper's cross-accelerator context (published figures, with DiP's
derived numbers computed by repro.core.energy)."""

from __future__ import annotations

import time

from repro.core import energy


def run(csv_rows):
    t0 = time.perf_counter()
    print("\n== Table IV: peak performance / energy efficiency ==")
    tops = energy.peak_tops(64)
    ee_dip = energy.energy_efficiency_tops_per_w("dip", 64)
    ee_ws = energy.energy_efficiency_tops_per_w("ws", 64)
    dip_hp = energy.hardware_point("dip", 64)
    print(f"DiP 64x64 (4096 MACs, INT8, 22nm @ 1GHz):")
    print(f"  peak performance : {tops:.3f} TOPS        (paper: 8.2)")
    print(f"  power            : {dip_hp.power_w*1000:.1f} mW       (paper: 858)")
    print(f"  area             : {dip_hp.area_mm2:.3f} mm^2     (paper: ~1)")
    print(f"  energy efficiency: {ee_dip:.2f} TOPS/W    (paper: 9.55)")
    print(f"  WS baseline      : {ee_ws:.2f} TOPS/W")
    print("published context (22nm-normalized, paper's Table IV): "
          "TPU 0.46 TOPS/mm^2 / 2.15 TOPS/W; Groq TSP 0.411 / 2.73; "
          "Hanguang-800 0.423 / 2.99; DiP 8.2 / 9.55")
    dt = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("table4_peak_tops", dt, f"{tops:.4f}"))
    csv_rows.append(("table4_tops_per_w", dt, f"{ee_dip:.4f}"))
