"""Fig. 6 reproduction: DiP vs TPU-like (WS) 64x64 on transformer MHA/FFN
GEMMs — cycle-accurate tile scheduling over the paper's nine-model workload
grid, reporting actual latency and energy per workload plus the improvement
envelopes the paper quotes (energy 1.25x-1.81x, latency 1.03x-1.49x).
"""

from __future__ import annotations

import time

from repro.core import energy, tilesim, workloads


def run(csv_rows):
    t0 = time.perf_counter()
    print("\n== Fig. 6: transformer workloads on 64x64 arrays ==")
    lat_ratios, en_ratios = [], []
    examples = []
    for model, seq, wl in workloads.paper_workload_grid():
        d = tilesim.schedule_gemm(wl, "dip")
        w = tilesim.schedule_gemm(wl, "ws")
        lr = w.cycles / d.cycles
        er = energy.workload_energy_j(w.cycles, "ws") / energy.workload_energy_j(
            d.cycles, "dip"
        )
        lat_ratios.append(lr)
        en_ratios.append(er)
        if seq == 64 and wl.name.startswith(("mha_scores", "ffn_w1")):
            examples.append((model, wl, d, w, lr, er))

    print(f"workloads evaluated: {len(lat_ratios)} "
          f"(9 models x {len(workloads.PAPER_SEQ_LENS)} seq lens x 6 GEMMs)")
    print(f"latency improvement: min {min(lat_ratios):.3f}x  max {max(lat_ratios):.3f}x "
          f"(paper: 1.03x..1.49x)")
    print(f"energy  improvement: min {min(en_ratios):.3f}x  max {max(en_ratios):.3f}x "
          f"(paper: 1.25x..1.81x)")

    print("\nsample rows (M-N-K | DiP cycles | WS cycles | lat x | energy x | DiP util):")
    for model, wl, d, w, lr, er in examples[:6]:
        print(f"  {model:>14s} {wl.m}x{wl.n_inner}x{wl.k:<6} {d.cycles:>9} "
              f"{w.cycles:>9} {lr:>6.3f} {er:>6.3f} {d.utilization:>6.3f}")

    # beyond-paper: double-buffered weight loading closes part of the gap
    big = tilesim.GemmWorkload(2048, 5120, 5120)
    db_d = tilesim.simulate_gemm_event(big, "dip", double_buffered=True)
    db_w = tilesim.simulate_gemm_event(big, "ws", double_buffered=True)
    nb_d = tilesim.simulate_gemm_event(big, "dip")
    print(f"\nbeyond-paper (event scheduler): double-buffered weight load saves "
          f"{100*(1-db_d/nb_d):.1f}% DiP cycles on the largest workload; "
          f"DiP/WS ratio with both double-buffered: {db_w/db_d:.3f}x")

    dt = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("fig6_latency_imp_max", dt, f"{max(lat_ratios):.4f}"))
    csv_rows.append(("fig6_latency_imp_min", dt, f"{min(lat_ratios):.4f}"))
    csv_rows.append(("fig6_energy_imp_max", dt, f"{max(en_ratios):.4f}"))
    csv_rows.append(("fig6_energy_imp_min", dt, f"{min(en_ratios):.4f}"))
