"""Scenario fleet: every zoo config x backend x sharding, end-to-end, measured.

The paper's evaluation (Sec. V) is a workload *matrix* — DiP swept across
transformer shapes against baselines — and this driver is the repro's
equivalent regression net.  For each cell (arch, matmul backend, sharding)
it runs the three serving-stack stages at reduced dims:

* **train**   — one ``train_step_fn`` step (AdamW), loss must be finite;
* **prefill** — two chunked-prefill forward calls through ``decode_step_fn``
  against a contiguous cache (the engine's prefill path);
* **decode**  — one ``paged_decode_step_fn`` step against a ``PagedKVCache``
  with populated block tables (the engine's steady-state path);

and records, per stage, structural evidence straight from the jaxpr —
``pallas_call`` launch count, collective counts (psum / all_gather /
all_to_all / ppermute / reduce_scatter via
``kernels.dip_matmul_sharded.count_collectives``), a peak-live-bytes
estimate from a top-level liveness walk — plus wall time and pass/fail.
Explicitly sharded cells additionally run a **column probe**: one
column-parallel projection dispatch whose collective counts pin the paper's
placement contract (``dip_tp`` columns: ZERO collectives; ``dip_fsdp``:
exactly one all_gather, no psum; ``dip_sp``: the gather moved INSIDE the
kernel's load stage — ppermutes only; ``dip_ep``: collective-free, the MoE
all_to_alls live in ``models.moe`` and are counted per stage).  ``pp``
cells run the pipelined train step (prefill/decode record skipped — the
stage axis is a training schedule).

The output is schema-validated ``BENCH_fleet.json``.  The committed copy is
the baseline: :func:`validate_fleet_json` enforces the intra-document
contracts and :func:`diff_fleet_json` rejects regressions against it (launch
counts may not grow, collective counts may not grow, cells may not vanish,
previously-passing stages may not fail).  CI's ``fleet`` job re-runs the
tiny matrix and diffs; refresh the baseline with::

    PYTHONPATH=src python benchmarks/fleet.py --tiny --out BENCH_fleet.json

Quantized backends (``dip_int8w`` / ``dip_fp8``) are inference-only (the
trainer rejects them); their train stage records as skipped, never failed.
Sharded cells (``tp`` / ``fsdp`` / ``sp`` / ``ep`` / ``pp``) re-exec onto
forced host devices when the current topology is single-device, mirroring
``kernels_bench --sharded``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

FLEET_SCHEMA_VERSION = 1
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fleet.json"

BACKENDS = ("xla", "pallas_dip", "dip_int8w", "dip_fp8")
SHARDINGS = ("gspmd", "tp", "fsdp", "sp", "ep", "pp")
STAGES = ("train", "prefill", "decode")
COLLECTIVES = ("psum", "all_gather", "all_to_all", "ppermute",
               "reduce_scatter")

# Quantization scheme each backend-axis value implies ("none" = float).
QUANT_FOR_BACKEND = {"xla": "none", "pallas_dip": "none",
                     "dip_int8w": "int8", "dip_fp8": "fp8_e4m3"}
# DiP-layout backends swap to the explicit sharded kernels under tp/fsdp/
# sp/ep (the registry dispatches off the weight's attached plan); xla stays
# xla and lets GSPMD place the collectives; pp keeps the config's backend
# inside each stage (the stage axis is orthogonal to the matmul dispatch).
SHARDED_EFFECTIVE = {"tp": "dip_tp", "fsdp": "dip_fsdp",
                     "sp": "dip_sp", "ep": "dip_ep"}

# Reduced stage dims — one compiled shape per stage across the whole fleet.
DIMS = {
    "train_batch": 2, "train_seq": 16,
    "prefill_chunk": 8, "prefill_len": 16,
    "slots": 4, "block_size": 4, "max_seq": 16, "decode_ctx": 3,
}


# ---------------------------------------------------------------------------
# matrix definitions
def full_cells(archs: Sequence[str]) -> List[Tuple[str, str, str]]:
    return [(a, b, s) for a in archs for b in BACKENDS for s in SHARDINGS]


def tiny_cells(archs: Sequence[str]) -> List[Tuple[str, str, str]]:
    """The committed-baseline matrix: every arch covers all three stages on
    the replicated float backends, quantized and sharded columns sample the
    families whose layouts differ (dense / MLA+MoE / hybrid-SSM)."""
    cells: List[Tuple[str, str, str]] = []
    for a in archs:
        cells += [(a, "xla", "gspmd"), (a, "pallas_dip", "gspmd"),
                  (a, "dip_int8w", "gspmd")]
    for a in ("llama3_8b", "deepseek_v2_lite_16b"):
        if a in archs:
            cells.append((a, "dip_fp8", "gspmd"))
    for a in ("llama3_8b", "deepseek_v2_lite_16b", "zamba2_2_7b"):
        if a in archs:
            cells.append((a, "pallas_dip", "tp"))
    for a in ("llama3_8b", "zamba2_2_7b"):
        if a in archs:
            cells.append((a, "pallas_dip", "fsdp"))
    # sequence-parallel: the dense family plus the hybrid-SSM layout (same
    # pair as fsdp — the schedules differ, the coverage question does not)
    for a in ("llama3_8b", "zamba2_2_7b"):
        if a in archs:
            cells.append((a, "pallas_dip", "sp"))
    # expert-parallel: both MoE families (with and without shared experts)
    for a in ("qwen3_moe_235b_a22b", "deepseek_v2_lite_16b"):
        if a in archs:
            cells.append((a, "pallas_dip", "ep"))
    # pipeline stages: the dense scan family (see pipeline_train_step_fn)
    if "llama3_8b" in archs:
        cells.append(("llama3_8b", "pallas_dip", "pp"))
    if "llama3_8b" in archs:
        cells.append(("llama3_8b", "xla", "tp"))
    return cells


# ---------------------------------------------------------------------------
# peak-live-bytes: top-level jaxpr liveness walk
def estimate_peak_live_bytes(fn, *args) -> int:
    """Upper-bound live bytes from the top-level jaxpr: walk equations in
    program order, birth outvars, kill values past their last use.  Sub-jaxpr
    internals (scan carries, pallas scratch) are not expanded — their results
    surface as top-level outvars — so this is an *estimate* tracking the
    dominant residents (params, optimizer state, caches, batch activations),
    which is what the fleet baseline wants to catch drifting."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr

    def nbytes(v) -> int:
        aval = getattr(v, "aval", None)
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            return 0
        try:
            itemsize = np.dtype(dtype).itemsize
        except TypeError:          # float0 tangents and friends
            return 0
        return int(np.prod(shape, dtype=np.int64)) * itemsize

    last_use: Dict[Any, int] = {}
    n = len(jaxpr.eqns)
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if hasattr(v, "aval"):
                last_use[v] = i
    for v in jaxpr.outvars:
        if hasattr(v, "aval"):
            last_use[v] = n

    live: Dict[Any, int] = {}
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        if last_use.get(v, -1) >= 0:
            live[v] = nbytes(v)
    peak = sum(live.values())
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            live[v] = nbytes(v)
        peak = max(peak, sum(live.values()))
        for v in list(eqn.invars) + list(eqn.outvars):
            if v in live and last_use.get(v, -1) <= i:
                del live[v]
    return int(peak)


# ---------------------------------------------------------------------------
# cell construction
def cell_config(arch: str, backend: str, sharding: str):
    """Resolve one matrix cell to (cfg, effective_backend, quant, mesh_axes).

    ``backend`` is the matrix-axis name; the *effective* backend is what the
    registry actually dispatches (``pallas_dip`` under ``tp`` runs as
    ``dip_tp`` etc.).  Sharded cells pin float32 compute: forced host devices
    have no native bf16 and the fleet compares counts, not flops.
    """
    from repro.configs import get_config

    quant = QUANT_FOR_BACKEND[backend]
    effective = backend
    overrides: Dict[str, Any] = {"quantization": quant}
    mesh_axes: Optional[Dict[str, int]] = None
    if sharding == "gspmd":
        overrides["matmul_backend"] = backend
    else:
        if backend != "xla":
            effective = SHARDED_EFFECTIVE.get(sharding, backend)
        overrides["matmul_backend"] = effective
        overrides["compute_dtype"] = "float32"
        if sharding in ("sp", "ep", "pp"):
            # the plan's strategy drives expert_plan / stage selection; the
            # legacy tp/fsdp cells predate cfg.sharding threading and keep
            # their arch default (the registry dispatches off the backend)
            overrides["sharding"] = sharding
        if sharding == "fsdp":
            mesh_axes = {"data": 2, "model": 1}
        elif sharding == "pp":
            mesh_axes = {"stage": 2, "data": 1, "model": 1}
        else:
            mesh_axes = {"data": 1, "model": 2}
    cfg = get_config(arch).reduced(**overrides)
    return cfg, effective, quant, mesh_axes


def _make_mesh(mesh_axes: Optional[Dict[str, int]]):
    if mesh_axes is None:
        return None
    from repro.distributed.plan import make_local_mesh

    return make_local_mesh(data=mesh_axes["data"], model=mesh_axes["model"],
                           stage=mesh_axes.get("stage", 1))


def _make_params(cfg, plan):
    import jax
    from repro.models import transformer as tf_model

    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    if plan is not None:
        # place first (the shardings tree carries plan-free nodes, so the
        # treedefs match), then stamp the WeightPlans for explicit dispatch
        pshard = plan.param_shardings(tf_model.param_template(cfg))
        params = jax.tree_util.tree_map(jax.device_put, params, pshard)
        params = plan.attach_params(params)
    return params


def _stage_record(wall_us: float, counts: Dict[str, int], peak: int) -> Dict[str, Any]:
    return {
        "status": "ok",
        "wall_us": round(float(wall_us), 1),
        "pallas_calls": int(counts.get("pallas_call", 0)),
        "collectives": {k: int(counts.get(k, 0)) for k in COLLECTIVES},
        "peak_live_bytes": int(peak),
    }


def _timed(step, *args, iters: int = 1):
    import jax

    out = step(*args)                       # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / iters, out


# ---------------------------------------------------------------------------
# stage runners
def _run_train(cfg, params, plan, iters: int) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.dip_matmul_sharded import count_collectives
    from repro.models import transformer as tf_model
    from repro.optim import AdamW

    opt = AdamW(lr=1e-3)
    if plan is not None and getattr(plan, "stages", 1) > 1:
        # stage axis in the plan: the trainer's pipelined step (GPipe
        # microbatching, boundary ppermutes overlapped with stage compute)
        from repro.distributed import pipeline as pp_lib

        step = jax.jit(pp_lib.pipeline_train_step_fn(
            cfg, opt, plan, n_micro=DIMS["train_batch"]))
    else:
        step = jax.jit(tf_model.train_step_fn(cfg, opt, plan=plan))
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(1, cfg.vocab_size,
                     size=(DIMS["train_batch"], DIMS["train_seq"])), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    counts = count_collectives(step, state, batch)
    peak = estimate_peak_live_bytes(step, state, batch)
    wall, (_, metrics) = _timed(step, state, batch, iters=iters)
    loss = float(metrics["loss"])
    if not np.isfinite(loss):
        raise RuntimeError(f"non-finite train loss: {loss}")
    return _stage_record(wall, counts, peak)


def _run_prefill(cfg, params, plan, iters: int) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.dip_matmul_sharded import count_collectives
    from repro.models import transformer as tf_model

    chunk, total = DIMS["prefill_chunk"], DIMS["prefill_len"]
    fwd = jax.jit(tf_model.decode_step_fn(cfg, plan=plan))
    cache = tf_model.init_cache(cfg, 1, total)
    rng = np.random.default_rng(1)
    toks = rng.integers(1, cfg.vocab_size, size=(total,)).astype(np.int32)
    c0 = jnp.asarray(toks[:chunk][None])
    counts = count_collectives(fwd, params, cache, c0)
    peak = estimate_peak_live_bytes(fwd, params, cache, c0)

    def both_chunks(cache):
        last = None
        for lo in range(0, total, chunk):
            piece = jnp.asarray(toks[lo:lo + chunk][None])
            last, cache = fwd(params, cache, piece)
        return last

    wall, logits = _timed(both_chunks, cache, iters=iters)
    if not np.isfinite(np.asarray(logits)).all():
        raise RuntimeError("non-finite prefill logits")
    # one chunk call is the engine's unit of work; both_chunks timed two
    return _stage_record(wall / (total // chunk), counts, peak)


def _run_decode(cfg, params, plan, iters: int) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.dip_matmul_sharded import count_collectives
    from repro.models import transformer as tf_model
    from repro.serving import kv_cache as kvc

    slots, bs, max_seq = DIMS["slots"], DIMS["block_size"], DIMS["max_seq"]
    ctx = DIMS["decode_ctx"]
    kv = kvc.PagedKVCache(
        cfg, num_blocks=slots * (max_seq // bs) + 1, block_size=bs,
        slots=slots, max_seq=max_seq, kv_quant=cfg.kv_quant, plan=plan)
    if not cfg.is_ssm or cfg.is_hybrid:
        for s in range(slots):
            assert kv.ensure(s, ctx + 1)
    step = jax.jit(tf_model.paged_decode_step_fn(cfg, plan=plan))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(slots, 1)), jnp.int32)
    positions = jnp.full((slots,), ctx, jnp.int32)
    tables = jnp.asarray(kv.block_tables)
    counts = count_collectives(step, params, kv.pools, tokens, positions, tables)
    peak = estimate_peak_live_bytes(
        step, params, kv.pools, tokens, positions, tables)
    wall, (logits, _) = _timed(step, params, kv.pools, tokens, positions,
                               tables, iters=iters)
    if not np.isfinite(np.asarray(logits)).all():
        raise RuntimeError("non-finite decode logits")
    return _stage_record(wall, counts, peak)


_STAGE_RUNNERS = {"train": _run_train, "prefill": _run_prefill,
                  "decode": _run_decode}


def _column_probe(cfg, plan) -> Dict[str, Any]:
    """One column-parallel projection dispatch, counted structurally.

    ``dip_tp`` columns keep the output dimension sharded and must launch
    shard-local kernels with no collective at all; ``dip_fsdp`` gathers the
    K-sharded storage exactly once and never psums.  The fleet schema turns
    these counts into hard contracts (see :func:`validate_fleet_json`).
    """
    import jax.numpy as jnp

    from repro import api
    from repro.kernels.dip_matmul_sharded import count_collectives

    d_in, d_out = cfg.d_model, 4 * api.PERM_TILE
    rng = np.random.default_rng(3)
    w = api.DipWeight.from_natural(
        jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32)))
    if cfg.quant_scheme is not None:
        w = api.quant.quantize(w, cfg.quant_scheme)
    w = plan.attach_params({"wq": w})["wq"]
    x = jnp.asarray(rng.normal(size=(8, d_in)).astype(np.float32))
    counts = count_collectives(
        lambda x: api.matmul(x, w, backend=cfg.matmul_backend), x)
    return {"pallas_calls": int(counts.get("pallas_call", 0)),
            "collectives": {k: int(counts.get(k, 0)) for k in COLLECTIVES}}


def _verify_probe(cfg) -> Dict[str, Any]:
    """One verified vs unverified dispatch, counted structurally from the
    jaxpr.  The ABFT audit (repro.reliability; docs/reliability.md) is jnp
    reductions over the existing output and weight checksums — the contract
    the fleet schema enforces is that ``verify=True`` adds ZERO extra
    pallas launches (the <= 1.15x wall-time bound in BENCH_reliability.json
    follows from this structure)."""
    import jax.numpy as jnp

    from repro import api
    from repro.kernels.dip_matmul_sharded import count_collectives
    from repro.reliability import attach_checksums

    d_in, d_out = cfg.d_model, 4 * api.PERM_TILE
    rng = np.random.default_rng(5)
    wn = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32))
    be = api.get_backend(cfg.matmul_backend)
    if cfg.quant_scheme is not None:
        w = api.quant.quantize(wn, cfg.quant_scheme)
    elif be.layout == "dip":
        w = api.DipWeight.from_natural(wn)
    else:
        w = wn
    w = attach_checksums(w)
    x = jnp.asarray(rng.normal(size=(8, d_in)).astype(np.float32))
    plain = count_collectives(
        lambda x: api.matmul(x, w, backend=cfg.matmul_backend), x)
    def _verified(x):
        out, report = api.matmul(x, w, backend=cfg.matmul_backend,
                                 verify=True)
        # the report's "mode" is a static string — not a JAX type; keeping
        # the array scalars stops the audit from being DCE'd out of the jaxpr
        return out, {k: v for k, v in report.items() if k != "mode"}

    ver = count_collectives(_verified, x)
    pv = int(ver.get("pallas_call", 0))
    pu = int(plain.get("pallas_call", 0))
    return {"pallas_calls_unverified": pu, "pallas_calls_verified": pv,
            "extra_pallas_calls": pv - pu}


# ---------------------------------------------------------------------------
# cell driver
def run_cell(arch: str, backend: str, sharding: str, *,
             iters: int = 1) -> Dict[str, Any]:
    from repro.configs.shapes import stage_matmul_shapes
    from repro.distributed.plan import make_plan
    from repro.models import transformer as tf_model  # noqa: F401 (import check)

    cfg, effective, quant, mesh_axes = cell_config(arch, backend, sharding)
    mesh = _make_mesh(mesh_axes)
    cell: Dict[str, Any] = {
        "arch": arch, "backend": backend, "sharding": sharding,
        "effective_backend": effective, "quantization": quant,
        "stages": {}, "column_probe": None, "verify_probe": None,
        "workload_shapes": {
            k: len(v) for k, v in stage_matmul_shapes(
                cfg, train_tokens=DIMS["train_batch"] * DIMS["train_seq"],
                prefill_tokens=DIMS["prefill_chunk"],
                decode_slots=DIMS["slots"]).items()
        },
    }
    plans = {"train": None, "prefill": None, "decode": None}
    if mesh is not None:
        plans["train"] = make_plan(mesh, cfg, "train")
        decode_plan = make_plan(mesh, cfg, "decode")
        plans["prefill"] = decode_plan
        plans["decode"] = decode_plan

    for stage in STAGES:
        if stage == "train" and quant != "none":
            cell["stages"][stage] = {
                "status": "skipped",
                "reason": f"{quant} weights are inference-only "
                          "(trainer rejects quantized configs)"}
            continue
        if sharding == "pp" and stage != "train":
            cell["stages"][stage] = {
                "status": "skipped",
                "reason": "pipeline stages are a training schedule (GPipe "
                          "microbatching amortizes the bubble over a batch; "
                          "serving runs tp/sp — see docs/distributed.md)"}
            continue
        try:
            params = _make_params(cfg, plans[stage])
            cell["stages"][stage] = _STAGE_RUNNERS[stage](
                cfg, params, plans[stage], iters)
        except Exception as e:                       # noqa: BLE001 — per-cell
            cell["stages"][stage] = {
                "status": "failed",
                "reason": f"{type(e).__name__}: {e}"[:300]}
    if effective in ("dip_tp", "dip_fsdp", "dip_sp", "dip_ep"):
        cell["column_probe"] = _column_probe(cfg, plans["decode"])
    if sharding == "gspmd":
        # the verified-dispatch subset: single-device cells cover every
        # backend family without re-exec; sharded verify rides the same
        # wrapper and is structurally identical per shard
        try:
            cell["verify_probe"] = _verify_probe(cfg)
        except Exception as e:                       # noqa: BLE001 — per-cell
            cell["verify_probe"] = {
                "status": "failed",
                "reason": f"{type(e).__name__}: {e}"[:300]}
    return cell


# ---------------------------------------------------------------------------
# schema validation + baseline diff (the acceptance contracts)
def _fail(msgs: List[str]):
    raise ValueError("invalid fleet document:\n  " + "\n  ".join(msgs))


def validate_fleet_json(payload: Dict[str, Any]) -> None:
    """Structural schema plus the intra-document contracts.

    * every cell carries all three stage records; ok-stages carry positive
      wall time, non-negative launch/collective counts, positive peak bytes;
    * ``dip_tp`` cells: column probe shows ZERO collectives, and the decode
      stage issues no all_gather (columns stay sharded, rows psum);
    * ``dip_fsdp`` cells: column probe shows exactly one all_gather and no
      psum;
    * ``dip_sp`` cells: the column probe gathers INSIDE the kernel's load
      stage — at least one ppermute, and zero psum / all_gather /
      all_to_all / reduce_scatter; the forward stages (prefill/decode) may
      not all_gather at all (the sequence-parallel overlap contract: the
      pre-kernel gather never reappears; train's backward carries
      all_gathers as the AD duals of the forward reduce_scatters);
    * ``dip_ep`` cells: the column probe is collective-free (dense
      projections place like ``dip_tp``); the prefill stage shows EXACTLY
      two all_to_alls per MoE forward body (dispatch + combine — the
      2-a2a contract of ``models.moe``), decode likewise, and train at
      least two (forward + transposed backward scan bodies);
    * ``pp`` cells: prefill/decode are recorded skipped (pipeline stages
      are a training schedule), and the train stage's jaxpr carries the
      boundary ppermute;
    * for ``tiny``/``full`` matrices: every arch in the document has at
      least one cell where train, prefill AND decode all passed.
    """
    errs: List[str] = []
    if payload.get("schema_version") != FLEET_SCHEMA_VERSION:
        _fail([f"schema_version must be {FLEET_SCHEMA_VERSION}, "
               f"got {payload.get('schema_version')!r}"])
    cells = payload.get("cells")
    if not isinstance(cells, list) or not cells:
        _fail(["cells must be a non-empty list"])
    for key in ("generated_by", "matrix", "dims"):
        if key not in payload:
            errs.append(f"missing top-level key {key!r}")

    full_pass: Dict[str, bool] = {}
    seen = set()
    for i, cell in enumerate(cells):
        where = f"cells[{i}]"
        for key in ("arch", "backend", "sharding", "effective_backend",
                    "quantization", "stages"):
            if key not in cell:
                errs.append(f"{where}: missing {key!r}")
        if errs:
            continue
        arch = cell["arch"]
        if cell["backend"] not in BACKENDS:
            errs.append(f"{where}: unknown backend {cell['backend']!r}")
        if cell["sharding"] not in SHARDINGS:
            errs.append(f"{where}: unknown sharding {cell['sharding']!r}")
        ckey = (arch, cell["backend"], cell["sharding"])
        if ckey in seen:
            errs.append(f"{where}: duplicate cell {ckey}")
        seen.add(ckey)
        stages = cell["stages"]
        for st in STAGES:
            rec = stages.get(st)
            if not isinstance(rec, dict) or "status" not in rec:
                errs.append(f"{where}.stages.{st}: missing record")
                continue
            if rec["status"] == "ok":
                if not (isinstance(rec.get("wall_us"), (int, float))
                        and rec["wall_us"] > 0):
                    errs.append(f"{where}.stages.{st}: wall_us must be > 0")
                if not (isinstance(rec.get("pallas_calls"), int)
                        and rec["pallas_calls"] >= 0):
                    errs.append(f"{where}.stages.{st}: bad pallas_calls")
                coll = rec.get("collectives")
                if (not isinstance(coll, dict)
                        or set(coll) != set(COLLECTIVES)
                        or any(not isinstance(coll[k], int) or coll[k] < 0
                               for k in COLLECTIVES)):
                    errs.append(f"{where}.stages.{st}: bad collectives dict")
                if not (isinstance(rec.get("peak_live_bytes"), int)
                        and rec["peak_live_bytes"] > 0):
                    errs.append(f"{where}.stages.{st}: bad peak_live_bytes")
            elif rec["status"] in ("failed", "skipped"):
                if not rec.get("reason"):
                    errs.append(f"{where}.stages.{st}: "
                                f"{rec['status']} needs a reason")
            else:
                errs.append(f"{where}.stages.{st}: "
                            f"unknown status {rec['status']!r}")
        all_ok = all(stages.get(st, {}).get("status") == "ok" for st in STAGES)
        full_pass[arch] = full_pass.get(arch, False) or all_ok

        probe = cell.get("column_probe")
        eff = cell["effective_backend"]
        if eff in ("dip_tp", "dip_fsdp", "dip_sp", "dip_ep"):
            if not isinstance(probe, dict):
                errs.append(f"{where}: {eff} cell needs a column_probe")
            else:
                pc = probe.get("collectives", {})
                if eff in ("dip_tp", "dip_ep") and any(
                        pc.get(k, 0) for k in COLLECTIVES):
                    errs.append(
                        f"{where}: {eff} column probe must show zero "
                        f"collectives, got {pc}")
                if eff == "dip_fsdp" and (
                        pc.get("all_gather") != 1 or pc.get("psum", 0) != 0):
                    errs.append(
                        f"{where}: dip_fsdp column probe must show exactly "
                        f"one all_gather and zero psum, got {pc}")
                if eff == "dip_sp" and (
                        pc.get("ppermute", 0) < 1
                        or any(pc.get(k, 0) for k in COLLECTIVES
                               if k != "ppermute")):
                    errs.append(
                        f"{where}: dip_sp column probe must gather inside "
                        f"the kernel (ppermute >= 1, nothing else), got {pc}")
            dec = stages.get("decode", {})
            if (eff == "dip_tp" and dec.get("status") == "ok"
                    and dec.get("collectives", {}).get("all_gather", 0) > 0):
                errs.append(f"{where}: dip_tp decode must not all_gather "
                            "(columns stay sharded; rows psum)")
            if eff == "dip_sp":
                # forward stages only: the train backward contains
                # all_gathers as the AD duals of the forward
                # reduce_scatters (fwd RS <-> bwd AG is the standard
                # sequence-parallel transpose pair)
                for st in ("prefill", "decode"):
                    rec = stages.get(st, {})
                    if (rec.get("status") == "ok"
                            and rec.get("collectives", {}).get(
                                "all_gather", 0) > 0):
                        errs.append(
                            f"{where}.{st}: dip_sp forward must never "
                            "all_gather — x blocks ring through the "
                            "kernel's load stage")
            if eff == "dip_ep":
                for st, want in (("prefill", 2), ("decode", 2)):
                    rec = stages.get(st, {})
                    if rec.get("status") != "ok":
                        continue
                    a2a = rec.get("collectives", {}).get("all_to_all", 0)
                    if a2a != want:
                        errs.append(
                            f"{where}.{st}: dip_ep must show exactly {want} "
                            f"all_to_alls per MoE forward body (dispatch + "
                            f"combine), got {a2a}")
                tr = stages.get("train", {})
                if (tr.get("status") == "ok"
                        and tr.get("collectives", {}).get("all_to_all", 0) < 2):
                    errs.append(f"{where}.train: dip_ep train must carry the "
                                "dispatch/combine all_to_all pair")

        if cell["sharding"] == "pp":
            for st in ("prefill", "decode"):
                if stages.get(st, {}).get("status") != "skipped":
                    errs.append(f"{where}.{st}: pp cells record serving "
                                "stages as skipped (training schedule)")
            tr = stages.get("train", {})
            if (tr.get("status") == "ok"
                    and tr.get("collectives", {}).get("ppermute", 0) < 1):
                errs.append(f"{where}.train: pp train must carry the stage-"
                            "boundary ppermute")

        vp = cell.get("verify_probe")
        if cell["sharding"] == "gspmd":
            if not isinstance(vp, dict):
                errs.append(f"{where}: gspmd cell needs a verify_probe")
            elif vp.get("status") == "failed":
                errs.append(f"{where}: verify_probe failed "
                            f"({vp.get('reason', 'no reason')})")
            elif vp.get("extra_pallas_calls", 0) != 0:
                errs.append(
                    f"{where}: verified dispatch added "
                    f"{vp['extra_pallas_calls']} pallas launches "
                    "(contract: the ABFT audit launches zero kernels)")

    if payload.get("matrix") in ("tiny", "full"):
        for arch, ok in sorted(full_pass.items()):
            if not ok:
                errs.append(
                    f"arch {arch!r} has no cell passing all of "
                    "train+prefill+decode")
    if errs:
        _fail(errs)


def diff_fleet_json(payload: Dict[str, Any],
                    baseline: Dict[str, Any]) -> None:
    """Reject regressions of ``payload`` against the committed ``baseline``.

    Launch counts must not exceed the baseline (the fused-epilogue and
    quantized-kernel wins of PRs 3-4 stay won), collective counts must not
    exceed it (the PR-5 placement contract stays placed), baseline cells may
    not disappear, and a stage that passed before may not fail now.  Wall
    times are informational — machines differ; structure does not.
    """
    errs: List[str] = []
    new = {(c["arch"], c["backend"], c["sharding"]): c
           for c in payload.get("cells", [])}
    for cell in baseline.get("cells", []):
        key = (cell["arch"], cell["backend"], cell["sharding"])
        name = "/".join(key)
        other = new.get(key)
        if other is None:
            errs.append(f"{name}: cell present in baseline but missing now")
            continue
        for st in STAGES:
            base = cell["stages"].get(st, {})
            cur = other["stages"].get(st, {})
            if base.get("status") != "ok":
                continue
            if cur.get("status") != "ok":
                errs.append(f"{name}.{st}: was ok in baseline, now "
                            f"{cur.get('status')!r} "
                            f"({cur.get('reason', 'no reason')})")
                continue
            if cur["pallas_calls"] > base["pallas_calls"]:
                errs.append(
                    f"{name}.{st}: pallas_calls regressed "
                    f"{base['pallas_calls']} -> {cur['pallas_calls']}")
            for k in COLLECTIVES:
                if cur["collectives"][k] > base["collectives"][k]:
                    errs.append(
                        f"{name}.{st}: {k} count regressed "
                        f"{base['collectives'][k]} -> {cur['collectives'][k]}")
        bvp = cell.get("verify_probe")
        if isinstance(bvp, dict) and "extra_pallas_calls" in bvp:
            cvp = other.get("verify_probe")
            if not isinstance(cvp, dict) or "extra_pallas_calls" not in cvp:
                errs.append(f"{name}: verify_probe present in baseline "
                            "but missing/failed now")
            elif cvp["extra_pallas_calls"] > bvp["extra_pallas_calls"]:
                errs.append(
                    f"{name}: verify_probe extra_pallas_calls regressed "
                    f"{bvp['extra_pallas_calls']} -> "
                    f"{cvp['extra_pallas_calls']}")
    if errs:
        raise ValueError("fleet regression vs baseline:\n  "
                         + "\n  ".join(errs))


# ---------------------------------------------------------------------------
# drive + report
def run_matrix(cells: Sequence[Tuple[str, str, str]], *, matrix: str,
               iters: int = 1, verbose: bool = True) -> Dict[str, Any]:
    import jax

    out: List[Dict[str, Any]] = []
    for arch, backend, sharding in cells:
        t0 = time.perf_counter()
        cell = run_cell(arch, backend, sharding, iters=iters)
        took = time.perf_counter() - t0
        if verbose:
            marks = " ".join(
                f"{st}:{cell['stages'][st]['status']}" for st in STAGES)
            print(f"  {arch:24s} {backend:10s} {sharding:6s}  "
                  f"{marks}  ({took:.1f}s)")
            for st in STAGES:
                rec = cell["stages"][st]
                if rec["status"] == "failed":
                    print(f"      {st} FAILED: {rec['reason']}")
        out.append(cell)
    return {
        "schema_version": FLEET_SCHEMA_VERSION,
        "generated_by": "benchmarks/fleet.py",
        "jax_backend": jax.default_backend(),
        "matrix": matrix,
        "dims": dict(DIMS),
        "devices": jax.device_count(),
        "cells": out,
    }


def csv_rows_from(payload: Dict[str, Any]) -> List[Tuple[str, float, str]]:
    rows = []
    for cell in payload["cells"]:
        stem = f"fleet_{cell['arch']}_{cell['backend']}_{cell['sharding']}"
        for st in STAGES:
            rec = cell["stages"][st]
            if rec["status"] != "ok":
                rows.append((f"{stem}_{st}", 0.0, rec["status"]))
                continue
            coll = sum(rec["collectives"].values())
            rows.append((f"{stem}_{st}", rec["wall_us"],
                         f"launches={rec['pallas_calls']};collectives={coll};"
                         f"peak_mb={rec['peak_live_bytes'] / 1e6:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# forced-device re-exec (mirrors kernels_bench --sharded)
_REEXEC_SENTINEL = "REPRO_DIP_FLEET_REEXEC"


def _reexec_with_devices(argv: Sequence[str], devices: int) -> int:
    import jax

    if os.environ.get(_REEXEC_SENTINEL):
        raise SystemExit(
            f"fleet: re-exec with forced host devices still sees "
            f"{jax.device_count()} device(s) (< {devices}); check "
            "JAX_PLATFORMS/XLA_FLAGS overrides")
    env = dict(os.environ)
    env[_REEXEC_SENTINEL] = "1"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()), *argv],
        env=env, cwd=str(root))
    return proc.returncode


def _needs_reexec(cells: Sequence[Tuple[str, str, str]]) -> bool:
    if not any(s != "gspmd" for _, _, s in cells):
        return False
    import jax

    return jax.device_count() < 2


# ---------------------------------------------------------------------------
# entrypoints
def _select_cells(args) -> Tuple[List[Tuple[str, str, str]], str]:
    from repro.configs import ALL_ARCHS

    archs = args.archs.split(",") if args.archs else list(ALL_ARCHS)
    unknown = sorted(set(archs) - set(ALL_ARCHS))
    if unknown:
        raise SystemExit(f"unknown archs: {unknown}; have {ALL_ARCHS}")
    matrix = "full" if args.full else "tiny"
    cells = (full_cells if args.full else tiny_cells)(archs)
    if args.backends:
        keep = set(args.backends.split(","))
        cells = [c for c in cells if c[1] in keep]
        matrix = "custom"
    if args.shardings:
        keep = set(args.shardings.split(","))
        cells = [c for c in cells if c[2] in keep]
        matrix = "custom"
    if args.archs:
        matrix = "custom"
    if not cells:
        raise SystemExit("filters selected an empty matrix")
    return cells, matrix


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tiny", action="store_true",
                    help="committed-baseline matrix (default)")
    ap.add_argument("--full", action="store_true",
                    help="every arch x backend x sharding cell")
    ap.add_argument("--archs", default=None, help="comma list subset")
    ap.add_argument("--backends", default=None, help="comma list subset")
    ap.add_argument("--shardings", default=None, help="comma list subset")
    ap.add_argument("--iters", type=int, default=1,
                    help="timed iterations per stage")
    ap.add_argument("--out", default=None,
                    help="write BENCH_fleet.json here")
    ap.add_argument("--baseline", default=None,
                    help="diff counts against this committed baseline")
    ap.add_argument("--devices", type=int, default=2,
                    help="forced host devices for sharded cells")
    args = ap.parse_args(argv)

    cells, matrix = _select_cells(args)
    if _needs_reexec(cells):
        return _reexec_with_devices(
            list(argv) if argv is not None else sys.argv[1:], args.devices)

    print(f"== fleet: {len(cells)} cells ({matrix} matrix) ==")
    payload = run_matrix(cells, matrix=matrix, iters=args.iters)
    validate_fleet_json(payload)
    print(f"schema: OK ({len(payload['cells'])} cells)")

    if args.baseline:
        with open(args.baseline) as f:
            diff_fleet_json(payload, json.load(f))
        print(f"baseline diff vs {args.baseline}: OK")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    failed = [
        (c["arch"], c["backend"], c["sharding"], st)
        for c in payload["cells"] for st in STAGES
        if c["stages"][st]["status"] == "failed"]
    if failed:
        print(f"{len(failed)} failed stage(s):")
        for arch, backend, sharding, st in failed:
            print(f"  {arch}/{backend}/{sharding}/{st}")
        return 1
    return 0


def run(csv_rows) -> None:
    """benchmarks.run harness contract: tiny matrix, validated, diffed
    against the committed baseline when present, rows appended."""
    cells = tiny_cells([a for a in _all_archs()])
    if _needs_reexec(cells):
        # Under the single-process harness we cannot re-exec just this
        # module; drop the sharded cells and say so rather than fail.
        print("fleet: <2 devices and no re-exec under benchmarks.run; "
              "dropping tp/fsdp cells (run benchmarks/fleet.py directly "
              "for the sharded columns)")
        cells = [c for c in cells if c[2] == "gspmd"]
        matrix = "custom"
    else:
        matrix = "tiny"
    payload = run_matrix(cells, matrix=matrix)
    validate_fleet_json(payload)
    if DEFAULT_JSON.exists() and matrix == "tiny":
        with open(DEFAULT_JSON) as f:
            diff_fleet_json(payload, json.load(f))
        print(f"fleet: baseline diff vs {DEFAULT_JSON.name}: OK")
    csv_rows.extend(csv_rows_from(payload))


def _all_archs() -> List[str]:
    from repro.configs import ALL_ARCHS

    return list(ALL_ARCHS)


if __name__ == "__main__":
    raise SystemExit(main())
