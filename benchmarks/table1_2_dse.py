"""Tables I & II reproduction: 22nm design-space exploration.

Area/power come from the paper's published implementation points (our
calibration data); every DERIVED quantity (saving percentages, improvement
ratios, EE/area) is computed by repro.core.energy and compared against the
paper's Table II values.
"""

from __future__ import annotations

import time

from repro.core import energy

PAPER_TABLE_II = {  # size: (thr, power, area, overall)
    4: (1.38, 1.16, 1.06, 1.70),
    8: (1.44, 1.18, 1.08, 1.84),
    16: (1.47, 1.20, 1.09, 1.93),
    32: (1.48, 1.25, 1.09, 2.02),
    64: (1.49, 1.21, 1.07, 1.93),
}


def run(csv_rows):
    t0 = time.perf_counter()
    print("\n== Table I: area/power savings (22nm @ 1GHz) ==")
    print(f"{'N':>4} {'WS um^2':>10} {'DiP um^2':>10} {'saved%':>7} "
          f"{'WS mW':>8} {'DiP mW':>8} {'saved%':>7}")
    for n in (4, 8, 16, 32, 64):
        w = energy.hardware_point("ws", n)
        d = energy.hardware_point("dip", n)
        sa = 100 * (w.area_um2 - d.area_um2) / w.area_um2
        sp = 100 * (w.power_mw - d.power_mw) / w.power_mw
        print(f"{n:>4} {w.area_um2:>10.0f} {d.area_um2:>10.0f} {sa:>6.2f} "
              f"{w.power_mw:>8.2f} {d.power_mw:>8.2f} {sp:>6.2f}")

    print("\n== Table II: DiP-over-WS improvement ratios (computed vs paper) ==")
    print(f"{'N':>4} {'thr':>6} {'power':>6} {'area':>6} {'overall':>8}  paper_overall")
    worst = 0.0
    for n, (pt, pp, pa, po) in PAPER_TABLE_II.items():
        imp = energy.table_ii_improvements(n)
        print(f"{n:>4} {imp.throughput:>6.2f} {imp.power:>6.2f} {imp.area:>6.2f} "
              f"{imp.overall:>8.3f}  {po:.2f}")
        worst = max(worst, abs(imp.overall - po))
    print(f"max |computed - paper| overall deviation: {worst:.3f} "
          f"(paper rounds factors before multiplying)")
    dt = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("table2_overall_imp_32", dt,
                     f"{energy.table_ii_improvements(32).overall:.4f}"))
    csv_rows.append(("table2_max_dev_vs_paper", dt, f"{worst:.4f}"))
