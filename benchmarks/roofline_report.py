"""Render the docs/benchmarks.md §Dry-run / §Roofline tables from dry-run JSONL.

    PYTHONPATH=src python -m benchmarks.roofline_report results/*.jsonl

Takes the LATEST row per (arch, shape, mesh) across all inputs (so re-running
improved cells supersedes older measurements), prints the roofline table
(single-pod) and the multi-pod pass/fail matrix, in markdown.
"""

from __future__ import annotations

import glob
import json
import sys


def load_rows(patterns):
    rows = {}
    for pat in patterns:
        for path in sorted(glob.glob(pat)):
            with open(path) as f:
                for line in f:
                    try:
                        r = json.loads(line)
                    except json.JSONDecodeError:
                        continue
                    key = (r.get("arch"), r.get("shape"), r.get("mesh"))
                    rows[key] = r  # later files/lines win
    return rows


def fmt_bytes(b):
    return f"{float(b)/1e9:.1f}"


def one_liner(r):
    dom = r.get("dominant", "?")
    hints = {
        "compute": "more MXU-efficient kernels / lower remat recompute",
        "memory": "fuse streamed attention/SSD intermediates (Pallas flash kernel) / fewer relayouts",
        "collective": "cheaper TP/EP boundaries (compressed or reduce-scattered), comm overlap",
    }
    return hints.get(dom, "")


def main(argv=None):
    patterns = (list(argv) if argv is not None else sys.argv[1:]) \
        or ["results/*.jsonl"]
    rows = load_rows(patterns)

    print("### §Roofline — single-pod (16x16 = 256 chips), per-device terms\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL_FLOPS | useful ratio | roofline frac | peak GB | next lever |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(rows):
        r = rows[key]
        if r.get("mesh") != "pod16x16" or r.get("status") != "ok":
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {float(r['compute_s']):.3f} "
            f"| {float(r['memory_s']):.3f} | {float(r['collective_s']):.3f} "
            f"| {r['dominant']} | {float(r['model_flops']):.2e} "
            f"| {float(r['useful_ratio']):.3f} | {float(r['roofline_fraction']):.3f} "
            f"| {fmt_bytes(r['peak_memory_bytes'])} | {one_liner(r)} |"
        )

    print("\n### §Dry-run — multi-pod (2x16x16 = 512 chips) compile matrix\n")
    print("| arch | shape | status | peak GB/dev | coll bytes/dev GB |")
    print("|---|---|---|---|---|")
    for key in sorted(rows):
        r = rows[key]
        if r.get("mesh") != "pod2x16x16":
            continue
        ok = r.get("status") == "ok"
        peak = fmt_bytes(r["peak_memory_bytes"]) if ok else "-"
        coll = fmt_bytes(r["coll_bytes_per_dev"]) if ok else "-"
        print(f"| {r['arch']} | {r['shape']} | {'ok' if ok else r['status'][:60]} "
              f"| {peak} | {coll} |")

    n_ok = sum(1 for r in rows.values() if r.get("status") == "ok")
    print(f"\ncells: {n_ok}/{len(rows)} ok "
          f"(skips per DESIGN.md §4: long_500k on 8 full-attention archs)")


def run(csv_rows) -> None:
    """benchmarks.run harness contract.  The report is *derived* from
    dry-run JSONL, not measured here: a checkout without results/ prints a
    note and contributes no timing rows; with results it renders the tables
    and records one summary row."""
    rows = load_rows(["results/*.jsonl"])
    if not rows:
        print("roofline_report: no results/*.jsonl in this checkout; run "
              "the dry-run launcher first (see docs/benchmarks.md)")
        return
    main([])
    n_ok = sum(1 for r in rows.values() if r.get("status") == "ok")
    csv_rows.append(("roofline_cells_ok", 0.0, f"{n_ok}/{len(rows)}"))


if __name__ == "__main__":
    main()
