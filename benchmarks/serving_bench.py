"""Serving benchmark: continuous-batching engine vs the legacy wave loop.

An open-loop Poisson workload (fixed arrival times, drawn once per seed)
with varied generation lengths is replayed against both serving paths at
equal slot count:

* **paged** — ``repro.serving.Engine``: continuous admission over the paged
  KV pool; freed slots refill mid-flight, so total decode steps approach
  ``sum(gen_len) / slots``.
* **wave** — ``runtime.WaveServer``: the pre-engine static-batch loop;
  slots refill only when ALL are free, so every wave decodes for its longest
  member (``sum over waves of max(gen_len)`` steps) while finished slots
  idle, and results are only observable at wave boundaries.

Varied ``max_new`` makes the gap structural, not a tuning artifact.  All
requests decode greedily so both paths do identical model work per token.

Writes schema-validated ``BENCH_serving.json``.  The schema encodes the
acceptance contract: the engine must beat the wave baseline on tokens/sec
AND p99 latency, and int8 paged KV must fit strictly more blocks (and
concurrent sequences) than bf16 in the same byte budget — a regression
fails validation, not just a test somewhere else.

    PYTHONPATH=src python benchmarks/serving_bench.py --tiny
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf_model
from repro.runtime.server import Request, ServerConfig, WaveServer
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving import kv_cache as kvc

SERVING_SCHEMA_VERSION = 1
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"


# ------------------------------------------------------------- workload ----
@dataclasses.dataclass
class Arrival:
    rid: int
    at_s: float                 # offset from workload start
    prompt: np.ndarray
    max_new: int


def make_workload(cfg, *, requests: int, rate_rps: float, seed: int,
                  prompt_range=(4, 16), max_new_range=(2, 24)) -> List[Arrival]:
    """Open-loop Poisson arrivals: exponential gaps at ``rate_rps``, varied
    prompt and generation lengths — drawn once, replayed for every engine."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(requests):
        t += float(rng.exponential(1.0 / rate_rps))
        out.append(Arrival(
            rid=rid,
            at_s=t,
            prompt=rng.integers(2, cfg.vocab_size,
                                size=int(rng.integers(*prompt_range))).astype(np.int32),
            max_new=int(rng.integers(max_new_range[0], max_new_range[1] + 1)),
        ))
    return out


def _percentiles(latencies: List[float]) -> Dict[str, float]:
    arr = np.asarray(latencies, np.float64)
    return {
        "p50_latency_s": round(float(np.percentile(arr, 50)), 4),
        "p99_latency_s": round(float(np.percentile(arr, 99)), 4),
    }


# -------------------------------------------------------------- drivers ----
def run_engine(cfg, params, workload: List[Arrival], *, slots: int,
               max_seq: int, prefill_chunk: int, block_size: Optional[int],
               kv_quant: str) -> Dict:
    eng = Engine(cfg, params, engine_cfg=EngineConfig(
        slots=slots, max_seq=max_seq, prefill_chunk=prefill_chunk,
        block_size=block_size, kv_quant=kv_quant,
    ))
    pending = collections.deque(sorted(workload, key=lambda a: a.at_s))
    t0 = time.monotonic()
    busy = True
    while pending or busy:
        now = time.monotonic() - t0
        while pending and pending[0].at_s <= now:
            a = pending.popleft()
            eng.add_request(a.prompt, SamplingParams(max_new_tokens=a.max_new),
                            rid=a.rid)
        busy = eng.step()
        if not busy and pending:
            time.sleep(min(5e-4, max(0.0, pending[0].at_s - now)))
    wall = time.monotonic() - t0
    total = sum(s["new_tokens"] for s in eng.request_stats.values())
    rec = {
        "tok_per_s": round(total / max(wall, 1e-9), 2),
        "total_tokens": total,
        "wall_s": round(wall, 4),
        "decode_steps": eng._decode_steps,
        "prefill_chunks": eng._prefill_chunks,
        "preemptions": eng._preempt_count,
    }
    rec.update(_percentiles(
        [s["latency_s"] for s in eng.request_stats.values()]
    ))
    return rec


def run_wave(cfg, params, workload: List[Arrival], *, slots: int,
             max_seq: int, max_new_cap: int) -> Dict:
    ws = WaveServer(cfg, ServerConfig(
        batch_slots=slots, max_seq=max_seq, max_new_tokens=max_new_cap,
        temperature=0.0, top_k=0,
    ), params)
    pending = collections.deque(sorted(workload, key=lambda a: a.at_s))
    queue: List[Arrival] = []
    latencies: List[float] = []
    total = 0
    steps = 0
    t0 = time.monotonic()
    while pending or queue:
        now = time.monotonic() - t0
        while pending and pending[0].at_s <= now:
            queue.append(pending.popleft())
        if not queue:
            time.sleep(min(5e-4, max(0.0, pending[0].at_s - now)))
            continue
        wave, queue = queue[:slots], queue[slots:]
        reqs = [Request(rid=a.rid, prompt=a.prompt, max_new=a.max_new)
                for a in wave]
        ws.serve(reqs)
        steps += ws.last_stats["decode_steps"]
        # a synchronous static-batch loop surfaces results at wave boundaries
        end = time.monotonic() - t0
        for a, r in zip(wave, reqs):
            total += len(r.out_tokens)
            latencies.append(end - a.at_s)
    wall = time.monotonic() - t0
    rec = {
        "tok_per_s": round(total / max(wall, 1e-9), 2),
        "total_tokens": total,
        "wall_s": round(wall, 4),
        "decode_steps": steps,
    }
    rec.update(_percentiles(latencies))
    return rec


# ------------------------------------------------------------- capacity ----
def capacity_record(cfg, *, slots: int, max_seq: int,
                    block_size: Optional[int]) -> Optional[Dict]:
    """int8-vs-bf16 blocks (and sequences of max_seq tokens) a fixed byte
    budget holds — the budget is what the bf16 pool at full occupancy costs."""
    if cfg.is_ssm:
        return None   # pure SSM: no paged KV bytes (state is O(1) per slot)
    bs = block_size or cfg.kv_block_size
    blocks_per_seq = -(-max_seq // bs)
    budget = (slots * blocks_per_seq + 1) * kvc.bytes_per_block(cfg, bs, "none")
    bf16 = kvc.blocks_for_budget(cfg, budget, bs, "none")
    int8 = kvc.blocks_for_budget(cfg, budget, bs, "int8")
    return {
        "budget_bytes": budget,
        "block_size": bs,
        "seq_len": max_seq,
        "bf16_blocks": bf16,
        "int8_blocks": int8,
        "bf16_max_concurrent": kvc.max_concurrent(cfg, bf16, max_seq, bs),
        "int8_max_concurrent": kvc.max_concurrent(cfg, int8, max_seq, bs),
    }


# ----------------------------------------------------------------- JSON ----
def write_serving_json(path, payload: Dict) -> pathlib.Path:
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return p


def validate_serving_json(path) -> Dict:
    """Schema check for BENCH_serving.json; returns the parsed payload.
    Raises ValueError on any violation (run by the CI serving job)."""
    payload = json.loads(pathlib.Path(path).read_text())

    def need(cond, msg):
        if not cond:
            raise ValueError(f"BENCH_serving.json schema violation: {msg}")

    need(payload.get("schema_version") == SERVING_SCHEMA_VERSION,
         f"schema_version != {SERVING_SCHEMA_VERSION}")
    need(isinstance(payload.get("arch"), str) and payload["arch"], "arch")
    need(isinstance(payload.get("slots"), int) and payload["slots"] >= 1, "slots")
    wl = payload.get("workload")
    need(isinstance(wl, dict), "workload must be a dict")
    for key in ("requests", "arrival_rate_rps", "seed", "max_new_range"):
        need(key in wl, f"workload missing {key!r}")
    engines = payload.get("engines")
    need(isinstance(engines, dict) and {"paged", "wave"} <= set(engines),
         "engines must record both 'paged' and 'wave'")
    for name, rec in engines.items():
        for key in ("tok_per_s", "p50_latency_s", "p99_latency_s",
                    "total_tokens", "decode_steps"):
            need(isinstance(rec.get(key), (int, float)),
                 f"engines.{name} missing/invalid {key!r}")
    paged, wave = engines["paged"], engines["wave"]
    # the acceptance contract IS the schema: the continuous-batching engine
    # must beat the static-batch wave loop on BOTH axes at equal slots
    need(paged["tok_per_s"] > wave["tok_per_s"],
         f"engine tok/s {paged['tok_per_s']} <= wave {wave['tok_per_s']}")
    need(paged["p99_latency_s"] < wave["p99_latency_s"],
         f"engine p99 {paged['p99_latency_s']} >= wave {wave['p99_latency_s']}")
    cap = payload.get("capacity")
    if cap is not None:
        for key in ("budget_bytes", "bf16_blocks", "int8_blocks",
                    "bf16_max_concurrent", "int8_max_concurrent"):
            need(isinstance(cap.get(key), int), f"capacity missing {key!r}")
        need(cap["int8_blocks"] > cap["bf16_blocks"],
             "int8 must fit strictly more blocks than bf16 at fixed bytes")
        need(cap["int8_max_concurrent"] > cap["bf16_max_concurrent"],
             "int8 must serve strictly more concurrent sequences than bf16")
    return payload


# ----------------------------------------------------------------- main ----
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/serving_bench.py",
        description="engine-vs-wave serving benchmark; writes BENCH_serving.json",
    )
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate (requests/sec)")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new-range", type=int, nargs=2, default=(2, 32))
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=None)
    ap.add_argument("--kv-quant", choices=("none", "int8"), default="none",
                    help="KV storage for the paged engine run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tiny", action="store_true",
                    help="small CI smoke (fewer requests, shorter outputs)")
    ap.add_argument("--out", default=str(DEFAULT_JSON))
    args = ap.parse_args(argv)

    if args.tiny:
        args.requests = min(args.requests, 10)
        args.max_new_range = (2, 16)
        args.max_seq = min(args.max_seq, 64)

    cfg = get_config(args.arch).reduced()
    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    workload = make_workload(
        cfg, requests=args.requests, rate_rps=args.rate, seed=args.seed,
        max_new_range=tuple(args.max_new_range),
    )
    print(f"== serving bench: {args.arch} reduced, {args.requests} requests, "
          f"{args.slots} slots, rate {args.rate}/s ==")

    paged = run_engine(
        cfg, params, workload, slots=args.slots, max_seq=args.max_seq,
        prefill_chunk=args.prefill_chunk, block_size=args.block_size,
        kv_quant=args.kv_quant,
    )
    print(f"paged: {paged['tok_per_s']} tok/s, p50 {paged['p50_latency_s']}s, "
          f"p99 {paged['p99_latency_s']}s, {paged['decode_steps']} decode steps")
    wave = run_wave(
        cfg, params, workload, slots=args.slots, max_seq=args.max_seq,
        max_new_cap=max(args.max_new_range),
    )
    print(f"wave:  {wave['tok_per_s']} tok/s, p50 {wave['p50_latency_s']}s, "
          f"p99 {wave['p99_latency_s']}s, {wave['decode_steps']} decode steps")

    payload = {
        "schema_version": SERVING_SCHEMA_VERSION,
        "generated_by": "benchmarks/serving_bench.py",
        "jax_backend": jax.default_backend(),
        "arch": args.arch,
        "slots": args.slots,
        "kv_quant": args.kv_quant,
        "workload": {
            "requests": args.requests,
            "arrival_rate_rps": args.rate,
            "max_new_range": list(args.max_new_range),
            "seed": args.seed,
        },
        "engines": {"paged": paged, "wave": wave},
        "capacity": capacity_record(cfg, slots=args.slots,
                                    max_seq=args.max_seq,
                                    block_size=args.block_size),
    }
    path = write_serving_json(args.out, payload)
    validate_serving_json(path)
    print(f"machine-readable record: {path}")
    return 0


def run(csv_rows) -> None:
    """benchmarks.run harness contract: tiny smoke into a temp file (the
    committed BENCH_serving.json is refreshed explicitly, not by the
    harness), schema-validated, throughput/latency appended as CSV rows."""
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        out = pathlib.Path(td) / "BENCH_serving.json"
        rc = main(["--tiny", "--out", str(out)])
        if rc != 0:
            raise RuntimeError("serving bench returned nonzero")
        with open(out) as f:
            payload = json.load(f)
    for kind in ("paged", "wave"):
        rec = payload["engines"][kind]
        csv_rows.append((
            f"serving_{payload['arch']}_{kind}",
            float(rec["p50_latency_s"]) * 1e6,
            f"tok_per_s={rec['tok_per_s']};decode_steps={rec['decode_steps']}",
        ))


if __name__ == "__main__":
    raise SystemExit(main())
