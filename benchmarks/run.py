"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig6,...]

Prints each experiment's human-readable report followed by a
``name,us_per_call,derived`` CSV block (the harness contract).
"""

from __future__ import annotations

import argparse

from benchmarks import (
    fig5_analytical, fig6_workloads, fleet, kernels_bench, roofline_report,
    serving_bench, table1_2_dse, table4_comparison,
)

MODULES = {
    "fig5": fig5_analytical,
    "table1_2": table1_2_dse,
    "fig6": fig6_workloads,
    "table4": table4_comparison,
    "kernels": kernels_bench,
    "serving": serving_bench,
    "roofline": roofline_report,
    "fleet": fleet,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of "
                    + ",".join(MODULES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)

    csv_rows = []
    for name in names:
        MODULES[name].run(csv_rows)

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
