"""Kernel micro-benchmarks (beyond paper): wall-time of the jit'd DiP ops on
this host plus the structural de-shear overhead ablation.

On CPU the Pallas kernels run in interpret mode, so absolute times are not
TPU-representative; what IS meaningful here: (a) the XLA-path DiP storage
format overhead (unpermute-then-dot vs plain dot — the fast path the
framework uses when not on TPU), and (b) interpret-mode parity checks that
accompany the timing so a regression cannot silently pass.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(csv_rows):
    print("\n== kernel micro-benchmarks (CPU host; Pallas in interpret mode) ==")
    r = np.random.default_rng(0)
    m, k, n = 512, 1024, 1024
    x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)

    plain = jax.jit(lambda a, b: a @ b)
    # the distributed default: de-shear as a gather, then the XLA dot
    desheared = jax.jit(lambda a, d: api.matmul(a, d, backend="xla"))

    t_plain = _time(plain, x, w)
    t_dip_xla = _time(desheared, x, dw)
    overhead = (t_dip_xla - t_plain) / t_plain * 100
    print(f"XLA plain matmul {m}x{k}x{n}:          {t_plain:9.1f} us")
    print(f"XLA matmul from DiP storage (+unshear): {t_dip_xla:9.1f} us "
          f"({overhead:+.1f}% — amortized de-shear cost)")

    # correctness parity accompanying the timings
    got = desheared(x, dw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain(x, w)), atol=2e-3)

    # tuning-table resolution for this shape (what the Pallas path would use)
    blocks = api.lookup_blocks("pallas_dip", m, k, n, x.dtype)
    print(f"tuning table -> pallas_dip blocks for {m}x{k}x{n} f32: {tuple(blocks)}")

    # interpret-mode pallas timing (documentation only — Python emulation)
    tiny_x = x[:64, :256]
    tiny_w = api.DipWeight.from_natural(w[:256, :256])
    t_pallas = _time(
        lambda a, d: api.matmul(a, d, backend="pallas_dip", interpret=True),
        tiny_x, tiny_w, iters=3,
    )
    print(f"Pallas pallas_dip 64x256x256 (interpret): {t_pallas:9.1f} us "
          f"(Python emulation — TPU path compiles via Mosaic)")

    # tuned-vs-heuristic delta on the same workload: what the autotuner's
    # measured entry buys over whatever the table currently resolves
    # (register=False keeps the benchmark from mutating the global table)
    from repro.api import autotune

    res = autotune.autotune_shape(
        "pallas_dip", 64, 256, 256, "float32",
        iters=2, warmup=1, interpret=True, max_candidates=4, register=False,
    )
    t_inc, t_best = res.incumbent_time_us, res.best.time_us
    speedup = res.speedup_vs_incumbent() or 1.0
    print(f"autotune 64x256x256 f32: incumbent {tuple(res.incumbent)} "
          f"{t_inc:9.1f} us -> best {tuple(res.best.blocks)} {t_best:9.1f} us "
          f"({speedup:.2f}x; {len(res.measurements)} candidates)")

    csv_rows.append(("kern_xla_plain_matmul", t_plain, f"{2*m*k*n/ (t_plain*1e-6) /1e9:.1f}GFLOP/s"))
    csv_rows.append(("kern_xla_dip_storage", t_dip_xla, f"overhead_{overhead:+.1f}%"))
    csv_rows.append(("kern_pallas_interpret", t_pallas, "interpret_mode"))
    csv_rows.append(("kern_autotune_best", t_best, f"tuned_vs_incumbent_{speedup:.2f}x"))
