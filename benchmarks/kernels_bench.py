"""Kernel micro-benchmarks (beyond paper): wall-time of the jit'd DiP ops on
this host plus the structural de-shear overhead ablation.

On CPU the Pallas kernels run in interpret mode, so absolute times are not
TPU-representative; what IS meaningful here: (a) the XLA-path DiP storage
format overhead (unpermute-then-dot vs plain dot — the fast path the
framework uses when not on TPU), and (b) interpret-mode parity checks that
accompany the timing so a regression cannot silently pass.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(csv_rows):
    print("\n== kernel micro-benchmarks (CPU host; Pallas in interpret mode) ==")
    r = np.random.default_rng(0)
    m, k, n = 512, 1024, 1024
    x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)

    plain = jax.jit(lambda a, b: a @ b)
    # the distributed default: de-shear as a gather, then the XLA dot
    desheared = jax.jit(lambda a, d: api.matmul(a, d, backend="xla"))

    t_plain = _time(plain, x, w)
    t_dip_xla = _time(desheared, x, dw)
    overhead = (t_dip_xla - t_plain) / t_plain * 100
    print(f"XLA plain matmul {m}x{k}x{n}:          {t_plain:9.1f} us")
    print(f"XLA matmul from DiP storage (+unshear): {t_dip_xla:9.1f} us "
          f"({overhead:+.1f}% — amortized de-shear cost)")

    # correctness parity accompanying the timings
    got = desheared(x, dw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain(x, w)), atol=2e-3)

    # tuning-table resolution for this shape (what the Pallas path would use)
    blocks = api.lookup_blocks("pallas_dip", m, k, n, x.dtype)
    print(f"tuning table -> pallas_dip blocks for {m}x{k}x{n} f32: {tuple(blocks)}")

    # interpret-mode pallas timing (documentation only — Python emulation)
    tiny_x = x[:64, :256]
    tiny_w = api.DipWeight.from_natural(w[:256, :256])
    t_pallas = _time(
        lambda a, d: api.matmul(a, d, backend="pallas_dip", interpret=True),
        tiny_x, tiny_w, iters=3,
    )
    print(f"Pallas pallas_dip 64x256x256 (interpret): {t_pallas:9.1f} us "
          f"(Python emulation — TPU path compiles via Mosaic)")

    # tuned-vs-heuristic delta on the same workload: what the autotuner's
    # measured entry buys over whatever the table currently resolves
    # (register=False keeps the benchmark from mutating the global table)
    from repro.api import autotune

    res = autotune.autotune_shape(
        "pallas_dip", 64, 256, 256, "float32",
        iters=2, warmup=1, interpret=True, max_candidates=4, register=False,
    )
    t_inc, t_best = res.incumbent_time_us, res.best.time_us
    speedup = res.speedup_vs_incumbent() or 1.0
    print(f"autotune 64x256x256 f32: incumbent {tuple(res.incumbent)} "
          f"{t_inc:9.1f} us -> best {tuple(res.best.blocks)} {t_best:9.1f} us "
          f"({speedup:.2f}x; {len(res.measurements)} candidates)")

    # quantized-vs-float deltas (the dip_int8w / dip_fp8 backends).  On this
    # CPU host the meaningful comparison is the XLA-path analog: a quantized
    # weight served through the natural-layout backend (dequant + dot) vs the
    # plain bf16 dot — storage shrinks 4x (int8) / 2x (fp8) while the dequant
    # epilogue rides the same amortization as the de-shear.  The Pallas
    # quantized kernel itself is timed at interpret scale like the float one.
    from repro.api import quant

    xb = x.astype(jnp.bfloat16)
    wb = w.astype(jnp.bfloat16)
    plain_bf16 = jax.jit(lambda a, b: a @ b)
    t_bf16 = _time(plain_bf16, xb, wb)
    for scheme in ("int8", "fp8_e4m3"):
        qw = quant.quantize(w, scheme)
        deq = jax.jit(lambda a, d: api.matmul(a, d, backend="xla"))
        t_q = _time(deq, xb, qw)
        delta = (t_q - t_bf16) / t_bf16 * 100
        bytes_ratio = jnp.dtype(qw.dtype).itemsize / 2.0  # vs bf16 storage
        print(f"XLA matmul from {scheme} storage (+dequant):  {t_q:9.1f} us "
              f"({delta:+.1f}% vs bf16 dot; {bytes_ratio:.1f}x weight bytes)")
        err = np.abs(
            np.asarray(deq(xb, qw), np.float32)
            - np.asarray(plain_bf16(xb, wb), np.float32)
        ).max() / np.abs(np.asarray(plain_bf16(xb, wb), np.float32)).max()
        print(f"  max rel deviation vs bf16: {err:.4f} "
              f"(documented bound: docs/quantization.md)")
        assert err < 0.05, f"{scheme} deviation {err} beyond documented bound"
        csv_rows.append((f"kern_xla_{scheme}_storage", t_q,
                         f"delta_vs_bf16_{delta:+.1f}%"))

    t_q_pallas = _time(
        lambda a, d: api.matmul(a, d, backend="dip_int8w", interpret=True),
        tiny_x, quant.quantize(w[:256, :256], "int8"), iters=3,
    )
    print(f"Pallas dip_int8w 64x256x256 (interpret):  {t_q_pallas:9.1f} us "
          f"(Python emulation; vs float pallas_dip {t_pallas:9.1f} us)")

    csv_rows.append(("kern_xla_plain_matmul", t_plain, f"{2*m*k*n/ (t_plain*1e-6) /1e9:.1f}GFLOP/s"))
    csv_rows.append(("kern_xla_dip_storage", t_dip_xla, f"overhead_{overhead:+.1f}%"))
    csv_rows.append(("kern_pallas_interpret", t_pallas, "interpret_mode"))
    csv_rows.append(("kern_pallas_int8w_interpret", t_q_pallas, "interpret_mode"))
    csv_rows.append(("kern_autotune_best", t_best, f"tuned_vs_incumbent_{speedup:.2f}x"))
