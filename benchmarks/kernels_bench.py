"""Kernel micro-benchmarks (beyond paper): wall-time of the jit'd DiP ops on
this host plus the structural de-shear overhead ablation and the
fused-vs-unfused epilogue comparison.

On CPU the Pallas kernels run in interpret mode, so absolute times are not
TPU-representative; what IS meaningful here: (a) the XLA-path DiP storage
format overhead (unpermute-then-dot vs plain dot — the fast path the
framework uses when not on TPU), (b) interpret-mode parity checks that
accompany the timing so a regression cannot silently pass, and (c) the
*structural* fused-epilogue evidence — the fused SwiGLU dispatch issues ONE
kernel launch where the unfused path issues three ops (two matmul launches
plus the elementwise silu*mul), counted directly in the jaxpr.

Every run writes ``BENCH_kernels.json`` (schema below) so the perf
trajectory is machine-readable across PRs; the CI ``bench-smoke`` job runs
``python benchmarks/kernels_bench.py --compare-epilogues --tiny`` and
validates the file with :func:`validate_bench_json`.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import api

BENCH_SCHEMA_VERSION = 1
DEFAULT_JSON = "BENCH_kernels.json"

# epilogues exercised by the fused-vs-unfused comparison (every variant with
# at least one extra operand or a second weight; "none" is the baseline)
_COMPARE_EPILOGUES = ("bias", "bias_silu", "swiglu", "residual")


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


# ---------------------------------------------------------------------------
# structural evidence: kernel launches per dispatch, counted in the jaxpr
def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call equations a traced call would launch (recursing
    through pjit/custom_vjp/scan sub-jaxprs)."""
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> int:
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                total += 1
            for sub in jax.core.jaxprs_in_params(eqn.params):
                total += walk(sub)
        return total

    return walk(closed.jaxpr)


# ---------------------------------------------------------------------------
# fused-vs-unfused epilogue comparison
def compare_epilogues(
    *,
    backend: str = "pallas_dip",
    m: int = 64,
    k: int = 256,
    n: int = 256,
    iters: int = 3,
    interpret: Optional[bool] = None,
    verbose: bool = True,
) -> dict:
    """Time every fused epilogue against its decomposed (unfused) form on
    the same backend and count kernel launches for both.

    Returns the machine-readable dict recorded under ``epilogue_compare`` in
    ``BENCH_kernels.json``.  Parity against the shared f32 epilogue
    arithmetic is asserted alongside the timings, so a fused-path regression
    cannot silently pass the benchmark.
    """
    if interpret is None:
        interpret = api.default_interpret()
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
    wg = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    wu = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    bias = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))
    resid = jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32))

    be = api.get_backend(backend)
    if be.layout == "dip_q":
        wrap = lambda w: api.quant.quantize(w, be.scheme)
    elif be.layout == "dip":
        wrap = api.DipWeight.from_natural
    else:
        wrap = lambda w: w
    g, u = wrap(wg), wrap(wu)

    def operands_for(epilogue):
        if epilogue == "swiglu":
            return (g, u), ()
        if epilogue.startswith("bias"):
            return g, (bias,)
        return g, (resid,)

    def fused_fn(epilogue):
        w, eops = operands_for(epilogue)
        return jax.jit(lambda: api.matmul(
            x, w, backend=backend, epilogue=epilogue, epilogue_operands=eops,
            interpret=interpret,
        ))

    def unfused_fn(epilogue):
        # the decomposed form every call site used before this subsystem:
        # separate matmul launch(es) + elementwise glue through HBM
        def f():
            if epilogue == "swiglu":
                zg = api.matmul(x, g, backend=backend, interpret=interpret)
                zu = api.matmul(x, u, backend=backend, interpret=interpret)
                return (jax.nn.silu(zg.astype(jnp.float32))
                        * zu.astype(jnp.float32)).astype(zg.dtype)
            z = api.matmul(x, g, backend=backend, interpret=interpret)
            z32 = z.astype(jnp.float32)
            if epilogue == "bias":
                out = z32 + bias
            elif epilogue == "bias_silu":
                out = jax.nn.silu(z32 + bias)
            else:
                out = z32 + resid
            return out.astype(z.dtype)
        return jax.jit(f)

    results = []
    for epilogue in _COMPARE_EPILOGUES:
        fused, unfused = fused_fn(epilogue), unfused_fn(epilogue)
        got, want = fused(), unfused()
        np.testing.assert_allclose(   # parity rides with the timing
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=2e-3, rtol=2e-3,
        )
        t_fused = _time(fused, iters=iters)
        t_unfused = _time(unfused, iters=iters)
        n_fused = count_pallas_calls(fused)
        n_unfused = count_pallas_calls(unfused)
        rec = {
            "epilogue": epilogue,
            "fused_us": round(t_fused, 1),
            "unfused_us": round(t_unfused, 1),
            "speedup": round(t_unfused / t_fused, 3),
            "fused_pallas_calls": n_fused,
            "unfused_pallas_calls": n_unfused,
        }
        results.append(rec)
        if verbose:
            ops = "3 ops (2 matmul + silu*mul)" if epilogue == "swiglu" else \
                  f"{n_unfused} launch(es) + elementwise"
            print(f"  {epilogue:>9}: fused {t_fused:9.1f} us "
                  f"({n_fused} kernel launch) vs unfused {t_unfused:9.1f} us "
                  f"({ops}) -> {rec['speedup']:.2f}x")
    if be.tiled:
        swiglu = next(r_ for r_ in results if r_["epilogue"] == "swiglu")
        assert swiglu["fused_pallas_calls"] == 1, (
            f"fused swiglu must be ONE kernel launch, traced "
            f"{swiglu['fused_pallas_calls']}"
        )
        assert swiglu["unfused_pallas_calls"] >= 2, "unfused swiglu lost its launches?"
    return {
        "backend": backend,
        "shape": [m, k, n],
        "mode": "interpret" if interpret else "compiled",
        "results": results,
    }


# ---------------------------------------------------------------------------
# fused-vs-unfused prologue comparison (the load-stage mirror of the above)
def compare_prologues(
    *,
    backend: str = "pallas_dip",
    m: int = 64,
    k: int = 256,
    n: int = 256,
    iters: int = 3,
    interpret: Optional[bool] = None,
    verbose: bool = True,
) -> dict:
    """Time the fused rmsnorm prologue against its decomposed form (the
    rms_norm -> matmul composition every block ran before this subsystem)
    and count kernel launches for both.  Parity is asserted alongside the
    timings.  Recorded under ``prologue_compare`` in BENCH_kernels.json."""
    from repro.kernels import prologue as prologue_lib

    if interpret is None:
        interpret = api.default_interpret()
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
    wn = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    g = jnp.asarray(r.normal(1, 0.1, (k,)).astype(np.float32))
    bias = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))

    be = api.get_backend(backend)
    if be.layout == "dip_q":
        w = api.quant.quantize(wn, be.scheme)
    elif be.layout == "dip":
        w = api.DipWeight.from_natural(wn)
    else:
        w = wn

    # with and without a fused epilogue riding the same launch: the second
    # row is the full per-projection story (norm + matmul + bias_silu, ONE
    # kernel where the unfused path pays three HBM round-trips)
    cases = [("rmsnorm", "none", ()), ("rmsnorm", "bias_silu", (bias,))]
    results = []
    for prologue, epilogue, eops in cases:
        fused = jax.jit(lambda _e=epilogue, _o=eops: api.matmul(
            x, w, backend=backend, prologue="rmsnorm", prologue_operands=(g,),
            epilogue=_e, epilogue_operands=_o, interpret=interpret,
        ))

        def unfused(_e=epilogue, _o=eops):
            xn = prologue_lib.apply("rmsnorm", x, g)  # separate norm pass
            z = api.matmul(xn, w, backend=backend, interpret=interpret)
            if _e == "none":
                return z
            return jax.nn.silu(z.astype(jnp.float32) + _o[0]).astype(z.dtype)

        unfused = jax.jit(unfused)
        got, want = fused(), unfused()
        np.testing.assert_allclose(   # parity rides with the timing
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=2e-3, rtol=2e-3,
        )
        t_fused = _time(fused, iters=iters)
        t_unfused = _time(unfused, iters=iters)
        n_fused = count_pallas_calls(fused)
        n_unfused = count_pallas_calls(unfused)
        label = prologue if epilogue == "none" else f"{prologue}+{epilogue}"
        rec = {
            "prologue": prologue,
            "epilogue": epilogue,
            "fused_us": round(t_fused, 1),
            "unfused_us": round(t_unfused, 1),
            "speedup": round(t_unfused / t_fused, 3),
            "fused_pallas_calls": n_fused,
            "unfused_pallas_calls": n_unfused,
        }
        results.append(rec)
        if verbose:
            print(f"  {label:>18}: fused {t_fused:9.1f} us "
                  f"({n_fused} kernel launch) vs unfused {t_unfused:9.1f} us "
                  f"({n_unfused} launch(es) + norm pass) -> {rec['speedup']:.2f}x")
    if be.tiled:
        for rec in results:
            assert rec["fused_pallas_calls"] == 1, (
                f"fused prologue dispatch must be ONE kernel launch, traced "
                f"{rec['fused_pallas_calls']} ({rec['epilogue']})"
            )
    return {
        "backend": backend,
        "shape": [m, k, n],
        "mode": "interpret" if interpret else "compiled",
        "results": results,
    }


# ---------------------------------------------------------------------------
# fused lm_head+CE and flash-attention structural smoke
def fused_upstream_smoke(
    *,
    t_tokens: int = 96,
    d_model: int = 64,
    vocab: int = 512,
    iters: int = 3,
    interpret: Optional[bool] = None,
    verbose: bool = True,
) -> dict:
    """Structural evidence for the two fused losses of the upstream story:

    * fused lm_head+CE — ONE pallas launch forward, and NO logits-sized
      ((rows >= T) x (cols >= V)) intermediate anywhere in the loss+grad
      jaxpr (the unfused oracle has one — asserted as the control);
    * flash attention through the registry — ONE pallas launch vs zero for
      the dense xla oracle, parity asserted.

    Recorded under ``fused_upstream`` in BENCH_kernels.json.
    """
    from repro.kernels import lm_head_ce
    from repro.kernels.flash_attention import flash_attention_pallas  # noqa: F401

    if interpret is None:
        interpret = api.default_interpret()
    r = np.random.default_rng(0)
    tt, d, v = t_tokens, d_model, vocab
    assert tt > d, "T must exceed d_model so dW cannot alias the predicate"
    x = jnp.asarray(r.normal(0, 1, (tt, d)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (d, v)).astype(np.float32))
    labels = jnp.asarray(r.integers(0, v, (tt,)).astype(np.int32))

    def fused_loss(xx, ww):
        return lm_head_ce.fused_cross_entropy_loss(
            xx, ww, labels, vocab_size=v, block_v=128, interpret=interpret)

    def unfused_loss(xx, ww):
        return lm_head_ce.reference_lm_head_ce(xx, ww, labels, vocab_size=v)

    got, want = float(fused_loss(x, w)), float(unfused_loss(x, w))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def logits_like(closed):
        hits = []

        def walk(jx):
            for eqn in jx.eqns:
                for var in eqn.outvars:
                    shape = getattr(getattr(var, "aval", None), "shape", ())
                    if (len(shape) >= 2 and shape[-1] >= v
                            and int(np.prod(shape[:-1])) >= tt):
                        hits.append(tuple(shape))
                for sub in jax.core.jaxprs_in_params(eqn.params):
                    walk(sub)

        walk(closed.jaxpr)
        return hits

    grad_fused = jax.make_jaxpr(jax.grad(fused_loss, argnums=(0, 1)))(x, w)
    grad_unfused = jax.make_jaxpr(jax.grad(unfused_loss, argnums=(0, 1)))(x, w)
    ce_logits_free = not logits_like(grad_fused)
    assert ce_logits_free, "fused CE materialized a logits-sized tensor"
    assert logits_like(grad_unfused), "control: oracle should materialize logits"
    ce_launches = count_pallas_calls(lambda a, b: fused_loss(a, b), x, w)
    assert ce_launches == 1, f"fused CE forward traced {ce_launches} launches"
    jit_f, jit_u = jax.jit(fused_loss), jax.jit(unfused_loss)
    t_f = _time(jit_f, x, w, iters=iters)
    t_u = _time(jit_u, x, w, iters=iters)
    ce = {
        "shape": [tt, d, v],
        "fused_us": round(t_f, 1),
        "unfused_us": round(t_u, 1),
        "pallas_calls": ce_launches,
        "logits_free_grad": bool(ce_logits_free),
    }
    if verbose:
        print(f"  fused lm_head+CE {tt}x{d}x{v}: {t_f:9.1f} us "
              f"({ce_launches} launch, logits-free grad) vs unfused "
              f"{t_u:9.1f} us")

    bh, sq, sk, hd = 4, 64, 64, 32
    q = jnp.asarray(r.normal(0, 1, (bh, sq, hd)).astype(np.float32))
    kk = jnp.asarray(r.normal(0, 1, (bh, sk, hd)).astype(np.float32))
    vv = jnp.asarray(r.normal(0, 1, (bh, sk, hd)).astype(np.float32))
    flash = jax.jit(lambda a, b, c: api.attention(
        a, b, c, backend="flash", block_q=32, block_k=32, interpret=interpret))
    dense = jax.jit(lambda a, b, c: api.attention(a, b, c, backend="xla"))
    np.testing.assert_allclose(
        np.asarray(flash(q, kk, vv)), np.asarray(dense(q, kk, vv)),
        atol=2e-3, rtol=2e-3,
    )
    fl_launches = count_pallas_calls(flash, q, kk, vv)
    assert fl_launches == 1, f"flash dispatch traced {fl_launches} launches"
    t_fl = _time(flash, q, kk, vv, iters=iters)
    t_dn = _time(dense, q, kk, vv, iters=iters)
    fa = {
        "shape": [bh, sq, sk, hd],
        "flash_us": round(t_fl, 1),
        "xla_us": round(t_dn, 1),
        "pallas_calls": fl_launches,
    }
    if verbose:
        print(f"  flash attention {bh}x{sq}x{sk}x{hd}: {t_fl:9.1f} us "
              f"({fl_launches} launch) vs dense xla {t_dn:9.1f} us")
    return {
        "mode": "interpret" if interpret else "compiled",
        "lm_head_ce": ce,
        "flash_attention": fa,
    }


# ---------------------------------------------------------------------------
# explicit-sharding comparison (dip_tp vs GSPMD-xla on virtual devices)
def compare_sharded(
    *,
    m: int = 16,
    k: int = 256,
    n: int = 256,
    iters: int = 3,
    verbose: bool = True,
) -> dict:
    """Time the explicit ``dip_tp``/``dip_fsdp`` shard_map dispatch against
    the implicit GSPMD-on-xla path on the live (virtual) mesh, and record
    launch/collective counts for both.

    Structural evidence, not wall-clock truth: on forced-host CPU devices
    both paths run emulated, so the *counts* are the durable signal — the
    explicit backends' collectives come straight from the jaxpr (zero for
    column, one psum for row, one all_gather for fsdp) while GSPMD's are
    counted from the partitioned HLO, where XLA chose them.  Parity between
    the two paths is asserted alongside the timings.
    """
    from repro.distributed.plan import WeightPlan, make_local_mesh
    from repro.kernels.dip_matmul_sharded import count_collectives

    devs = jax.device_count()
    model = 4 if devs % 4 == 0 else devs
    mesh = make_local_mesh(data=devs // model, model=model)
    col = WeightPlan("column", axis="model", fsdp="data", mesh=mesh)
    row = WeightPlan("row", axis="model", fsdp="data", mesh=mesh)

    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
    wn = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))

    def gspmd_fn(spec):
        # the implicit path: same DiP storage placed with the CASE's
        # partitioning (column: N over TP; row: K over TP; fsdp: K over
        # data) and the dot left to GSPMD
        dw = api.DipWeight.from_natural(wn)
        dws = dw.with_data(
            jax.device_put(dw.data, jax.sharding.NamedSharding(mesh, spec))
        )
        return jax.jit(lambda a: api.matmul(a, dws, backend="xla")), dws

    def hlo_collectives(jitted, *args) -> int:
        txt = jitted.lower(*args).compile().as_text()
        return sum(txt.count(s) for s in
                   ("all-reduce(", "all-gather(", "collective-permute(",
                    "all-to-all("))

    P = jax.sharding.PartitionSpec
    cases = [("column", "dip_tp", col, P(None, "model")),
             ("row", "dip_tp", row, P("model", None)),
             ("fsdp", "dip_fsdp", col, P("data", None))]
    results = []
    for label, backend, plan, gspmd_spec in cases:
        dw = api.DipWeight.from_natural(wn, plan=plan)
        explicit = jax.jit(lambda a, _dw=dw, _b=backend: api.matmul(a, _dw, backend=_b))
        gspmd, _ = gspmd_fn(gspmd_spec)
        with mesh:
            got = explicit(x)
            want = gspmd(x)
            np.testing.assert_allclose(  # parity rides with the timing
                np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3,
            )
            t_explicit = _time(explicit, x, iters=iters)
            t_gspmd = _time(gspmd, x, iters=iters)
            counts = count_collectives(explicit, x)
            n_hlo = hlo_collectives(gspmd, x)
        rec = {
            "case": label,
            "backend": backend,
            "explicit_us": round(t_explicit, 1),
            "gspmd_us": round(t_gspmd, 1),
            "psums": counts["psum"],
            "all_gathers": counts["all_gather"],
            "pallas_calls": counts["pallas_call"],
            "gspmd_hlo_collectives": n_hlo,
        }
        results.append(rec)
        if verbose:
            print(f"  {label:>7} ({backend}): explicit {t_explicit:9.1f} us "
                  f"[{counts['psum']} psum, {counts['all_gather']} all_gather, "
                  f"{counts['pallas_call']} launch] vs GSPMD-xla "
                  f"{t_gspmd:9.1f} us [{n_hlo} HLO collectives]")
    assert next(r_ for r_ in results if r_["case"] == "column")["psums"] == 0
    assert next(r_ for r_ in results if r_["case"] == "row")["psums"] == 1
    assert next(r_ for r_ in results if r_["case"] == "fsdp")["all_gathers"] == 1
    return {
        "mesh_axes": {str(a): int(s) for a, s in mesh.shape.items()},
        "shape": [m, k, n],
        "mode": "interpret" if api.default_interpret() else "compiled",
        "results": results,
    }


_REEXEC_SENTINEL = "REPRO_DIP_SHARDED_REEXEC"


def _reexec_with_devices(argv: Sequence[str], devices: int) -> int:
    """`--sharded` needs a multi-device topology; XLA locks the device count
    at first init, so spawn a fresh interpreter with forced host devices.
    One level deep only: if the child STILL comes up short (e.g. a platform
    override the forced-count flag cannot affect), it errors instead of
    re-execing again."""
    if os.environ.get(_REEXEC_SENTINEL):
        raise SystemExit(
            f"--sharded: re-exec with forced host devices still sees "
            f"{jax.device_count()} device(s) (< {devices}); check "
            "JAX_PLATFORMS/XLA_FLAGS overrides"
        )
    env = dict(os.environ)
    env[_REEXEC_SENTINEL] = "1"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"  # the forced count only exists on cpu
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    proc = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()), *argv],
        env=env, cwd=str(root),
    )
    return proc.returncode


# ---------------------------------------------------------------------------
# machine-readable output
def write_bench_json(path, csv_rows, epilogue_compare: Optional[dict],
                     sharded_compare: Optional[dict] = None,
                     prologue_compare: Optional[dict] = None,
                     fused_upstream: Optional[dict] = None) -> pathlib.Path:
    p = pathlib.Path(path)
    payload = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_by": "benchmarks/kernels_bench.py",
        "jax_backend": jax.default_backend(),
        "entries": [
            {"name": name, "us_per_call": round(float(us), 1), "derived": str(derived)}
            for name, us, derived in csv_rows
        ],
    }
    if epilogue_compare is not None:
        payload["epilogue_compare"] = epilogue_compare
    if sharded_compare is not None:
        payload["sharded_compare"] = sharded_compare
    if prologue_compare is not None:
        payload["prologue_compare"] = prologue_compare
    if fused_upstream is not None:
        payload["fused_upstream"] = fused_upstream
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return p


def validate_bench_json(path) -> dict:
    """Schema check for BENCH_kernels.json; returns the parsed payload.
    Raises ValueError on any violation (run by the CI bench-smoke job)."""
    payload = json.loads(pathlib.Path(path).read_text())

    def need(cond, msg):
        if not cond:
            raise ValueError(f"BENCH_kernels.json schema violation: {msg}")

    need(payload.get("schema_version") == BENCH_SCHEMA_VERSION,
         f"schema_version != {BENCH_SCHEMA_VERSION}")
    need(isinstance(payload.get("entries"), list), "entries must be a list")
    for e in payload["entries"]:
        need(isinstance(e.get("name"), str) and e["name"], "entry without name")
        need(isinstance(e.get("us_per_call"), (int, float)), f"{e.get('name')}: bad us_per_call")
    if "epilogue_compare" in payload:
        ec = payload["epilogue_compare"]
        need(isinstance(ec.get("backend"), str), "epilogue_compare.backend")
        need(isinstance(ec.get("shape"), list) and len(ec["shape"]) == 3,
             "epilogue_compare.shape must be [m, k, n]")
        need(isinstance(ec.get("results"), list) and ec["results"],
             "epilogue_compare.results empty")
        for rec in ec["results"]:
            for key in ("epilogue", "fused_us", "unfused_us", "speedup",
                        "fused_pallas_calls", "unfused_pallas_calls"):
                need(key in rec, f"epilogue_compare result missing {key!r}")
        swiglu = [r for r in ec["results"] if r["epilogue"] == "swiglu"]
        need(bool(swiglu), "epilogue_compare must include the swiglu headline")
        need(swiglu[0]["fused_pallas_calls"] <= 1,
             "fused swiglu recorded more than one kernel launch")
    if "prologue_compare" in payload:
        pc = payload["prologue_compare"]
        need(isinstance(pc.get("backend"), str), "prologue_compare.backend")
        need(isinstance(pc.get("shape"), list) and len(pc["shape"]) == 3,
             "prologue_compare.shape must be [m, k, n]")
        need(isinstance(pc.get("results"), list) and pc["results"],
             "prologue_compare.results empty")
        for rec in pc["results"]:
            for key in ("prologue", "epilogue", "fused_us", "unfused_us",
                        "speedup", "fused_pallas_calls", "unfused_pallas_calls"):
                need(key in rec, f"prologue_compare result missing {key!r}")
            # the structural contract IS the schema: norm + matmul (+ any
            # epilogue) must stay ONE launch on the fused backends
            need(rec["fused_pallas_calls"] <= 1,
                 "fused prologue recorded more than one kernel launch")
    if "fused_upstream" in payload:
        fu = payload["fused_upstream"]
        need(isinstance(fu.get("lm_head_ce"), dict), "fused_upstream.lm_head_ce")
        need(isinstance(fu.get("flash_attention"), dict),
             "fused_upstream.flash_attention")
        ce = fu["lm_head_ce"]
        for key in ("shape", "fused_us", "unfused_us", "pallas_calls",
                    "logits_free_grad"):
            need(key in ce, f"fused_upstream.lm_head_ce missing {key!r}")
        need(ce["pallas_calls"] == 1,
             "fused lm_head+CE must be exactly one kernel launch")
        need(ce["logits_free_grad"] is True,
             "fused lm_head+CE grad materialized logits-sized tensors")
        fa = fu["flash_attention"]
        for key in ("shape", "flash_us", "xla_us", "pallas_calls"):
            need(key in fa, f"fused_upstream.flash_attention missing {key!r}")
        need(fa["pallas_calls"] == 1,
             "flash attention dispatch must be exactly one kernel launch")
    if "sharded_compare" in payload:
        sc = payload["sharded_compare"]
        need(isinstance(sc.get("mesh_axes"), dict) and sc["mesh_axes"],
             "sharded_compare.mesh_axes")
        need(isinstance(sc.get("shape"), list) and len(sc["shape"]) == 3,
             "sharded_compare.shape must be [m, k, n]")
        need(isinstance(sc.get("results"), list) and sc["results"],
             "sharded_compare.results empty")
        by_case = {}
        for rec in sc["results"]:
            for key in ("case", "backend", "explicit_us", "gspmd_us",
                        "psums", "all_gathers", "pallas_calls",
                        "gspmd_hlo_collectives"):
                need(key in rec, f"sharded_compare result missing {key!r}")
            by_case[rec["case"]] = rec
        need({"column", "row", "fsdp"} <= set(by_case),
             "sharded_compare must cover column, row, and fsdp")
        # the collective-placement contract IS the schema: a drifting count
        # fails the bench, not just a test somewhere else
        need(by_case["column"]["psums"] == 0 and by_case["column"]["all_gathers"] == 0,
             "column-parallel recorded collectives (contract: zero)")
        need(by_case["row"]["psums"] == 1,
             "row-parallel must record exactly one psum")
        need(by_case["fsdp"]["all_gathers"] == 1,
             "fsdp must record exactly one all_gather per weight")
    if "reliability" in payload:
        # fused payloads may embed the ABFT verify-overhead + chaos-smoke
        # sections; the contracts live with the reliability bench
        from benchmarks.reliability_bench import validate_reliability_section

        validate_reliability_section(payload["reliability"], need)
    return payload


# ---------------------------------------------------------------------------
def run(csv_rows, *, out_json=DEFAULT_JSON):
    print("\n== kernel micro-benchmarks (CPU host; Pallas in interpret mode) ==")
    # the harness (benchmarks/run.py) shares one csv_rows across modules;
    # BENCH_kernels.json must record only THIS module's rows or the tracked
    # perf trajectory diffs spurious fig5/table4 entries across invocations
    first_own_row = len(csv_rows)
    r = np.random.default_rng(0)
    m, k, n = 512, 1024, 1024
    x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)

    plain = jax.jit(lambda a, b: a @ b)
    # the distributed default: de-shear as a gather, then the XLA dot
    desheared = jax.jit(lambda a, d: api.matmul(a, d, backend="xla"))

    t_plain = _time(plain, x, w)
    t_dip_xla = _time(desheared, x, dw)
    overhead = (t_dip_xla - t_plain) / t_plain * 100
    print(f"XLA plain matmul {m}x{k}x{n}:          {t_plain:9.1f} us")
    print(f"XLA matmul from DiP storage (+unshear): {t_dip_xla:9.1f} us "
          f"({overhead:+.1f}% — amortized de-shear cost)")

    # correctness parity accompanying the timings
    got = desheared(x, dw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain(x, w)), atol=2e-3)

    # tuning-table resolution for this shape (what the Pallas path would use)
    blocks = api.lookup_blocks("pallas_dip", m, k, n, x.dtype)
    print(f"tuning table -> pallas_dip blocks for {m}x{k}x{n} f32: {tuple(blocks)}")

    # interpret-mode pallas timing (documentation only — Python emulation)
    tiny_x = x[:64, :256]
    tiny_w = api.DipWeight.from_natural(w[:256, :256])
    t_pallas = _time(
        lambda a, d: api.matmul(a, d, backend="pallas_dip", interpret=True),
        tiny_x, tiny_w, iters=3,
    )
    print(f"Pallas pallas_dip 64x256x256 (interpret): {t_pallas:9.1f} us "
          f"(Python emulation — TPU path compiles via Mosaic)")

    # tuned-vs-heuristic delta on the same workload: what the autotuner's
    # measured entry buys over whatever the table currently resolves
    # (register=False keeps the benchmark from mutating the global table)
    from repro.api import autotune

    res = autotune.autotune_shape(
        "pallas_dip", 64, 256, 256, "float32",
        iters=2, warmup=1, interpret=True, max_candidates=4, register=False,
    )
    t_inc, t_best = res.incumbent_time_us, res.best.time_us
    speedup = res.speedup_vs_incumbent() or 1.0
    print(f"autotune 64x256x256 f32: incumbent {tuple(res.incumbent)} "
          f"{t_inc:9.1f} us -> best {tuple(res.best.blocks)} {t_best:9.1f} us "
          f"({speedup:.2f}x; {len(res.measurements)} candidates)")

    # quantized-vs-float deltas (the dip_int8w / dip_fp8 backends).  On this
    # CPU host the meaningful comparison is the XLA-path analog: a quantized
    # weight served through the natural-layout backend (dequant + dot) vs the
    # plain bf16 dot — storage shrinks 4x (int8) / 2x (fp8) while the dequant
    # epilogue rides the same amortization as the de-shear.  The Pallas
    # quantized kernel itself is timed at interpret scale like the float one.
    from repro.api import quant

    xb = x.astype(jnp.bfloat16)
    wb = w.astype(jnp.bfloat16)
    plain_bf16 = jax.jit(lambda a, b: a @ b)
    t_bf16 = _time(plain_bf16, xb, wb)
    for scheme in ("int8", "fp8_e4m3"):
        qw = quant.quantize(w, scheme)
        deq = jax.jit(lambda a, d: api.matmul(a, d, backend="xla"))
        t_q = _time(deq, xb, qw)
        delta = (t_q - t_bf16) / t_bf16 * 100
        bytes_ratio = jnp.dtype(qw.dtype).itemsize / 2.0  # vs bf16 storage
        print(f"XLA matmul from {scheme} storage (+dequant):  {t_q:9.1f} us "
              f"({delta:+.1f}% vs bf16 dot; {bytes_ratio:.1f}x weight bytes)")
        err = np.abs(
            np.asarray(deq(xb, qw), np.float32)
            - np.asarray(plain_bf16(xb, wb), np.float32)
        ).max() / np.abs(np.asarray(plain_bf16(xb, wb), np.float32)).max()
        print(f"  max rel deviation vs bf16: {err:.4f} "
              f"(documented bound: docs/quantization.md)")
        assert err < 0.05, f"{scheme} deviation {err} beyond documented bound"
        csv_rows.append((f"kern_xla_{scheme}_storage", t_q,
                         f"delta_vs_bf16_{delta:+.1f}%"))

    t_q_pallas = _time(
        lambda a, d: api.matmul(a, d, backend="dip_int8w", interpret=True),
        tiny_x, quant.quantize(w[:256, :256], "int8"), iters=3,
    )
    print(f"Pallas dip_int8w 64x256x256 (interpret):  {t_q_pallas:9.1f} us "
          f"(Python emulation; vs float pallas_dip {t_pallas:9.1f} us)")

    # fused-vs-unfused epilogue deltas (the flush-stage fusion subsystem)
    print("fused-vs-unfused epilogues (pallas_dip 64x256x256, interpret):")
    ec = compare_epilogues(backend="pallas_dip", m=64, k=256, n=256, iters=2)
    for rec in ec["results"]:
        csv_rows.append((f"kern_epilogue_{rec['epilogue']}_fused",
                         rec["fused_us"],
                         f"vs_unfused_{rec['speedup']:.2f}x_"
                         f"launches_{rec['fused_pallas_calls']}v{rec['unfused_pallas_calls']}"))

    # fused-vs-unfused prologue deltas (the load-stage fusion subsystem)
    print("fused-vs-unfused rmsnorm prologue (pallas_dip 64x256x256, interpret):")
    pc = compare_prologues(backend="pallas_dip", m=64, k=256, n=256, iters=2)
    for rec in pc["results"]:
        label = (rec["prologue"] if rec["epilogue"] == "none"
                 else f"{rec['prologue']}_{rec['epilogue']}")
        csv_rows.append((f"kern_prologue_{label}_fused", rec["fused_us"],
                         f"vs_unfused_{rec['speedup']:.2f}x_"
                         f"launches_{rec['fused_pallas_calls']}v{rec['unfused_pallas_calls']}"))

    # fused lm_head+CE and flash-attention structural smoke
    print("fused upstream smoke (lm_head+CE, flash attention; interpret):")
    fu = fused_upstream_smoke(iters=2)
    csv_rows.append(("kern_fused_ce", fu["lm_head_ce"]["fused_us"],
                     f"vs_unfused_{fu['lm_head_ce']['unfused_us']}us_logits_free"))
    csv_rows.append(("kern_flash_attention", fu["flash_attention"]["flash_us"],
                     f"vs_xla_{fu['flash_attention']['xla_us']}us_1launch"))

    csv_rows.append(("kern_xla_plain_matmul", t_plain, f"{2*m*k*n/ (t_plain*1e-6) /1e9:.1f}GFLOP/s"))
    csv_rows.append(("kern_xla_dip_storage", t_dip_xla, f"overhead_{overhead:+.1f}%"))
    csv_rows.append(("kern_pallas_interpret", t_pallas, "interpret_mode"))
    csv_rows.append(("kern_pallas_int8w_interpret", t_q_pallas, "interpret_mode"))
    csv_rows.append(("kern_autotune_best", t_best, f"tuned_vs_incumbent_{speedup:.2f}x"))

    path = write_bench_json(out_json, csv_rows[first_own_row:], ec,
                            prologue_compare=pc, fused_upstream=fu)
    validate_bench_json(path)
    print(f"machine-readable record: {path}")


# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/kernels_bench.py",
        description="DiP kernel micro-benchmarks; writes BENCH_kernels.json.",
    )
    ap.add_argument("--compare-epilogues", action="store_true",
                    help="run ONLY the fused-vs-unfused epilogue comparison")
    ap.add_argument("--sharded", action="store_true",
                    help="run ONLY the explicit-sharding comparison (dip_tp/"
                         "dip_fsdp vs GSPMD-xla); re-execs itself with "
                         "--xla_force_host_platform_device_count when the "
                         "topology is single-device")
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual device count for --sharded (default 8)")
    ap.add_argument("--upstream", action="store_true",
                    help="run ONLY the upstream-fusion smoke: rmsnorm-"
                         "prologue compare + fused lm_head+CE + flash "
                         "attention (CI bench-smoke)")
    ap.add_argument("--backend", default="pallas_dip",
                    help="backend for --compare-epilogues (default pallas_dip)")
    ap.add_argument("--tiny", action="store_true",
                    help="tiny interpret-friendly shape (CI smoke)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=DEFAULT_JSON,
                    help=f"output JSON path (default {DEFAULT_JSON})")
    args = ap.parse_args(argv)

    csv_rows: List = []
    if args.sharded:
        if jax.device_count() < args.devices:
            return _reexec_with_devices(
                ["--sharded", "--devices", str(args.devices),
                 "--iters", str(args.iters), "--out", args.out]
                + (["--tiny"] if args.tiny else []),
                args.devices,
            )
        m, k, n = (8, 256, 256) if args.tiny else (64, 512, 512)
        print(f"== explicit sharding vs GSPMD-xla "
              f"({jax.device_count()} devices, {m}x{k}x{n}) ==")
        sc = compare_sharded(m=m, k=k, n=n, iters=args.iters)
        for rec in sc["results"]:
            csv_rows.append((
                f"kern_sharded_{rec['case']}_explicit", rec["explicit_us"],
                f"vs_gspmd_{rec['gspmd_us']}us_psum{rec['psums']}"
                f"_ag{rec['all_gathers']}_launch{rec['pallas_calls']}",
            ))
        path = write_bench_json(args.out, csv_rows, None, sc)
        validate_bench_json(path)
        print(f"machine-readable record: {path}")
        return 0
    if args.upstream:
        m, k, n = (32, 64, 64) if args.tiny else (64, 256, 256)
        print(f"== fused-vs-unfused rmsnorm prologue ({args.backend} {m}x{k}x{n}) ==")
        pc = compare_prologues(backend=args.backend, m=m, k=k, n=n,
                               iters=args.iters)
        print("== fused upstream smoke (lm_head+CE, flash attention) ==")
        fu = fused_upstream_smoke(iters=args.iters)
        for rec in pc["results"]:
            label = (rec["prologue"] if rec["epilogue"] == "none"
                     else f"{rec['prologue']}_{rec['epilogue']}")
            csv_rows.append((f"kern_prologue_{label}_fused", rec["fused_us"],
                             f"vs_unfused_{rec['speedup']:.2f}x"))
        csv_rows.append(("kern_fused_ce", fu["lm_head_ce"]["fused_us"],
                         "logits_free"))
        csv_rows.append(("kern_flash_attention", fu["flash_attention"]["flash_us"],
                         "1launch"))
        path = write_bench_json(args.out, csv_rows, None,
                                prologue_compare=pc, fused_upstream=fu)
        validate_bench_json(path)
        print(f"machine-readable record: {path}")
        return 0
    if args.compare_epilogues:
        m, k, n = (32, 64, 64) if args.tiny else (64, 256, 256)
        print(f"== fused-vs-unfused epilogues ({args.backend} {m}x{k}x{n}) ==")
        ec = compare_epilogues(
            backend=args.backend, m=m, k=k, n=n, iters=args.iters,
        )
        swiglu = next(r for r in ec["results"] if r["epilogue"] == "swiglu")
        print(f"fused SwiGLU: {swiglu['fused_pallas_calls']} kernel launch "
              f"(vs three ops unfused: {swiglu['unfused_pallas_calls']} matmul "
              f"launches + elementwise glue)")
        for rec in ec["results"]:
            csv_rows.append((f"kern_epilogue_{rec['epilogue']}_fused",
                             rec["fused_us"], f"vs_unfused_{rec['speedup']:.2f}x"))
        path = write_bench_json(args.out, csv_rows, ec)
        validate_bench_json(path)
        print(f"machine-readable record: {path}")
        return 0

    run(csv_rows, out_json=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
