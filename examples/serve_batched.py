"""End-to-end serving driver (the paper is an inference accelerator, so the
end-to-end example is serving): batched requests through the slot-pool
server, with the DiP permutated weight format + Pallas kernel as the live
matmul path.

    PYTHONPATH=src python examples/serve_batched.py [--arch yi-9b] [--dip]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf_model
from repro.runtime import Server, ServerConfig
from repro.runtime.server import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--dip", action="store_true",
                    help="DiP storage + Pallas fused kernel for every matmul")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(compute_dtype="float32")
    if args.dip:
        cfg = dataclasses.replace(cfg, matmul_backend="pallas_dip")
    print(f"serving reduced {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"backend={cfg.matmul_backend}, dip_storage={cfg.uses_dip_storage})")

    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    server = Server(
        cfg,
        ServerConfig(batch_slots=args.slots, max_seq=128, max_new_tokens=args.max_new),
        params,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, size=int(rng.integers(4, 12))))
        for i in range(args.requests)
    ]
    results = server.serve(reqs)
    for rid in sorted(results):
        print(f"  request {rid}: {len(results[rid]):>3} new tokens  {results[rid][:10]}")
    s = server.last_stats
    print(f"done: {s['decode_steps']} decode steps, {s['tok_per_s']:.1f} tok/s "
          f"(CPU host; interpret-mode kernels when --dip)")


if __name__ == "__main__":
    main()
