"""Quickstart: the DiP paper in one page.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's core contribution end to end:
  1. the weight permutation (Fig. 3),
  2. the 3x3 cycle-by-cycle example (Fig. 4) on the register-level simulator,
  3. the analytical WS-vs-DiP comparison (Fig. 5 / eqs. 1-7),
  4. the TPU-adapted Pallas kernel computing a matmul from permutated storage.
"""

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core import analytical, permute, simulator

# 1. the permutation ---------------------------------------------------------
w = np.arange(9).reshape(3, 3)
p = permute.permute_weights_np(w)
print("weight matrix W:\n", w)
print("DiP-permutated P (column i rotated up by i):\n", p)
assert np.array_equal(permute.unpermute_weights_np(p), w)

# 2. the Fig. 4 walk-through on the cycle-accurate simulator -----------------
x = np.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]])
res = simulator.simulate_dip(x, w, stages=2)
print("\nDiP 3x3, 2-stage MAC (paper Fig. 4):")
print("  output == X @ W:", np.array_equal(res.output, x @ w))
print(f"  first output row at cycle {res.first_output_cycle} (paper: 3)")
print(f"  total latency {res.latency} cycles = 2N+S-2 (paper: 6)")
print(f"  TFPU {res.tfpu} cycles = N (paper: 3); WS needs 2N-1 = 5")

# 3. analytical scaling (Fig. 5) ---------------------------------------------
print("\nWS vs DiP at 64x64 (S=2):")
c = analytical.compare(64, s=2)
print(f"  latency   : WS {c.ws_latency} vs DiP {c.dip_latency}  "
      f"({100*c.latency_saving:.1f}% saved)")
print(f"  throughput: {c.throughput_improvement:.3f}x  (paper: 1.49x)")
print(f"  registers : {100*c.register_saving:.1f}% saved  (paper: ~20%)")

# 4. the TPU adaptation: matmul straight from permutated storage -------------
rng = np.random.default_rng(0)
xb = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32))
wb = jnp.asarray(rng.normal(size=(256, 192)).astype(np.float32))
dw = api.DipWeight.from_natural(wb)             # offline permutation (Fig. 3)
print(f"\nfirst-class permutated storage: {dw}")
out = api.matmul(xb, dw, backend="pallas_dip")  # fused de-shear + MXU matmul
print("Pallas pallas_dip backend from permutated storage: max |err| =",
      float(jnp.max(jnp.abs(out - xb @ wb))))
out_sys = api.matmul(xb, dw, backend="pallas_systolic")
print("wavefront-emulation backend (diagonal input movement): max |err| =",
      float(jnp.max(jnp.abs(out_sys - xb @ wb))))
print("registered matmul backends:", ", ".join(api.list_backends()))
print("\nOK — see benchmarks/ for the full Fig.5/6 + Table I/II/IV reproduction.")
