"""Train a language model end to end: data pipeline -> sharded train step ->
async checkpoints -> resume.  Defaults to a CPU-sized model; ``--params-100m``
selects a ~100M-parameter mamba2-family config (the assignment's train-driver
scale — practical on a real accelerator host, slow but functional on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --params-100m --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.optim import AdamW, cosine_schedule
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config("mamba2-370m")
    if args.params_100m:
        # ~100M: 24 layers at d_model=640
        cfg = dataclasses.replace(cfg, n_layers=24, d_model=640, ssm_chunk=64)
    else:
        cfg = cfg.reduced(d_model=256, n_layers=4, ssm_state=32, ssm_headdim=64,
                          vocab_size=50280, compute_dtype="float32")
    print(f"training {cfg.name} variant: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    gt = None
    if args.compress_grads:
        from repro.distributed import compression
        gt = compression.compression_transform()

    trainer = Trainer(
        cfg,
        TrainerConfig(
            steps=args.steps, ckpt_every=max(10, args.steps // 4),
            ckpt_dir=args.ckpt_dir, log_every=10, async_ckpt=True,
        ),
        optimizer=AdamW(lr=cosine_schedule(args.lr, 20, args.steps), grad_transform=gt),
        seq_len=args.seq, global_batch=args.batch,
    )
    out = trainer.run()
    m = out["metrics"]
    print(f"\nfinal loss {m[-1]['loss']:.4f} (first {m[0]['loss']:.4f}) in "
          f"{out['wall_s']:.1f}s — checkpoints in {args.ckpt_dir} "
          f"(re-run the same command to watch auto-resume)")


if __name__ == "__main__":
    main()
