"""Property tests for the DiP weight permutation (paper Fig. 3)."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_shim import given, settings, st

from repro.core import dataflow, permute

dims = st.integers(min_value=1, max_value=24)


@settings(max_examples=40, deadline=None)
@given(rows=dims, cols=dims, seed=st.integers(0, 2**31 - 1))
def test_permute_matches_paper_pseudocode(rows, cols, seed):
    w = np.random.default_rng(seed).integers(-100, 100, size=(rows, cols))
    got = np.asarray(permute.permute_weights(jnp.asarray(w)))
    want = permute.permute_weights_np(w)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(rows=dims, cols=dims, seed=st.integers(0, 2**31 - 1))
def test_permute_roundtrip(rows, cols, seed):
    w = np.random.default_rng(seed).normal(size=(rows, cols)).astype(np.float32)
    p = permute.permute_weights(jnp.asarray(w))
    back = permute.unpermute_weights(p)
    np.testing.assert_allclose(np.asarray(back), w)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 150),
    cols=st.integers(1, 150),
    tile=st.sampled_from([4, 8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_permute_roundtrip_any_shape(rows, cols, tile, seed):
    w = np.random.default_rng(seed).normal(size=(rows, cols)).astype(np.float32)
    p = permute.permute_tiled(jnp.asarray(w), tile)
    assert p.shape[-2] % tile == 0 and p.shape[-1] % tile == 0  # padded storage
    back = permute.unpermute_tiled(p, tile)[:rows, :cols]
    np.testing.assert_allclose(np.asarray(back), w)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 16), m=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_rolled_mac_identity(n, m, seed):
    """out[m,i] = sum_r x[m,(i+r)%N] * P[r,i]  ==  x @ W  (paper Sec III-B)."""
    r = np.random.default_rng(seed)
    x = r.integers(-20, 20, size=(m, n))
    w = r.integers(-20, 20, size=(n, n))
    p = permute.permute_weights_np(w)
    got = dataflow.dip_matmul_rolled_np(x, p)
    np.testing.assert_array_equal(got, x @ w)
    # jax version agrees
    got_jax = dataflow.dip_matmul_rolled(jnp.asarray(x), jnp.asarray(p))
    np.testing.assert_array_equal(np.asarray(got_jax), x @ w)


def test_permutation_is_column_rotation():
    """Each column i is rotated up by i (the Fig. 2c description)."""
    n = 8
    w = np.arange(n * n).reshape(n, n)
    p = permute.permute_weights_np(w)
    for i in range(n):
        np.testing.assert_array_equal(p[:, i], np.roll(w[:, i], -i))


def test_batched_permute():
    w = np.random.default_rng(0).normal(size=(3, 2, 16, 16)).astype(np.float32)
    p = permute.permute_weights(jnp.asarray(w))
    for a in range(3):
        for b in range(2):
            np.testing.assert_allclose(
                np.asarray(p[a, b]), permute.permute_weights_np(w[a, b])
            )
