"""The fleet driver's schema IS the acceptance contract (benchmarks/fleet.py).

Property-tested (hypothesis shim): synthetic fleet documents round-trip
through ``validate_fleet_json`` and JSON serialization; the baseline differ
rejects launch-count regressions, collective-count regressions, vanished
cells, and newly-failing stages no matter where in the matrix they occur.
Plus deterministic unit coverage of the cell-config mapping (the
matrix axis -> effective backend/quantization resolution), the tiny-matrix
coverage guarantees, and the peak-live-bytes estimator.
"""

import copy
import json

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

fleet = pytest.importorskip("benchmarks.fleet")

ARCHS = ["llama3_8b", "deepseek_v2_lite_16b", "zamba2_2_7b"]


# ---------------------------------------------------------- doc synthesis ----
def _stage(pallas=4, psum=0, ag=0, wall=123.4, status="ok", ppermute=0,
           a2a=0, rs=0):
    if status != "ok":
        return {"status": status, "reason": "synthetic"}
    return {
        "status": "ok", "wall_us": wall, "pallas_calls": pallas,
        "collectives": {"psum": psum, "all_gather": ag,
                        "all_to_all": a2a, "ppermute": ppermute,
                        "reduce_scatter": rs},
        "peak_live_bytes": 1 << 20,
    }


def _cell(arch="llama3_8b", backend="pallas_dip", sharding="gspmd",
          pallas=4, psum=0, ag=0):
    effective = backend
    if sharding not in ("gspmd", "pp") and backend != "xla":
        effective = fleet.SHARDED_EFFECTIVE[sharding]
    quant = fleet.QUANT_FOR_BACKEND[backend]
    probe = None
    if effective in ("dip_tp", "dip_ep"):
        probe = {"pallas_calls": 1, "collectives": dict.fromkeys(
            fleet.COLLECTIVES, 0)}
    elif effective == "dip_fsdp":
        probe = {"pallas_calls": 1, "collectives": dict(
            dict.fromkeys(fleet.COLLECTIVES, 0), all_gather=1)}
    elif effective == "dip_sp":
        probe = {"pallas_calls": 2, "collectives": dict(
            dict.fromkeys(fleet.COLLECTIVES, 0), ppermute=1)}
    vprobe = None
    if sharding == "gspmd":
        vprobe = {"pallas_calls_unverified": pallas,
                  "pallas_calls_verified": pallas,
                  "extra_pallas_calls": 0}
    # keep the synthetic cell legal under the per-strategy contracts:
    # dip_sp never all_gathers, dip_ep carries the 2-a2a pair, pp records
    # serving stages skipped and ppermutes in train
    a2a = 2 if effective == "dip_ep" else 0
    if effective == "dip_sp":
        ag = 0
    stages = {
        "train": _stage(pallas, psum, ag, a2a=a2a,
                        ppermute=1 if sharding == "pp" else 0,
                        status="skipped" if quant != "none" else "ok"),
        "prefill": _stage(pallas, psum, ag, a2a=a2a),
        # dip_tp decode must not all_gather — keep the synthetic legal
        "decode": _stage(pallas, psum,
                         0 if effective == "dip_tp" else ag, a2a=a2a),
    }
    if sharding == "pp":
        stages["prefill"] = _stage(status="skipped")
        stages["decode"] = _stage(status="skipped")
    return {
        "arch": arch, "backend": backend, "sharding": sharding,
        "effective_backend": effective, "quantization": quant,
        "column_probe": probe, "verify_probe": vprobe,
        "stages": stages,
    }


def _doc(cells, matrix="custom"):
    return {
        "schema_version": fleet.FLEET_SCHEMA_VERSION,
        "generated_by": "benchmarks/fleet.py", "jax_backend": "cpu",
        "matrix": matrix, "dims": dict(fleet.DIMS), "devices": 1,
        "cells": cells,
    }


# ------------------------------------------------------- validator props ----
@settings(max_examples=25)
@given(pallas=st.integers(min_value=0, max_value=40),
       psum=st.integers(min_value=0, max_value=8),
       arch=st.sampled_from(ARCHS),
       backend=st.sampled_from(list(fleet.BACKENDS)),
       sharding=st.sampled_from(list(fleet.SHARDINGS)))
def test_validator_roundtrips_valid_documents(pallas, psum, arch, backend,
                                              sharding):
    doc = _doc([_cell(arch, backend, sharding, pallas=pallas, psum=psum)])
    fleet.validate_fleet_json(doc)                       # direct
    fleet.validate_fleet_json(json.loads(json.dumps(doc)))   # JSON round-trip
    fleet.diff_fleet_json(doc, copy.deepcopy(doc))       # self-diff is clean


@settings(max_examples=25)
@given(base=st.integers(min_value=0, max_value=30),
       bump=st.integers(min_value=1, max_value=5),
       stage=st.sampled_from(["prefill", "decode"]))
def test_differ_rejects_launch_count_regression(base, bump, stage):
    doc = _doc([_cell(pallas=base)])
    worse = copy.deepcopy(doc)
    worse["cells"][0]["stages"][stage]["pallas_calls"] = base + bump
    with pytest.raises(ValueError, match="pallas_calls regressed"):
        fleet.diff_fleet_json(worse, doc)
    fleet.diff_fleet_json(doc, worse)    # fewer launches than baseline: fine


@settings(max_examples=25)
@given(bump=st.integers(min_value=1, max_value=5),
       kind=st.sampled_from(list(fleet.COLLECTIVES)))
def test_differ_rejects_collective_count_regression(bump, kind):
    doc = _doc([_cell(backend="xla", sharding="tp", psum=1)])
    worse = copy.deepcopy(doc)
    coll = worse["cells"][0]["stages"]["decode"]["collectives"]
    coll[kind] = coll[kind] + bump
    with pytest.raises(ValueError, match=f"{kind} count regressed"):
        fleet.diff_fleet_json(worse, doc)


@settings(max_examples=10)
@given(drop=st.integers(min_value=0, max_value=2))
def test_differ_rejects_missing_cells_and_new_failures(drop):
    cells = [_cell(a) for a in ARCHS]
    doc = _doc(cells)
    shrunk = _doc([c for i, c in enumerate(cells) if i != drop])
    with pytest.raises(ValueError, match="missing now"):
        fleet.diff_fleet_json(shrunk, doc)
    broken = copy.deepcopy(doc)
    broken["cells"][drop]["stages"]["decode"] = _stage(status="failed")
    with pytest.raises(ValueError, match="was ok in baseline"):
        fleet.diff_fleet_json(broken, doc)


def test_validator_rejects_structural_violations():
    with pytest.raises(ValueError, match="schema_version"):
        fleet.validate_fleet_json({"schema_version": 999})
    with pytest.raises(ValueError, match="non-empty"):
        fleet.validate_fleet_json(
            {"schema_version": fleet.FLEET_SCHEMA_VERSION, "cells": []})
    doc = _doc([_cell()])
    del doc["cells"][0]["stages"]["decode"]
    with pytest.raises(ValueError, match="missing record"):
        fleet.validate_fleet_json(doc)
    dup = _doc([_cell(), _cell()])
    with pytest.raises(ValueError, match="duplicate cell"):
        fleet.validate_fleet_json(dup)
    bad = _doc([_cell()])
    bad["cells"][0]["stages"]["prefill"]["wall_us"] = 0
    with pytest.raises(ValueError, match="wall_us"):
        fleet.validate_fleet_json(bad)
    # the ABFT verify contract is schema, not just a test: a gspmd cell
    # must carry a probe, and the audit must add ZERO pallas launches
    noprobe = _doc([_cell()])
    noprobe["cells"][0]["verify_probe"] = None
    with pytest.raises(ValueError, match="needs a verify_probe"):
        fleet.validate_fleet_json(noprobe)
    leaky = _doc([_cell()])
    leaky["cells"][0]["verify_probe"]["extra_pallas_calls"] = 1
    with pytest.raises(ValueError, match="zero kernels"):
        fleet.validate_fleet_json(leaky)


def test_validator_enforces_placement_contracts():
    """dip_tp columns: ZERO collectives; dip_fsdp: one all_gather, no psum;
    dip_tp decode never all_gathers.  These are the PR-5 placement wins as
    schema rules."""
    tp = _doc([_cell(sharding="tp")])
    tp["cells"][0]["column_probe"]["collectives"]["psum"] = 1
    with pytest.raises(ValueError, match="zero"):
        fleet.validate_fleet_json(tp)

    fsdp = _doc([_cell(sharding="fsdp")])
    fsdp["cells"][0]["column_probe"]["collectives"]["all_gather"] = 2
    with pytest.raises(ValueError, match="exactly"):
        fleet.validate_fleet_json(fsdp)

    noprobe = _doc([_cell(sharding="tp")])
    noprobe["cells"][0]["column_probe"] = None
    with pytest.raises(ValueError, match="column_probe"):
        fleet.validate_fleet_json(noprobe)

    leak = _doc([_cell(sharding="tp")])
    leak["cells"][0]["stages"]["decode"]["collectives"]["all_gather"] = 1
    with pytest.raises(ValueError, match="must not all_gather"):
        fleet.validate_fleet_json(leak)


def test_validator_enforces_overlap_contracts():
    """The PR-10 communication-hiding wins as schema rules: dip_sp gathers
    inside the kernel (ppermute-only probe, no all_gather anywhere), dip_ep
    carries exactly the dispatch/combine all_to_all pair, pp trains with the
    boundary ppermute and records serving stages skipped."""
    sp = _doc([_cell(sharding="sp")])
    sp["cells"][0]["column_probe"]["collectives"]["all_gather"] = 1
    with pytest.raises(ValueError, match="inside"):
        fleet.validate_fleet_json(sp)
    sp = _doc([_cell(sharding="sp")])
    sp["cells"][0]["column_probe"]["collectives"]["ppermute"] = 0
    with pytest.raises(ValueError, match="ppermute >= 1"):
        fleet.validate_fleet_json(sp)
    sp = _doc([_cell(sharding="sp")])
    sp["cells"][0]["stages"]["prefill"]["collectives"]["all_gather"] = 1
    with pytest.raises(ValueError, match="never all_gather"):
        fleet.validate_fleet_json(sp)

    ep = _doc([_cell(sharding="ep")])
    ep["cells"][0]["stages"]["prefill"]["collectives"]["all_to_all"] = 3
    with pytest.raises(ValueError, match="exactly 2 all_to_alls"):
        fleet.validate_fleet_json(ep)
    ep = _doc([_cell(sharding="ep")])
    ep["cells"][0]["column_probe"]["collectives"]["psum"] = 1
    with pytest.raises(ValueError, match="zero"):
        fleet.validate_fleet_json(ep)
    ep = _doc([_cell(sharding="ep")])
    ep["cells"][0]["stages"]["train"]["collectives"]["all_to_all"] = 0
    with pytest.raises(ValueError, match="dispatch/combine"):
        fleet.validate_fleet_json(ep)

    pp = _doc([_cell(sharding="pp")])
    pp["cells"][0]["stages"]["decode"] = _stage()
    with pytest.raises(ValueError, match="skipped"):
        fleet.validate_fleet_json(pp)
    pp = _doc([_cell(sharding="pp")])
    pp["cells"][0]["stages"]["train"]["collectives"]["ppermute"] = 0
    with pytest.raises(ValueError, match="boundary ppermute"):
        fleet.validate_fleet_json(pp)


def test_validator_tiny_matrix_requires_full_arch_coverage():
    """In a tiny/full document every arch must pass all three stages in at
    least one cell — the acceptance headline of the fleet baseline."""
    broken = _doc([_cell("llama3_8b"), _cell("zamba2_2_7b")], matrix="tiny")
    broken["cells"][1]["stages"]["train"] = _stage(status="failed")
    with pytest.raises(ValueError, match="zamba2_2_7b.*no cell passing"):
        fleet.validate_fleet_json(broken)
    # same document as a custom (filtered) matrix is fine
    broken["matrix"] = "custom"
    fleet.validate_fleet_json(broken)


# ------------------------------------------------------------ cell config ----
def test_cell_config_effective_backend_and_quant_mapping():
    cfg, eff, quant, mesh = fleet.cell_config("llama3_8b", "pallas_dip", "gspmd")
    assert (eff, quant, mesh) == ("pallas_dip", "none", None)
    assert cfg.matmul_backend == "pallas_dip"

    cfg, eff, quant, mesh = fleet.cell_config("llama3_8b", "pallas_dip", "tp")
    assert eff == "dip_tp" and cfg.matmul_backend == "dip_tp"
    assert mesh == {"data": 1, "model": 2}
    assert cfg.compute_dtype == "float32"     # forced-host-device precision

    cfg, eff, quant, mesh = fleet.cell_config("yi_9b", "dip_int8w", "fsdp")
    assert eff == "dip_fsdp" and quant == "int8"
    assert cfg.quantization == "int8" and mesh == {"data": 2, "model": 1}

    cfg, eff, quant, mesh = fleet.cell_config("llama3_8b", "xla", "tp")
    assert eff == "xla"                       # GSPMD places the collectives
    assert cfg.matmul_backend == "xla" and mesh == {"data": 1, "model": 2}

    cfg, eff, quant, _ = fleet.cell_config("musicgen_medium", "dip_fp8", "gspmd")
    assert quant == "fp8_e4m3" and cfg.quantization == "fp8_e4m3"

    cfg, eff, quant, mesh = fleet.cell_config("llama3_8b", "pallas_dip", "sp")
    assert eff == "dip_sp" and cfg.matmul_backend == "dip_sp"
    assert cfg.sharding == "sp" and mesh == {"data": 1, "model": 2}

    cfg, eff, quant, mesh = fleet.cell_config(
        "qwen3_moe_235b_a22b", "pallas_dip", "ep")
    assert eff == "dip_ep" and cfg.sharding == "ep"
    assert mesh == {"data": 1, "model": 2}

    cfg, eff, quant, mesh = fleet.cell_config("llama3_8b", "pallas_dip", "pp")
    assert eff == "pallas_dip"        # stages run the config's own backend
    assert cfg.sharding == "pp" and mesh == {"stage": 2, "data": 1, "model": 1}


def test_tiny_matrix_covers_every_arch_with_full_stage_cells():
    from repro.configs import ALL_ARCHS

    cells = fleet.tiny_cells(ALL_ARCHS)
    assert len(cells) == len(set(cells)), "duplicate cells in tiny matrix"
    for arch in ALL_ARCHS:
        # at least one float replicated cell -> all three stages can pass
        assert any(c == (arch, "xla", "gspmd") for c in cells)
        assert any(c == (arch, "pallas_dip", "gspmd") for c in cells)
        assert any(c == (arch, "dip_int8w", "gspmd") for c in cells)
    assert ("llama3_8b", "pallas_dip", "tp") in cells
    assert ("llama3_8b", "pallas_dip", "fsdp") in cells
    assert ("llama3_8b", "pallas_dip", "sp") in cells
    assert ("zamba2_2_7b", "pallas_dip", "sp") in cells
    assert ("qwen3_moe_235b_a22b", "pallas_dip", "ep") in cells
    assert ("deepseek_v2_lite_16b", "pallas_dip", "ep") in cells
    assert ("llama3_8b", "pallas_dip", "pp") in cells
    # arch filters subset consistently
    sub = fleet.tiny_cells(["llama3_8b"])
    assert set(sub) <= set(cells) and all(a == "llama3_8b" for a, _, _ in sub)


def test_full_matrix_is_cartesian():
    cells = fleet.full_cells(["a", "b"])
    assert len(cells) == 2 * len(fleet.BACKENDS) * len(fleet.SHARDINGS)
    assert len(set(cells)) == len(cells)


# ----------------------------------------------------- peak-bytes + CSV ----
def test_estimate_peak_live_bytes_tracks_dominant_intermediate():
    import jax.numpy as jnp

    def small(x):
        return (x @ x).sum()

    def big(x):
        y = jnp.concatenate([x] * 8, axis=0)     # 8x intermediate
        return (y @ x).sum()

    x = np.zeros((32, 32), np.float32)
    lo = fleet.estimate_peak_live_bytes(small, x)
    hi = fleet.estimate_peak_live_bytes(big, x)
    assert lo >= x.nbytes                        # inputs are resident
    assert hi >= lo + 7 * x.nbytes               # the blow-up is visible


def test_csv_rows_follow_harness_contract():
    doc = _doc([_cell("llama3_8b"), _cell("zamba2_2_7b", backend="dip_int8w")])
    rows = fleet.csv_rows_from(doc)
    assert len(rows) == 2 * len(fleet.STAGES)
    names = [r[0] for r in rows]
    assert "fleet_llama3_8b_pallas_dip_gspmd_decode" in names
    for name, us, derived in rows:
        assert isinstance(us, float)
        if derived not in ("failed", "skipped"):
            assert "launches=" in derived and "peak_mb=" in derived
