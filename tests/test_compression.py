"""Gradient compression: quantization error feedback + compressed training."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    compression_transform,
    dequantize_int8,
    quantize_int8,
)
from repro.optim import AdamW


def test_quantize_roundtrip_error_bounded():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(128,)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_carries_residual():
    gt = compression_transform()
    params = {"w": jnp.zeros((4,))}
    state = gt.init(params)
    g = {"w": jnp.asarray([1e-4, 2e-4, -1e-4, 1.0])}  # tiny grads vanish in int8
    out1, state = gt.fn(g, state)
    # residual accumulates and eventually releases the small components
    total = jax.tree_util.tree_map(jnp.zeros_like, g)
    for _ in range(2000):
        out, state = gt.fn(g, state)
        total = jax.tree_util.tree_map(jnp.add, total, out)
    mean = np.asarray(total["w"]) / 2000
    np.testing.assert_allclose(mean, np.asarray(g["w"]), rtol=0.05, atol=2e-5)


def test_compressed_training_still_converges():
    opt = AdamW(lr=0.05, weight_decay=0.0, clip_norm=1e9,
                grad_transform=compression_transform())
    params = {"w": jnp.array([4.0, -3.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 2.0])

    @jax.jit
    def step(params, state):
        grads = {"w": 2 * (params["w"] - target)}
        updates, state = opt.update(grads, state, params)
        return {"w": params["w"] + updates["w"]}, state

    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)
