"""End-to-end system behaviour: train -> checkpoint -> serve, with the
paper's technique (DiP permutated weight storage) on."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as tf_model
from repro.optim import AdamW, cosine_schedule
from repro.runtime import Server, ServerConfig, Trainer, TrainerConfig
from repro.runtime.server import Request


def _cfg(**kw):
    base = dict(name="sys", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
                remat="none", compute_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_train_then_serve_end_to_end(tmp_path):
    cfg = _cfg()
    trainer = Trainer(
        cfg,
        TrainerConfig(steps=20, ckpt_every=10, ckpt_dir=str(tmp_path / "ck"),
                      async_ckpt=True, log_every=100),
        optimizer=AdamW(lr=cosine_schedule(3e-3, 5, 20)),
        seq_len=64, global_batch=4,
    )
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0], "training must reduce loss"

    server = Server(cfg, ServerConfig(batch_slots=2, max_seq=128,
                                      max_new_tokens=12), out["state"]["params"])
    reqs = [Request(rid=i, prompt=np.arange(2, 8, dtype=np.int32)) for i in range(4)]
    results = server.serve(reqs)
    assert set(results) == {0, 1, 2, 3}
    assert all(1 <= len(v) <= 12 for v in results.values())
    assert all(0 <= t < cfg.vocab_size for v in results.values() for t in v)
    assert server.last_stats["decode_steps"] > 0


def test_dip_format_system_runs_with_pallas_kernels(tmp_path):
    """The paper's storage format + fused kernel as the live matmul path."""
    cfg = _cfg(matmul_backend="pallas_dip", vocab_size=256,
               d_model=64, d_ff=128)
    trainer = Trainer(
        cfg,
        TrainerConfig(steps=4, ckpt_every=100, ckpt_dir=str(tmp_path / "ck2"),
                      async_ckpt=False, log_every=100),
        optimizer=AdamW(lr=1e-3),
        seq_len=32, global_batch=2,
    )
    out = trainer.run()
    assert np.isfinite(out["metrics"][-1]["loss"])
    assert out["metrics"][-1]["loss"] < out["metrics"][0]["loss"] * 1.2


def test_weight_format_checkpoint_roundtrips_permutated(tmp_path):
    """Checkpoints persist the permutated storage (as DipWeight pytree
    nodes); restore + de-permute recovers the natural weights exactly."""
    from repro.api import DipWeight
    from repro.checkpoint import restore_pytree, save_pytree

    cfg = _cfg(dip_weights=True)
    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "dipck")
    save_pytree(path, params)
    got = restore_pytree(path, jax.eval_shape(lambda: params))
    assert isinstance(got["layers"]["wq"], DipWeight)
    w_stored = got["layers"]["wq"]
    w_live = params["layers"]["wq"]
    assert (w_stored.d_in, w_stored.d_out) == (w_live.d_in, w_live.d_out)
    np.testing.assert_array_equal(np.asarray(w_stored.data), np.asarray(w_live.data))
    # storage really is permutated: de-shear differs from raw storage
    nat = w_live.to_natural()
    assert not np.array_equal(np.asarray(nat[0]), np.asarray(w_live.data[0]))
