"""Docs cannot silently rot: the fenced-Python checker (also run as the CI
docs job) must pass, and the docs the README/ISSUE promise must exist."""

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_required_docs_exist():
    for rel in ("README.md", "docs/api.md", "docs/tuning.md",
                "docs/architecture.md", "docs/reliability.md"):
        assert (ROOT / rel).exists(), rel


def test_every_python_block_parses():
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    paths = check_docs.default_paths(ROOT)
    assert len(paths) >= 4
    assert check_docs.check(paths) == []


def test_checker_flags_broken_block(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("# t\n```python\ndef oops(:\n```\n")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py"), str(bad)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 1
    assert "bad.md" in proc.stderr
