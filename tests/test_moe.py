"""MoE routing invariants: conservation, capacity, shared experts, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.configs.base import ArchConfig
from repro.models import moe, transformer as tf_model

KEY = jax.random.PRNGKey(3)


def _cfg(e=8, k=2, shared=0, cf=1.25):
    return ArchConfig(
        name="m", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab_size=64, head_dim=16, n_experts=e, moe_top_k=k,
        n_shared_experts=shared, d_ff_expert=16, capacity_factor=cf,
        remat="none", compute_dtype="float32",
    )


def _params(cfg, key=KEY):
    p = tf_model.init_params(key, cfg)
    return p["layers"]


def _layer_slice(lp):
    return jax.tree_util.tree_map(lambda t: t[0], lp)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    lp = _layer_slice(_params(cfg))
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    out, aux, dropped = moe.moe_ffn(x, lp, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and np.isfinite(float(aux))
    assert int(dropped) == 0  # cf=1.25 leaves headroom at these shapes


def test_capacity_overflow_drops_tokens_but_stays_finite():
    """cf -> tiny forces drops; output must stay finite AND the drop
    count must surface them (the old API dropped silently)."""
    cfg = _cfg(cf=0.05)
    lp = _layer_slice(_params(cfg))
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    out, _, dropped = moe.moe_ffn(x, lp, cfg)
    assert bool(jnp.isfinite(out).all())
    # 2 groups x 32 tokens x k=2 slots = 128 demanded, capacity 8/expert
    assert int(dropped) > 0


def test_huge_capacity_equals_explicit_dense_routing():
    """With capacity >= tokens*k no drops occur: the scatter/gather dispatch
    must equal an explicit per-token loop over its top-k experts."""
    cfg = _cfg(e=4, k=2, cf=64.0)
    lp = _layer_slice(_params(cfg))
    x = jax.random.normal(KEY, (1, 6, cfg.d_model))
    got, _, dropped = moe.moe_ffn(x, lp, cfg)
    assert int(dropped) == 0  # cf=64 is ample: parity claim requires no drops

    # reference: dense routing
    xf = np.asarray(x.reshape(6, -1), np.float64)
    logits = xf @ np.asarray(lp["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ids = np.argsort(-probs, -1)[:, :2]
    want = np.zeros_like(xf)
    wg = np.asarray(lp["w_gate"], np.float64)
    wu = np.asarray(lp["w_up"], np.float64)
    wd = np.asarray(lp["w_down"], np.float64)

    def silu(v):
        return v / (1 + np.exp(-v))

    for t in range(6):
        g = probs[t, ids[t]]
        g = g / g.sum()
        for j, e in enumerate(ids[t]):
            h = silu(xf[t] @ wg[e]) * (xf[t] @ wu[e])
            want[t] += g[j] * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(got[0]), want, atol=2e-3, rtol=1e-2)


def test_single_expert_equals_dense_ffn_with_zero_drops():
    """num_experts=1, top_k=1: routing is the identity (one expert takes
    every token at gate 1.0), so moe_ffn must equal dense_ffn over the same
    weights — and the surfaced drop count must be ZERO, which is what makes
    the equality claim sound (a silent drop would fail it confusingly)."""
    cfg = _cfg(e=1, k=1)
    lp = _layer_slice(_params(cfg))
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    got, _, dropped = moe.moe_ffn(x, lp, cfg)
    assert int(dropped) == 0
    want = moe.dense_ffn(
        x,
        {"w_gate": lp["w_gate"][0], "w_up": lp["w_up"][0],
         "w_down": lp["w_down"][0]},
        cfg,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_shared_experts_added():
    cfg_ns = _cfg(shared=0)
    cfg_sh = _cfg(shared=1)
    lp = _layer_slice(_params(cfg_sh))
    x = jax.random.normal(KEY, (1, 4, cfg_sh.d_model))
    out_sh, _, _ = moe.moe_ffn(x, lp, cfg_sh)
    out_ns, _, _ = moe.moe_ffn(x, {k: v for k, v in lp.items() if not k.startswith("shared")}, cfg_ns)
    shared_only = moe.dense_ffn(
        x,
        {"w_gate": lp["shared_w_gate"], "w_up": lp["shared_w_up"],
         "w_down": lp["shared_w_down"]},
        cfg_sh,
    )
    np.testing.assert_allclose(
        np.asarray(out_sh), np.asarray(out_ns + shared_only), atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(tokens=st.integers(4, 64), e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_capacity_function_bounds(tokens, e, k):
    cfg = _cfg(e=e, k=k)
    cap = moe.moe_capacity(tokens, cfg)
    assert cap >= 8 and cap % 8 == 0
    assert cap * e >= tokens * k  # with cf >= 1, total slots cover demand


def test_aux_loss_decreases_under_balanced_routing():
    """Uniform router logits => minimal load-balance loss (= cfg coefficient)."""
    cfg = _cfg(e=4, k=1)
    lp = dict(_layer_slice(_params(cfg)))
    lp["router"] = jnp.zeros_like(lp["router"])  # perfectly uniform
    x = jax.random.normal(KEY, (1, 64, cfg.d_model))
    _, aux_uniform, _ = moe.moe_ffn(x, lp, cfg)
    lp["router"] = lp["router"].at[:, 0].set(10.0)  # collapse to expert 0
    _, aux_collapsed, _ = moe.moe_ffn(x, lp, cfg)
    assert float(aux_collapsed) > float(aux_uniform)
