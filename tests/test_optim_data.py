"""Optimizer + schedules + data pipeline unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataState, SyntheticLM
from repro.optim import AdamW, clip_by_global_norm, cosine_schedule, linear_warmup


# ---------------------------------------------------------------- optimizer --
def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0, 5.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 1.0, 1.0])

    @jax.jit
    def step(params, state):
        grads = {"w": 2 * (params["w"] - target)}
        updates, state = opt.update(grads, state, params)
        return {"w": params["w"] + updates["w"]}, state

    for _ in range(200):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_clipping():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == 20.0
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_weight_decay_applies_to_matrices_only():
    opt = AdamW(lr=1.0, weight_decay=0.5, b1=0.0, b2=0.0, eps=1e-8, clip_norm=1e9)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = opt.init(params)
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    updates, _ = opt.update(grads, state, params)
    assert float(jnp.abs(updates["mat"]).max()) > 0      # decayed
    assert float(jnp.abs(updates["vec"]).max()) == 0     # not decayed


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(jnp.asarray(5))) == 0.5
    assert float(warm(jnp.asarray(100))) == 1.0
    cos = cosine_schedule(1.0, 10, 110, min_frac=0.1)
    assert float(cos(jnp.asarray(110))) == jnp.float32(0.1)
    assert float(cos(jnp.asarray(10))) == 1.0


# --------------------------------------------------------------------- data --
def test_data_deterministic_and_restartable():
    kw = dict(vocab_size=1000, seq_len=64, global_batch=4, seed=7)
    a = SyntheticLM(**kw).batch(12)
    b = SyntheticLM(**kw).batch(12)   # fresh instance, same (seed, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(**kw).batch(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_sharding_partitions_global_batch():
    kw = dict(vocab_size=1000, seq_len=32, global_batch=8, seed=1)
    full = SyntheticLM(**kw).batch(3)["tokens"]
    parts = [
        SyntheticLM(**kw, shard_index=i, num_shards=4).batch(3)["tokens"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_data_prefetch_iterator():
    pipe = SyntheticLM(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    pipe.start(DataState(step=5))
    it = iter(pipe)
    s0, b0 = next(it)
    s1, b1 = next(it)
    pipe.stop()
    assert (s0, s1) == (5, 6)
    np.testing.assert_array_equal(b0["tokens"], pipe.batch(5)["tokens"])


def test_data_tokens_in_range_and_structured():
    b = SyntheticLM(vocab_size=500, seq_len=512, global_batch=16, seed=2).batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 500
    # EOS-delimited documents appear across the batch (doc len ~ geom(384))
    assert (b["tokens"] == 1).sum() > 0


def test_data_embeddings_mode():
    b = SyntheticLM(vocab_size=500, seq_len=16, global_batch=2, seed=0,
                    emit_embeddings=32).batch(0)
    assert b["embeddings"].shape == (2, 16, 32)
    assert "tokens" not in b
