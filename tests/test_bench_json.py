"""Perf reporting must not silently rot: the kernels benchmark's
machine-readable output (BENCH_kernels.json) is produced and schema-valid
on a tiny interpret-mode shape — the same invocation the CI ``bench-smoke``
job runs.
"""

import json

import pytest

kernels_bench = pytest.importorskip("benchmarks.kernels_bench")


def test_compare_epilogues_writes_schema_valid_json(tmp_path):
    out = tmp_path / "BENCH_kernels.json"
    rc = kernels_bench.main(
        ["--compare-epilogues", "--tiny", "--iters", "1", "--out", str(out)]
    )
    assert rc == 0 and out.exists()
    payload = kernels_bench.validate_bench_json(out)
    ec = payload["epilogue_compare"]
    # the acceptance headline: fused SwiGLU is ONE kernel launch where the
    # unfused path is three ops (two matmul launches + elementwise glue)
    swiglu = next(r for r in ec["results"] if r["epilogue"] == "swiglu")
    assert swiglu["fused_pallas_calls"] == 1
    assert swiglu["unfused_pallas_calls"] >= 2
    assert {r["epilogue"] for r in ec["results"]} >= {"bias", "swiglu", "residual"}
    assert payload["entries"], "timing entries missing"


def test_sharded_compare_writes_schema_valid_json(tmp_path):
    """--sharded re-execs itself onto 8 virtual devices, records explicit
    dip_tp/dip_fsdp vs GSPMD-xla timings, and the collective counts in the
    payload honour the placement contract (validated by the schema)."""
    out = tmp_path / "BENCH_sharded.json"
    rc = kernels_bench.main(
        ["--sharded", "--tiny", "--iters", "1", "--out", str(out)]
    )
    assert rc == 0 and out.exists()
    payload = kernels_bench.validate_bench_json(out)
    cases = {r["case"]: r for r in payload["sharded_compare"]["results"]}
    assert set(cases) == {"column", "row", "fsdp"}
    assert cases["column"]["psums"] == 0 and cases["column"]["all_gathers"] == 0
    assert cases["row"]["psums"] == 1
    assert cases["fsdp"]["all_gathers"] == 1 and cases["fsdp"]["psums"] == 0
    for rec in cases.values():
        assert rec["pallas_calls"] >= 1          # the shard still launches
        assert rec["explicit_us"] > 0 and rec["gspmd_us"] > 0


def test_validate_bench_json_rejects_schema_violations(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema_version": 999, "entries": []}))
    with pytest.raises(ValueError, match="schema_version"):
        kernels_bench.validate_bench_json(bad)
    bad.write_text(json.dumps({
        "schema_version": kernels_bench.BENCH_SCHEMA_VERSION,
        "entries": [{"name": "x", "us_per_call": 1.0}],
        "epilogue_compare": {"backend": "pallas_dip", "shape": [1, 2, 3],
                             "results": [{"epilogue": "bias"}]},
    }))
    with pytest.raises(ValueError, match="missing"):
        kernels_bench.validate_bench_json(bad)
    # a drifting collective count is a SCHEMA violation, not just a test
    rec = {"case": "column", "backend": "dip_tp", "explicit_us": 1.0,
           "gspmd_us": 1.0, "psums": 1, "all_gathers": 0, "pallas_calls": 1,
           "gspmd_hlo_collectives": 0}
    bad.write_text(json.dumps({
        "schema_version": kernels_bench.BENCH_SCHEMA_VERSION,
        "entries": [{"name": "x", "us_per_call": 1.0}],
        "sharded_compare": {
            "mesh_axes": {"data": 2, "model": 4}, "shape": [8, 256, 256],
            "results": [rec,
                        dict(rec, case="row", psums=1),
                        dict(rec, case="fsdp", psums=0, all_gathers=1)],
        },
    }))
    with pytest.raises(ValueError, match="column-parallel recorded"):
        kernels_bench.validate_bench_json(bad)
