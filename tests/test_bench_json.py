"""Perf reporting must not silently rot: the kernels benchmark's
machine-readable output (BENCH_kernels.json) is produced and schema-valid
on a tiny interpret-mode shape — the same invocation the CI ``bench-smoke``
job runs.
"""

import json

import pytest

kernels_bench = pytest.importorskip("benchmarks.kernels_bench")


def test_compare_epilogues_writes_schema_valid_json(tmp_path):
    out = tmp_path / "BENCH_kernels.json"
    rc = kernels_bench.main(
        ["--compare-epilogues", "--tiny", "--iters", "1", "--out", str(out)]
    )
    assert rc == 0 and out.exists()
    payload = kernels_bench.validate_bench_json(out)
    ec = payload["epilogue_compare"]
    # the acceptance headline: fused SwiGLU is ONE kernel launch where the
    # unfused path is three ops (two matmul launches + elementwise glue)
    swiglu = next(r for r in ec["results"] if r["epilogue"] == "swiglu")
    assert swiglu["fused_pallas_calls"] == 1
    assert swiglu["unfused_pallas_calls"] >= 2
    assert {r["epilogue"] for r in ec["results"]} >= {"bias", "swiglu", "residual"}
    assert payload["entries"], "timing entries missing"


def test_sharded_compare_writes_schema_valid_json(tmp_path):
    """--sharded re-execs itself onto 8 virtual devices, records explicit
    dip_tp/dip_fsdp vs GSPMD-xla timings, and the collective counts in the
    payload honour the placement contract (validated by the schema)."""
    out = tmp_path / "BENCH_sharded.json"
    rc = kernels_bench.main(
        ["--sharded", "--tiny", "--iters", "1", "--out", str(out)]
    )
    assert rc == 0 and out.exists()
    payload = kernels_bench.validate_bench_json(out)
    cases = {r["case"]: r for r in payload["sharded_compare"]["results"]}
    assert set(cases) == {"column", "row", "fsdp"}
    assert cases["column"]["psums"] == 0 and cases["column"]["all_gathers"] == 0
    assert cases["row"]["psums"] == 1
    assert cases["fsdp"]["all_gathers"] == 1 and cases["fsdp"]["psums"] == 0
    for rec in cases.values():
        assert rec["pallas_calls"] >= 1          # the shard still launches
        assert rec["explicit_us"] > 0 and rec["gspmd_us"] > 0


def test_validate_bench_json_rejects_schema_violations(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema_version": 999, "entries": []}))
    with pytest.raises(ValueError, match="schema_version"):
        kernels_bench.validate_bench_json(bad)
    bad.write_text(json.dumps({
        "schema_version": kernels_bench.BENCH_SCHEMA_VERSION,
        "entries": [{"name": "x", "us_per_call": 1.0}],
        "epilogue_compare": {"backend": "pallas_dip", "shape": [1, 2, 3],
                             "results": [{"epilogue": "bias"}]},
    }))
    with pytest.raises(ValueError, match="missing"):
        kernels_bench.validate_bench_json(bad)
    # a drifting collective count is a SCHEMA violation, not just a test
    rec = {"case": "column", "backend": "dip_tp", "explicit_us": 1.0,
           "gspmd_us": 1.0, "psums": 1, "all_gathers": 0, "pallas_calls": 1,
           "gspmd_hlo_collectives": 0}
    bad.write_text(json.dumps({
        "schema_version": kernels_bench.BENCH_SCHEMA_VERSION,
        "entries": [{"name": "x", "us_per_call": 1.0}],
        "sharded_compare": {
            "mesh_axes": {"data": 2, "model": 4}, "shape": [8, 256, 256],
            "results": [rec,
                        dict(rec, case="row", psums=1),
                        dict(rec, case="fsdp", psums=0, all_gathers=1)],
        },
    }))
    with pytest.raises(ValueError, match="column-parallel recorded"):
        kernels_bench.validate_bench_json(bad)


# ----------------------------------------------------- serving bench JSON ---
serving_bench = pytest.importorskip("benchmarks.serving_bench")


def test_committed_serving_baseline_validates():
    """The committed BENCH_serving.json (the acceptance record: engine beats
    the wave baseline on tok/s AND p99; int8 holds more than bf16) must stay
    schema-valid."""
    import pathlib
    baseline = pathlib.Path(__file__).parent.parent / "BENCH_serving.json"
    payload = serving_bench.validate_serving_json(baseline)
    assert payload["engines"]["paged"]["tok_per_s"] > payload["engines"]["wave"]["tok_per_s"]
    cap = payload["capacity"]
    assert cap["int8_max_concurrent"] > cap["bf16_max_concurrent"]


def _serving_payload(**over):
    eng = {"tok_per_s": 50.0, "p50_latency_s": 0.1, "p99_latency_s": 0.5,
           "total_tokens": 100, "decode_steps": 40, "wall_s": 2.0}
    wave = dict(eng, tok_per_s=25.0, p99_latency_s=1.5, decode_steps=80)
    payload = {
        "schema_version": serving_bench.SERVING_SCHEMA_VERSION,
        "arch": "llama3_8b", "slots": 4, "kv_quant": "none",
        "workload": {"requests": 10, "arrival_rate_rps": 50.0,
                     "max_new_range": [2, 16], "seed": 0},
        "engines": {"paged": eng, "wave": wave},
        "capacity": {"budget_bytes": 1 << 20, "block_size": 16, "seq_len": 64,
                     "bf16_blocks": 32, "int8_blocks": 65,
                     "bf16_max_concurrent": 8, "int8_max_concurrent": 16},
    }
    payload.update(over)
    return payload


def test_validate_serving_json_rejects_violations(tmp_path):
    bad = tmp_path / "bad.json"

    def check(match, **over):
        bad.write_text(json.dumps(_serving_payload(**over)))
        with pytest.raises(ValueError, match=match):
            serving_bench.validate_serving_json(bad)

    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(_serving_payload()))
    serving_bench.validate_serving_json(ok)         # the fixture itself passes

    check("schema_version", schema_version=999)
    check("both 'paged' and 'wave'", engines={"paged": {}})
    p = _serving_payload()["engines"]
    # losing either axis is a schema violation, not just a slow run
    check("tok/s", engines={"paged": dict(p["paged"], tok_per_s=10.0),
                            "wave": p["wave"]})
    check("p99", engines={"paged": dict(p["paged"], p99_latency_s=2.0),
                          "wave": p["wave"]})
    c = _serving_payload()["capacity"]
    check("strictly more blocks", capacity=dict(c, int8_blocks=32))
    check("concurrent", capacity=dict(c, int8_max_concurrent=8))
    # missing percentile keys
    check("p99_latency_s", engines={
        "paged": {k: v for k, v in p["paged"].items() if k != "p99_latency_s"},
        "wave": p["wave"]})


# ------------------------------------------------- reliability bench JSON ---
reliability_bench = pytest.importorskip("benchmarks.reliability_bench")


def test_reliability_bench_writes_schema_valid_json(tmp_path):
    """The CI ``reliability`` job's invocation: tiny shape, the verify
    overhead contract (<= 1.15x) and every chaos-smoke detection hold."""
    out = tmp_path / "BENCH_reliability.json"
    rc = reliability_bench.main(["--tiny", "--out", str(out)])
    assert rc == 0 and out.exists()
    payload = reliability_bench.validate_reliability_json(out)
    vo = payload["verify_overhead"]
    assert vo["ratio"] <= vo["max_ratio"]
    assert all(payload["chaos_smoke"].values())


def test_committed_reliability_baseline_validates():
    """The committed BENCH_reliability.json must stay schema-valid."""
    import pathlib
    baseline = pathlib.Path(__file__).parent.parent / "BENCH_reliability.json"
    payload = reliability_bench.validate_reliability_json(baseline)
    assert payload["chaos_smoke"]["weight_flip_detected"] is True


def test_validate_reliability_json_rejects_violations(tmp_path):
    bad = tmp_path / "bad.json"

    def payload(**over):
        base = {
            "schema_version": reliability_bench.RELIABILITY_SCHEMA_VERSION,
            "jax_backend": "cpu",
            "verify_overhead": {
                "backend": "xla", "shape": [128, 256, 256], "iters": 3,
                "unverified_us": 100.0, "verified_us": 105.0,
                "ratio": 1.05, "max_ratio": reliability_bench.MAX_VERIFY_RATIO,
            },
            "chaos_smoke": {"weight_flip_detected": True,
                            "quant_flip_detected": True,
                            "nan_detected": True},
        }
        base.update(over)
        return base

    bad.write_text(json.dumps(payload()))
    reliability_bench.validate_reliability_json(bad)   # the fixture passes

    def check(match, **over):
        bad.write_text(json.dumps(payload(**over)))
        with pytest.raises(ValueError, match=match):
            reliability_bench.validate_reliability_json(bad)

    check("schema_version", schema_version=999)
    vo = payload()["verify_overhead"]
    # blowing the wall-time contract is a SCHEMA violation
    check("wall time", verify_overhead=dict(vo, ratio=1.5))
    # an escaped injected fault is a SCHEMA violation
    cs = payload()["chaos_smoke"]
    check("escaped detection", chaos_smoke=dict(cs, nan_detected=False))


# ------------------------------------------------------- fleet bench JSON ---
fleet = pytest.importorskip("benchmarks.fleet")


def test_committed_fleet_baseline_validates():
    """The committed BENCH_fleet.json is the standing regression net: it must
    stay schema-valid, cover every zoo config with all three stages passing
    in at least one cell (the acceptance headline), honour the dip_tp /
    dip_fsdp placement contracts, and self-diff clean (so the CI fleet job's
    diff logic cannot reject the baseline itself)."""
    import pathlib

    from repro.configs import ALL_ARCHS

    path = pathlib.Path(__file__).parent.parent / "BENCH_fleet.json"
    with open(path) as f:
        payload = json.load(f)
    fleet.validate_fleet_json(payload)
    assert payload["matrix"] == "tiny"
    archs = {c["arch"] for c in payload["cells"]}
    assert archs == set(ALL_ARCHS), f"baseline missing archs: {set(ALL_ARCHS) - archs}"
    # the sharded columns are present with their probes
    effs = {c["effective_backend"] for c in payload["cells"]}
    assert {"dip_tp", "dip_fsdp"} <= effs
    fleet.diff_fleet_json(payload, payload)
