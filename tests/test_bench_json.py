"""Perf reporting must not silently rot: the kernels benchmark's
machine-readable output (BENCH_kernels.json) is produced and schema-valid
on a tiny interpret-mode shape — the same invocation the CI ``bench-smoke``
job runs.
"""

import json

import pytest

kernels_bench = pytest.importorskip("benchmarks.kernels_bench")


def test_compare_epilogues_writes_schema_valid_json(tmp_path):
    out = tmp_path / "BENCH_kernels.json"
    rc = kernels_bench.main(
        ["--compare-epilogues", "--tiny", "--iters", "1", "--out", str(out)]
    )
    assert rc == 0 and out.exists()
    payload = kernels_bench.validate_bench_json(out)
    ec = payload["epilogue_compare"]
    # the acceptance headline: fused SwiGLU is ONE kernel launch where the
    # unfused path is three ops (two matmul launches + elementwise glue)
    swiglu = next(r for r in ec["results"] if r["epilogue"] == "swiglu")
    assert swiglu["fused_pallas_calls"] == 1
    assert swiglu["unfused_pallas_calls"] >= 2
    assert {r["epilogue"] for r in ec["results"]} >= {"bias", "swiglu", "residual"}
    assert payload["entries"], "timing entries missing"


def test_validate_bench_json_rejects_schema_violations(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema_version": 999, "entries": []}))
    with pytest.raises(ValueError, match="schema_version"):
        kernels_bench.validate_bench_json(bad)
    bad.write_text(json.dumps({
        "schema_version": kernels_bench.BENCH_SCHEMA_VERSION,
        "entries": [{"name": "x", "us_per_call": 1.0}],
        "epilogue_compare": {"backend": "pallas_dip", "shape": [1, 2, 3],
                             "results": [{"epilogue": "bias"}]},
    }))
    with pytest.raises(ValueError, match="missing"):
        kernels_bench.validate_bench_json(bad)
