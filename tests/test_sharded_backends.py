"""Conformance for the explicit multi-chip backends (`dip_tp` / `dip_fsdp` /
`dip_sp` / `dip_ep`) and the ShardingPlan metadata they dispatch on.

Two layers of coverage:

* **Multi-device conformance** (subprocess, 8 forced host devices — shared
  helper in conftest): every epilogue x dtype for column-parallel,
  row-parallel, and fsdp dispatch against the single-device ``api.matmul``
  reference, with jaxpr-asserted collective counts (zero for column, exactly
  ONE psum for row — including the dual-weight swiglu pair — one all_gather
  per weight for fsdp), quantized weights included (bit-exact for int8 on
  the full-K paths, per the documented tolerance on the K-split path), and a
  reduced end-to-end model forward through ``dip_tp``.  ``dip_sp`` adds the
  sequence-parallel contract: NO pre-kernel all_gather — the x blocks ring
  through the kernel's load stage via ppermute issued before each fused
  launch (column) or a single reduce_scatter (row).  ``dip_ep`` adds the
  MoE expert-parallel contract: exactly TWO all_to_alls per ``moe_ffn``
  call (dispatch + combine), with the dispatch issued before the
  shared-expert compute it hides behind.
* **Plan metadata invariants** (in-process, device-count independent): the
  ``WeightPlan`` carried on a weight survives jit / scan / grad /
  checkpoint-save/restore; restore validates plans against the live mesh;
  plan-free weights decompose to GSPMD; registration rules for sharded
  layouts hold.

Tolerances (documented in docs/distributed.md): column/fsdp run the SAME
f32-accumulated kernel over the full contraction, so they match the
single-device dispatch to launch-order noise (bit-exact for int8 — identical
activation quantization and int32 accumulation); row-parallel splits K, so
float results differ by f32 reduction reordering (<= the generic f32
tolerance) and int8 results re-quantize activations per shard (compared
against the float reference within the documented int8 bound instead).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import run_forced_devices as _run

from repro import api
from repro.checkpoint import restore_pytree, save_pytree
from repro.distributed.plan import ShardingPlan, WeightPlan, make_local_mesh, make_plan


# ===========================================================================
# multi-device conformance (subprocess; 8 forced host devices)
# ===========================================================================
def test_sharded_backends_match_single_device_every_epilogue():
    """The acceptance matrix: dip_tp(column) / dip_tp(row) / dip_fsdp vs the
    single-device pallas_dip dispatch for every epilogue x float dtype, plus
    jaxpr collective counts."""
    out = _run("""
from repro import api
from repro.distributed.plan import WeightPlan, make_local_mesh
from repro.kernels.dip_matmul_sharded import count_collectives

mesh = make_local_mesh(data=2, model=4)
col = WeightPlan("column", axis="model", fsdp="data", mesh=mesh)
row = WeightPlan("row", axis="model", fsdp="data", mesh=mesh)

m, k, n = 8, 256, 256
r = np.random.default_rng(0)
TOL = {"float32": dict(atol=2e-3, rtol=2e-3),
       "bfloat16": dict(atol=0.5, rtol=0.05)}

def inputs(epilogue, dtype):
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32)).astype(dtype)
    wg = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32)).astype(dtype)
    wu = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))
    resid = jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32)).astype(dtype)
    if epilogue == "swiglu":
        return x, (wg, wu), ()
    if epilogue.startswith("bias"):
        return x, wg, (b,)
    if epilogue == "residual":
        return x, wg, (resid,)
    return x, wg, ()

def wrap(w, plan):
    if isinstance(w, tuple):
        return tuple(api.DipWeight.from_natural(wi, plan=plan) for wi in w)
    return api.DipWeight.from_natural(w, plan=plan)

cases = [("dip_tp", col, "column"), ("dip_tp", row, "row"),
         ("dip_fsdp", col, "fsdp")]
for epilogue in api.EPILOGUES:
    for dtype in ("float32", "bfloat16"):
        x, w, ops = inputs(epilogue, dtype)
        want = api.matmul(x, wrap(w, None), backend="pallas_dip",
                          epilogue=epilogue, epilogue_operands=ops)
        for backend, plan, label in cases:
            got = api.matmul(x, wrap(w, plan), backend=backend,
                             epilogue=epilogue, epilogue_operands=ops)
            assert got.shape == want.shape, (label, epilogue, dtype)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                **TOL[dtype], err_msg=f"{label}/{epilogue}/{dtype}")
print("PARITY_OK")

# ---- fused rmsnorm prologue across sharded dispatch ----------------------
# column/fsdp keep the full K local, so the norm fuses into the per-shard
# kernel; row-parallel splits K and must DECOMPOSE (a shard cannot see the
# whole row to reduce it) — both must match the single-device fused result.
g = jnp.asarray(r.normal(1, 0.1, (k,)).astype(np.float32))
for dtype in ("float32", "bfloat16"):
    x, wg, _ = inputs("none", dtype)
    want = api.matmul(x, wrap(wg, None), backend="pallas_dip",
                      prologue="rmsnorm", prologue_operands=(g,))
    for backend, plan, label in cases:
        got = api.matmul(x, wrap(wg, plan), backend=backend,
                         prologue="rmsnorm", prologue_operands=(g,))
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype], err_msg=f"prologue/{label}/{dtype}")
print("PROLOGUE_OK")

# ---- jaxpr-asserted collective placement ---------------------------------
x, wg, _ = inputs("none", "float32")
_, pair, _ = inputs("swiglu", "float32")
def counts(backend, w, epilogue="none", ops=()):
    return count_collectives(
        lambda xx: api.matmul(xx, w, backend=backend, epilogue=epilogue,
                              epilogue_operands=ops), x)

c = counts("dip_tp", wrap(wg, col))
assert c["psum"] == 0 and c["all_gather"] == 0 and c["pallas_call"] == 1, c
c = counts("dip_tp", wrap(pair, col), "swiglu")
assert c["psum"] == 0 and c["pallas_call"] == 1, c   # ONE fused launch/shard
c = counts("dip_tp", wrap(wg, row))
assert c["psum"] == 1 and c["all_gather"] == 0 and c["pallas_call"] == 1, c
c = counts("dip_tp", wrap(pair, row), "swiglu")
assert c["psum"] == 1 and c["pallas_call"] == 2, c   # ONE psum for the pair
bias = jnp.zeros((n,), jnp.float32)
c = counts("dip_tp", wrap(wg, row), "bias_silu", (bias,))
assert c["psum"] == 1, c                             # epilogue past the psum
c = counts("dip_fsdp", wrap(wg, col))
assert c["all_gather"] == 1 and c["psum"] == 0 and c["pallas_call"] == 1, c
c = counts("dip_fsdp", wrap(pair, col), "swiglu")
assert c["all_gather"] == 2 and c["psum"] == 0 and c["pallas_call"] == 1, c
print("COLLECTIVES_OK")
""", devices=8, timeout=900)
    assert "PARITY_OK" in out and "COLLECTIVES_OK" in out
    assert "PROLOGUE_OK" in out


def test_sharded_backends_quantized_exact_for_int8():
    """Quantized dispatch through the sharded backends: the scales shard
    with N on the column path, and the full-K paths (column / fsdp) are
    BIT-EXACT vs the single-device int8 kernel (same per-row activation
    quantization, same int32 accumulation); the K-split row path re-scales
    activations per shard and is held to the documented int8-vs-float
    bound instead."""
    out = _run("""
from repro import api
from repro.distributed.plan import WeightPlan, make_local_mesh
from repro.kernels import ref

mesh = make_local_mesh(data=2, model=4)
col = WeightPlan("column", axis="model", fsdp="data", mesh=mesh)
row = WeightPlan("row", axis="model", fsdp="data", mesh=mesh)

m, k, n = 8, 256, 256
r = np.random.default_rng(1)
x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
b = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))

for scheme in sorted(api.quant.SCHEMES):
    qw = api.quant.quantize(w, scheme)
    for epilogue, ops in (("none", ()), ("bias_silu", (b,))):
        want = api.matmul(x, qw, epilogue=epilogue, epilogue_operands=ops)
        got_col = api.matmul(x, qw.with_plan(col), backend="dip_tp",
                             epilogue=epilogue, epilogue_operands=ops)
        got_fsdp = api.matmul(x, qw.with_plan(col), backend="dip_fsdp",
                              epilogue=epilogue, epilogue_operands=ops)
        if scheme == "int8":
            np.testing.assert_array_equal(np.asarray(got_col), np.asarray(want))
            np.testing.assert_array_equal(np.asarray(got_fsdp), np.asarray(want))
        else:
            np.testing.assert_allclose(np.asarray(got_col), np.asarray(want),
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(got_fsdp), np.asarray(want),
                                       atol=1e-5, rtol=1e-5)

# K-split row path: per-shard activation re-quantization -> compare against
# the FLOAT reference within the documented int8 bound (docs/quantization.md)
qw = api.quant.quantize(w, "int8")
got_row = api.matmul(x, qw.with_plan(row), backend="dip_tp")
want_f = np.asarray(ref.ws_matmul_ref(x, w))
dev = np.abs(np.asarray(got_row) - want_f).max() / np.abs(want_f).max()
assert dev < 0.02, f"row-parallel int8 deviation {dev}"
print("QUANT_OK")
""", devices=8, timeout=600)
    assert "QUANT_OK" in out


def test_dip_sp_parity_counts_and_schedule():
    """Sequence-parallel dispatch: parity vs the single-device kernel for a
    representative epilogue/prologue slice, then the overlap contract in the
    jaxpr — the column path issues NO all_gather (each shard's x block is
    gathered inside the kernel's load stage: tp-1 ppermutes, each issued
    BEFORE the fused launch it overlaps), the row path ends in ONE
    reduce_scatter, and int8 stays bit-exact on the full-K column path."""
    out = _run("""
from repro import api
from repro.distributed.plan import WeightPlan, make_local_mesh
from repro.kernels import ref
from repro.kernels.dip_matmul_sharded import collective_schedule, count_collectives

mesh = make_local_mesh(data=2, model=4)
col = WeightPlan("column", axis="model", fsdp="data", mesh=mesh)
row = WeightPlan("row", axis="model", fsdp="data", mesh=mesh)
m, k, n = 8, 256, 256
r = np.random.default_rng(0)
x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
wg = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
wu = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
b = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))
resid = jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32))

def wrap(w, plan):
    if isinstance(w, tuple):
        return tuple(api.DipWeight.from_natural(wi, plan=plan) for wi in w)
    return api.DipWeight.from_natural(w, plan=plan)

for epi, w, ops in [("none", wg, ()), ("bias_gelu", wg, (b,)),
                    ("residual", wg, (resid,)), ("swiglu", (wg, wu), ())]:
    want = api.matmul(x, wrap(w, None), backend="pallas_dip",
                      epilogue=epi, epilogue_operands=ops)
    for plan, lbl in [(col, "col"), (row, "row")]:
        got = api.matmul(x, wrap(w, plan), backend="dip_sp",
                         epilogue=epi, epilogue_operands=ops)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=2e-3, err_msg=f"{lbl}/{epi}")
g = jnp.asarray(r.normal(1, 0.1, (k,)).astype(np.float32))
want = api.matmul(x, wrap(wg, None), backend="pallas_dip",
                  prologue="rmsnorm", prologue_operands=(g,))
for plan, lbl in [(col, "col"), (row, "row")]:
    got = api.matmul(x, wrap(wg, plan), backend="dip_sp",
                     prologue="rmsnorm", prologue_operands=(g,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3, err_msg=lbl)
print("SP_PARITY_OK")

# ---- the overlap contract, jaxpr-asserted --------------------------------
def sp(w, **kw):
    return lambda xx: api.matmul(xx, w, backend="dip_sp", **kw)

c = count_collectives(sp(wrap(wg, col)), x)
assert c["all_gather"] == 0 and c["psum"] == 0, c      # NO pre-kernel gather
assert c["ppermute"] == 3 and c["pallas_call"] == 4, c # tp-1 hops, tp launches
sched = collective_schedule(sp(wrap(wg, col)), x)
assert sched[0] == "ppermute", sched  # hop issued BEFORE the launch it hides
assert sched[:4] == ["ppermute", "pallas_call"] * 2, sched
c = count_collectives(sp(wrap((wg, wu), col), epilogue="swiglu"), x)
assert c["pallas_call"] == 4 and c["psum"] == 0, c     # ONE fused launch/step
c = count_collectives(sp(wrap(wg, row)), x)
assert c["reduce_scatter"] == 1 and c["psum"] == 0, c  # row: scatter, not psum
assert c["pallas_call"] == 1 and c["all_gather"] == 0, c
print("SP_COLLECTIVES_OK")

# ---- quantized -----------------------------------------------------------
qw = api.quant.quantize(wg, "int8")
got = api.matmul(x, qw.with_plan(col), backend="dip_sp")
np.testing.assert_array_equal(np.asarray(got), np.asarray(api.matmul(x, qw)))
got_row = api.matmul(x, qw.with_plan(row), backend="dip_sp")
want_f = np.asarray(ref.ws_matmul_ref(x, wg))
dev = np.abs(np.asarray(got_row) - want_f).max() / np.abs(want_f).max()
assert dev < 0.02, dev
xb, wb = x.astype(jnp.bfloat16), wg.astype(jnp.bfloat16)
want = api.matmul(xb, wrap(wb, None), backend="pallas_dip")
got = api.matmul(xb, wrap(wb, row), backend="dip_sp")
np.testing.assert_allclose(np.asarray(got, np.float32),
                           np.asarray(want, np.float32), atol=0.5, rtol=0.05)
print("SP_QUANT_OK")
""", devices=8, timeout=900)
    assert "SP_PARITY_OK" in out and "SP_COLLECTIVES_OK" in out
    assert "SP_QUANT_OK" in out


def test_dip_ep_moe_collective_contract():
    """Expert-parallel MoE: moe_ffn under an 'ep' plan must equal the
    global-dispatch path under zero drops, and its jaxpr must show exactly
    TWO all_to_alls (dispatch + combine) with the dispatch issued BEFORE the
    shared-expert launches it overlaps, plus ONE psum (aux/drop stats)."""
    out = _run("""
from repro.configs.base import ArchConfig
from repro.distributed.plan import make_local_mesh, make_plan
from repro.models import moe, transformer as tf_model
from repro.kernels.dip_matmul_sharded import collective_schedule, count_collectives

cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=64, n_heads=2,
                 n_kv_heads=2, d_ff=0, vocab_size=64, head_dim=32, n_experts=8,
                 moe_top_k=2, n_shared_experts=1, d_ff_expert=32,
                 capacity_factor=2.0, remat="none", compute_dtype="float32",
                 matmul_backend="dip_ep", sharding="ep")
key = jax.random.PRNGKey(0)
lp = jax.tree_util.tree_map(lambda t: t[0], tf_model.init_params(key, cfg)["layers"])
mesh = make_local_mesh(data=2, model=4)
plan = make_plan(mesh, cfg, "train")
assert plan.expert_plan is not None and plan.explicit_backend == "dip_ep"
lp = plan.attach_params(lp)
x = jax.random.normal(key, (4, 16, cfg.d_model))

ref_out, ref_aux, ref_drop = moe.moe_ffn(x, lp, cfg)        # global dispatch
with mesh:
    out, aux, drop = moe.moe_ffn(x, lp, cfg, plan=plan)     # expert-parallel
assert int(drop) == 0 and int(ref_drop) == 0
np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                           atol=2e-3, rtol=2e-3)
assert abs(float(ref_aux) - float(aux)) < 1e-3  # per-shard stats, averaged
print("EP_PARITY_OK")

c = count_collectives(lambda xx: moe.moe_ffn(xx, lp, cfg, plan=plan)[0], x)
assert c["all_to_all"] == 2 and c["psum"] == 1 and c["all_gather"] == 0, c
sched = collective_schedule(lambda xx: moe.moe_ffn(xx, lp, cfg, plan=plan)[0], x)
# dispatch a2a BEFORE the shared-expert launches it hides behind
assert sched.index("all_to_all") < sched.index("pallas_call"), sched
print("EP_COLLECTIVES_OK")

# seq-split fallback (batch not divisible by the axis) keeps parity
x2 = jax.random.normal(key, (2, 16, cfg.d_model))
ref2, _, _ = moe.moe_ffn(x2, lp, cfg)
with mesh:
    out2, _, d2 = moe.moe_ffn(x2, lp, cfg, plan=plan)
assert int(d2) == 0
np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2),
                           atol=2e-3, rtol=2e-3)
print("EP_SEQ_SPLIT_OK")
""", devices=8, timeout=900)
    assert "EP_PARITY_OK" in out and "EP_COLLECTIVES_OK" in out
    assert "EP_SEQ_SPLIT_OK" in out


def test_model_forward_through_dip_tp_matches_gspmd():
    """End to end: a reduced transformer with cfg.sharding='tp' and
    matmul_backend='dip_tp', plans attached by the ShardingPlan, forward
    under jit+scan on an 8-device mesh — logits match the implicit
    GSPMD-on-xla path."""
    out = _run("""
import dataclasses
from repro.configs.base import ArchConfig
from repro.distributed.plan import make_plan
from repro.models import transformer as tf_model

cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=256, n_heads=4,
                 n_kv_heads=4, d_ff=256, vocab_size=512, head_dim=64,
                 remat="none", compute_dtype="float32", param_dtype="float32",
                 matmul_backend="dip_tp", sharding="tp")
assert cfg.uses_dip_storage
key = jax.random.PRNGKey(0)
params = tf_model.init_params(key, cfg)
toks = jax.random.randint(key, (2, 8), 0, 512)

# implicit reference: same DiP-stored params through GSPMD-on-xla
ref_cfg = dataclasses.replace(cfg, matmul_backend="xla", sharding="gspmd")
ref_logits, _, _ = tf_model.forward(params, ref_cfg, tokens=toks)

mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = make_plan(mesh, cfg, "train")
params_tp = plan.attach_params(params)
# every 2-D projection in this config divides the mesh: all plans explicit
lyr = params_tp["layers"]
assert lyr["wq"].plan.kind == "column" and lyr["wo"].plan.kind == "row"
assert lyr["w_gate"].plan.kind == "column" and lyr["w_down"].plan.kind == "row"
shards = plan.param_shardings(params_tp)
with mesh:
    params_tp = jax.tree_util.tree_map(jax.device_put, params_tp, shards)
    fwd = jax.jit(lambda p, t: tf_model.forward(p, cfg, tokens=t, plan=plan)[0])
    logits = fwd(params_tp, toks)
np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                           atol=5e-2, rtol=5e-3)
print("MODEL_TP_OK")
""", devices=8, timeout=900)
    assert "MODEL_TP_OK" in out


# ===========================================================================
# plan metadata invariants (in-process; device-count independent)
# ===========================================================================
def _mesh11():
    return make_local_mesh(data=1, model=1)


def _plan_col(mesh=None):
    return WeightPlan("column", axis="model", fsdp="data", mesh=mesh or _mesh11())


def test_weight_plan_survives_jit_scan_grad():
    mesh = _mesh11()
    plan = _plan_col(mesh)
    r = np.random.default_rng(3)
    stacked = api.DipWeight.from_natural(
        jnp.asarray(r.normal(0, 1, (3, 100, 130)).astype(np.float32)), plan=plan
    )
    x = jnp.asarray(r.normal(0, 1, (4, 100)).astype(np.float32))

    @jax.jit
    def ident(w):
        return w

    back = ident(stacked)
    assert isinstance(back, api.DipWeight) and back.plan == plan

    def body(carry, lw):
        assert lw.plan == plan  # plan rides into the scan body (static aux)
        return carry, api.matmul(x, lw)

    _, ys = jax.lax.scan(body, 0, stacked)
    assert ys.shape == (3, 4, 130)

    g = jax.grad(
        lambda w: jnp.sum(api.matmul(x, w, backend="pallas_dip"))
    )(jax.tree_util.tree_map(lambda t: t[0], stacked))
    assert isinstance(g, api.DipWeight) and g.plan == plan

    spec = jax.eval_shape(lambda t: t, stacked)
    assert spec.plan == plan


def test_weight_plan_survives_checkpoint_and_validates_on_restore(tmp_path):
    mesh = _mesh11()
    plan = _plan_col(mesh)
    r = np.random.default_rng(5)
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    tree = {
        "wq": api.DipWeight.from_natural(w, plan=plan),
        "q": api.quant.quantize(w, "int8").with_plan(plan),
    }
    path = str(tmp_path / "ck")
    save_pytree(path, tree)

    # the manifest records the JSON plan (mesh reduced to axis sizes)
    import json, os
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    entry = manifest["dip_weights"]["['wq']"]  # tree_flatten_with_path key
    assert entry["plan"]["kind"] == "column"
    assert entry["plan"]["axis"] == "model"
    assert entry["plan"]["mesh_axes"] == {"data": 1, "model": 1}

    got = restore_pytree(path, jax.eval_shape(lambda: tree))
    assert got["wq"].plan == plan and got["q"].plan == plan
    np.testing.assert_array_equal(np.asarray(got["wq"].data),
                                  np.asarray(tree["wq"].data))

    # plan KIND mismatch on restore is detected
    bad_plan = WeightPlan("row", axis="model", fsdp="data", mesh=mesh)
    bad = jax.eval_shape(lambda: {
        "wq": tree["wq"].with_plan(bad_plan), "q": tree["q"].with_plan(bad_plan)
    })
    with pytest.raises(ValueError, match="ShardingPlan mismatch"):
        restore_pytree(path, bad)

    # a live mesh that lost the saved plan's axis is detected
    mesh1 = jax.make_mesh((1,), ("stage",))
    lost = WeightPlan("column", axis="stage", fsdp=None, mesh=mesh1)
    # rewrite the manifest as if saved from a {model}-axis mesh restoring
    # onto a {stage}-only mesh: axis names must survive re-mesh
    bad2 = jax.eval_shape(lambda: {
        "wq": tree["wq"].with_plan(lost), "q": tree["q"].with_plan(lost)
    })
    with pytest.raises(ValueError, match="ShardingPlan mismatch"):
        restore_pytree(path, bad2)

    # restoring into a plan-FREE target still works (plans are optional)
    plain = jax.eval_shape(lambda: {
        "wq": tree["wq"].with_plan(None), "q": tree["q"].with_plan(None)
    })
    got2 = restore_pytree(path, plain)
    assert got2["wq"].plan is None


def test_attach_params_stamps_declarative_roles():
    from repro.configs.base import ArchConfig
    from repro.models import transformer as tf_model

    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=128,
                     n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=512,
                     head_dim=64, matmul_backend="pallas_dip", sharding="tp",
                     remat="none")
    mesh = make_local_mesh(data=1, model=1)
    plan = make_plan(mesh, cfg, "train")
    specs = plan.attach_params(tf_model.param_specs(cfg))
    lyr = specs["layers"]
    assert lyr["wq"].plan.kind == "column"
    assert lyr["wo"].plan.kind == "row"
    assert lyr["w_gate"].plan.kind == "column"
    assert lyr["w_down"].plan.kind == "row"
    assert specs["lm_head"].plan.kind == "column"
    # shardings mirror the attached plans, so device_put zips in lockstep
    shards = plan.param_shardings(specs)
    assert shards["layers"]["wq"].plan == lyr["wq"].plan
    jax.tree_util.tree_structure(specs) == jax.tree_util.tree_structure(shards)


def test_plan_free_weight_decomposes_to_gspmd():
    r = np.random.default_rng(7)
    x = jnp.asarray(r.normal(0, 1, (4, 100)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)  # no plan
    for backend in ("dip_tp", "dip_fsdp", "dip_sp", "dip_ep"):
        got = api.matmul(x, dw, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   atol=2e-3, rtol=2e-3, err_msg=backend)
    # quantized plan-free weights keep their scheme kernel on decomposition
    qw = api.quant.quantize(w, "int8")
    got_q = api.matmul(x, qw, backend="dip_tp")
    np.testing.assert_array_equal(np.asarray(got_q), np.asarray(api.matmul(x, qw)))
    # a replicated plan decomposes too (nothing to shard over)
    rep = api.DipWeight.from_natural(w, plan=WeightPlan("replicated"))
    got_r = api.matmul(x, rep, backend="dip_tp")
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(x @ w),
                               atol=2e-3, rtol=2e-3)


def test_sharded_registration_rules():
    for name in ("dip_tp", "dip_fsdp", "dip_sp", "dip_ep"):
        assert api.backend_layout(name) == "sharded", name
        # sharded backends declare the full fused-epilogue AND -prologue sets
        assert set(api.backend_epilogues(name)) == set(api.EPILOGUES), name
        assert set(api.backend_prologues(name)) == set(api.PROLOGUES), name
    with pytest.raises(ValueError, match="tiled=False"):
        api.register_backend("bad_sharded", lambda *a, **k: None,
                             layout="sharded", tiled=True)


def test_weight_plan_validation_and_describe():
    with pytest.raises(ValueError, match="column | row | replicated"):
        WeightPlan("diagonal")
    mesh = make_local_mesh(data=1, model=1)
    p = WeightPlan("row", axis="model", fsdp="data", mesh=mesh)
    d = p.describe()
    assert d == {"kind": "row", "axis": "model", "fsdp": "data",
                 "mesh_axes": {"data": 1, "model": 1}}
    assert p.fsdp_size == 1 and p.tp_size == 1
    assert WeightPlan("row", axis="ghost", mesh=mesh).tp_size == 1  # absent axis
    assert WeightPlan("replicated").describe()["mesh_axes"] is None
    # value equality + hashability (jit static aux requirements)
    assert p == WeightPlan("row", axis="model", fsdp="data", mesh=mesh)
    assert hash(p) == hash(WeightPlan("row", axis="model", fsdp="data", mesh=mesh))


def test_sharded_dispatch_validates_inputs():
    mesh = _mesh11()
    col = _plan_col(mesh)
    w = jnp.ones((100, 130), jnp.float32)
    dw = api.DipWeight.from_natural(w, plan=col)
    with pytest.raises(ValueError, match="contraction"):
        api.matmul(jnp.ones((4, 96), jnp.float32), dw, backend="dip_tp")
    with pytest.raises(ValueError, match="2-D"):
        api.matmul(
            jnp.ones((4, 100), jnp.float32),
            api.DipWeight.from_natural(jnp.ones((2, 100, 130)), plan=col),
            backend="dip_tp",
        )
    # mixed plans on a swiglu pair are rejected
    other = WeightPlan("column", axis="model", fsdp=None, mesh=mesh)
    with pytest.raises(ValueError, match="share one WeightPlan"):
        api.matmul(
            jnp.ones((4, 100), jnp.float32),
            (dw, api.DipWeight.from_natural(w, plan=other)),
            backend="dip_tp", epilogue="swiglu",
        )
