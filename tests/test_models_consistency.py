"""Cross-path numerical consistency: decode==forward, chunked==dense,
DiP storage == natural storage, systolic == fast path, SSM chunk invariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import transformer as tf_model

KEY = jax.random.PRNGKey(7)


def _dense_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                remat="none", compute_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_prefill_then_decode_matches_full_forward():
    cfg = _dense_cfg()
    params = tf_model.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 21), 0, cfg.vocab_size)
    dstep = tf_model.decode_step_fn(cfg)
    cache = tf_model.init_cache(cfg, 2, 32)
    _, cache = dstep(params, cache, toks[:, :13])        # prefill 13
    l1, cache = dstep(params, cache, toks[:, 13:17])     # chunked prefill 4
    l2, cache = dstep(params, cache, toks[:, 17:21])     # 4 more
    full, _, _ = tf_model.forward(params, cfg, tokens=toks)
    np.testing.assert_allclose(
        np.asarray(l2), np.asarray(full[:, 17:21]), atol=3e-3, rtol=1e-3
    )
    assert int(cache["pos"]) == 21


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_attention_equals_dense(chunk):
    cfg = _dense_cfg()
    params = tf_model.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    dense, _, _ = tf_model.forward(params, cfg, tokens=toks)
    chunked, _, _ = tf_model.forward(params, cfg, tokens=toks, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), atol=2e-3)


# keys stored as api.DipWeight when cfg.uses_dip_storage (dense family)
_DIP_KEYS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "lm_head",
             "in_proj", "out_proj", "w_dkv", "w_krope", "w_uk", "w_uv",
             "shared_w_gate", "shared_w_up", "shared_w_down"}


def _to_dip_params(tree):
    from repro import api

    out = {}
    for k, v in tree.items():
        if isinstance(v, dict):
            out[k] = _to_dip_params(v)
        elif k in _DIP_KEYS and v.ndim >= 2:
            out[k] = api.DipWeight.from_natural(v)  # leading stack dims pass through
        else:
            out[k] = v
    return out


def test_dip_storage_equals_natural_storage():
    """DipWeight storage must be numerically identical to natural layout."""
    cfg_nat = _dense_cfg()
    cfg_dip = dataclasses.replace(cfg_nat, dip_weights=True)
    params_nat = tf_model.init_params(KEY, cfg_nat)
    params_dip = _to_dip_params(params_nat)

    toks = jax.random.randint(KEY, (2, 16), 0, cfg_nat.vocab_size)
    l_nat, _, _ = tf_model.forward(params_nat, cfg_nat, tokens=toks)
    l_dip, _, _ = tf_model.forward(params_dip, cfg_dip, tokens=toks)
    np.testing.assert_allclose(np.asarray(l_dip), np.asarray(l_nat), atol=2e-3)


def test_pallas_impl_equals_xla_impl():
    cfg_x = _dense_cfg(n_layers=1, vocab_size=128)
    cfg_p = dataclasses.replace(cfg_x, matmul_backend="pallas_dip")
    params = tf_model.init_params(KEY, cfg_x)
    params_p = _to_dip_params(params)
    toks = jax.random.randint(KEY, (1, 8), 0, 128)
    lx, _, _ = tf_model.forward(params, cfg_x, tokens=toks)
    lp, _, _ = tf_model.forward(params_p, cfg_p, tokens=toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx), atol=3e-3, rtol=1e-3)


def test_ssm_chunk_size_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    base = dict(name="s", family="ssm", n_layers=2, d_model=64, n_heads=0,
                n_kv_heads=0, d_ff=0, vocab_size=128, ssm_state=16,
                ssm_headdim=32, remat="none", compute_dtype="float32")
    toks = jax.random.randint(KEY, (2, 24), 0, 128)
    outs = []
    for chunk in (4, 8, 24):
        cfg = ArchConfig(**base, ssm_chunk=chunk)
        params = tf_model.init_params(KEY, cfg)
        lo, _, _ = tf_model.forward(params, cfg, tokens=toks)
        outs.append(np.asarray(lo))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(outs[0], outs[2], atol=2e-3, rtol=1e-3)


def test_microbatched_train_step_matches_full_batch():
    from repro.optim import AdamW

    cfg = _dense_cfg()
    params = tf_model.init_params(KEY, cfg)
    opt = AdamW(lr=1e-3)
    toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    s0 = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    full_step = jax.jit(tf_model.train_step_fn(cfg, opt))
    micro_step = jax.jit(tf_model.train_step_fn(cfg, opt, microbatch=2))
    s_full, m_full = full_step(s0, batch)
    s_micro, m_micro = micro_step(s0, batch)
    # same loss (mean over tokens) and near-identical parameter update
    assert abs(float(m_full["loss"]) - float(m_micro["loss"])) < 2e-3
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s_full["params"], s_micro["params"]
    )
    assert max(jax.tree_util.tree_leaves(diffs)) < 2e-3


def test_quantized_mla_matches_float_within_quant_error():
    """MLA's absorbed form contracts w_uk/w_uv per-head, so attention must
    de-shear (and dequantize) them before use — an int8 deepseek-family
    forward has to track the float forward within the rounding budget.
    Regression guard for the QuantizedDipWeight path in attention._natural
    (surfaced by the fleet sweep: deepseek_v2 x dip_int8w decode)."""
    from repro.configs import get_config

    cfg = dataclasses.replace(
        get_config("deepseek_v2_lite_16b").reduced(), compute_dtype="float32")
    params = tf_model.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    lf, _, _ = tf_model.forward(params, cfg, tokens=toks)

    qcfg = dataclasses.replace(cfg, quantization="int8",
                               matmul_backend="dip_int8w")
    qparams = tf_model.quantize_params(params, "int8")
    lq, _, _ = tf_model.forward(qparams, qcfg, tokens=toks)
    assert np.isfinite(np.asarray(lq)).all()
    # int8 rounding, not garbage: logits stay close and rank the same tokens
    err = np.abs(np.asarray(lq) - np.asarray(lf)).max()
    assert err < 0.5, f"quantized MLA diverged from float: max|dlogit|={err}"
    agree = (np.asarray(lq).argmax(-1) == np.asarray(lf).argmax(-1)).mean()
    assert agree > 0.9


def test_cross_entropy_masking_contract():
    """Regression (pre-PR bug): ``layers.cross_entropy_loss`` averaged over
    every position — padding included.  Now: a fully-valid batch still
    equals the historical unmasked mean EXACTLY, while ``mask`` and the
    -100 ``ignore_index`` exclude tokens from both the sum and the divisor."""
    from repro.models import layers

    r = np.random.default_rng(19)
    b, s, v = 2, 12, 32
    logits = jnp.asarray(r.normal(size=(b, s, v)).astype(np.float32))
    labels = jnp.asarray(r.integers(0, v, (b, s)).astype(np.int32))

    # 1. all-valid == the historical unmasked mean, bit for bit
    base = layers.cross_entropy_loss(logits, labels)
    np.testing.assert_array_equal(
        np.asarray(layers.cross_entropy_loss(
            logits, labels, mask=jnp.ones((b, s), jnp.int32))),
        np.asarray(base),
    )

    # 2. masked positions drop out of sum AND divisor: the masked loss over
    # the full batch == the unmasked loss over only the kept positions
    mask = jnp.asarray((r.random((b, s)) > 0.4).astype(np.int32))
    got = layers.cross_entropy_loss(logits, labels, mask=mask)
    keep = np.asarray(mask).astype(bool).reshape(-1)
    want = layers.cross_entropy_loss(
        logits.reshape(-1, v)[keep], labels.reshape(-1)[keep])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)

    # 3. ignore_index behaves exactly like mask==0 (and never gathers OOB)
    lab_ig = labels.at[0, :3].set(-100)
    m_eq = jnp.ones((b, s), jnp.int32).at[0, :3].set(0)
    np.testing.assert_allclose(
        np.asarray(layers.cross_entropy_loss(logits, lab_ig)),
        np.asarray(layers.cross_entropy_loss(logits, labels, mask=m_eq)),
        atol=1e-6, rtol=1e-6,
    )

    # 4. gradients at excluded positions are exactly zero
    g = jax.grad(lambda lg: layers.cross_entropy_loss(lg, labels, mask=mask))(logits)
    np.testing.assert_array_equal(
        np.asarray(g)[~np.asarray(mask).astype(bool)], 0.0)

    # 5. everything excluded: finite zero, not 0/0
    assert float(layers.cross_entropy_loss(
        logits, labels, mask=jnp.zeros((b, s), jnp.int32))) == 0.0


def test_loss_fn_fused_matches_unfused():
    """The fused lm_head+CE path (``fused_ce=True``) must match the unfused
    logits path in loss AND gradients, with and without a loss_mask —
    including through DiP weight storage (the natural-head extraction)."""
    for dip in (False, True):
        cfg = _dense_cfg(**({"dip_weights": True} if dip else {}))
        params = tf_model.init_params(KEY, cfg)  # DipWeight storage when dip
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        mask = (jax.random.uniform(KEY, (2, 16)) > 0.3).astype(jnp.int32)
        for batch in ({"tokens": toks, "labels": toks},
                      {"tokens": toks, "labels": toks, "loss_mask": mask}):
            lf, gf = jax.value_and_grad(
                lambda p: tf_model.loss_fn(p, cfg, batch, fused_ce=True))(params)
            lu, gu = jax.value_and_grad(
                lambda p: tf_model.loss_fn(p, cfg, batch, fused_ce=False))(params)
            np.testing.assert_allclose(float(lf), float(lu), atol=2e-5, rtol=2e-5)
            for a, b in zip(jax.tree_util.tree_leaves(gf),
                            jax.tree_util.tree_leaves(gu)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)


def test_flash_prefill_matches_full_forward():
    """decode_step_fn(attn_backend='flash') — the serving chunked-prefill
    route through the attention registry — must match the dense forward."""
    cfg = _dense_cfg()
    params = tf_model.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 21), 0, cfg.vocab_size)
    dstep = tf_model.decode_step_fn(cfg, attn_backend="flash")
    cache = tf_model.init_cache(cfg, 2, 32)
    _, cache = dstep(params, cache, toks[:, :13])
    l1, cache = dstep(params, cache, toks[:, 13:17])
    l2, cache = dstep(params, cache, toks[:, 17:21])
    full, _, _ = tf_model.forward(params, cfg, tokens=toks)
    np.testing.assert_allclose(
        np.asarray(l2), np.asarray(full[:, 17:21]), atol=3e-3, rtol=1e-3)
    assert int(cache["pos"]) == 21
