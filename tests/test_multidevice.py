"""Multi-device behaviours, each in a subprocess with forced host devices
(the shared helper lives in conftest — the pytest process must NOT set
XLA_FLAGS so smoke tests see the real topology).

Covers: pipeline-parallel equivalence (bit-match + overlap schedule),
pipelined train step vs flat, compressed psum, sharded train step on
a small (2,2) mesh, plan PartitionSpec validity for every arch, divisibility
fallback surfacing (warn-once / strict), and a reduced-config
production-mesh dry-run (the CI-sized version of deliverable e).
"""

import pytest

from conftest import run_forced_devices as _run


def test_pipeline_parallel_equals_sequential():
    """Bit-match, not allclose: every stage applies stage_fn exactly once
    per microbatch to exactly the upstream activation, so the overlapped
    schedule must be numerically invisible.  The tick body's jaxpr must
    open with the ppermute (transfer issued BEFORE the stage compute —
    the overlap contract)."""
    out = _run("""
from repro.distributed.pipeline import pipeline_apply
from repro.kernels.dip_matmul_sharded import collective_schedule, count_collectives
mesh = jax.make_mesh((4,), ("stage",))
n_stages, n_micro, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (n_stages, d, d)) * 0.3,
          "b": jax.random.normal(key, (n_stages, d)) * 0.1}
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])
x = jax.random.normal(key, (n_micro, mb, d))
got = jax.jit(lambda p, xs: pipeline_apply(mesh, stage_fn, p, xs))(params, x)
ref = x
for s in range(n_stages):
    ref = stage_fn({"w": params["w"][s], "b": params["b"][s]}, ref)
assert np.array_equal(np.asarray(got), np.asarray(ref)), (
    float(np.abs(np.asarray(got) - np.asarray(ref)).max()))
apply = lambda p, xs: pipeline_apply(mesh, stage_fn, p, xs)
sched = collective_schedule(apply, params, x)
assert sched[0] == "ppermute", sched      # transfer leads the tick body
cnt = count_collectives(apply, params, x)
assert cnt["ppermute"] == 1 and cnt["psum"] == 1, cnt  # scan body + broadcast
assert cnt["all_gather"] == 0 and cnt["all_to_all"] == 0, cnt
print("PIPELINE_OK")
""")
    assert "PIPELINE_OK" in out


def test_pipelined_train_step_matches_flat():
    """plan.stages > 1 swaps the trainer's step for the pipelined one; its
    loss must equal the flat train step exactly and its updated params must
    match to accumulation tolerance (scan-of-scan vs flat scan)."""
    out = _run("""
from repro.configs.base import ArchConfig
from repro.distributed import make_local_mesh, make_plan, pipeline_train_step_fn
from repro.models import transformer as tf_model
from repro.optim import AdamW

mesh = make_local_mesh(1, 1, stage=4)
cfg = ArchConfig(name="pp_t", family="dense", n_layers=4, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
                 remat="none", compute_dtype="float32",
                 matmul_backend="pallas_dip", sharding="pp")
plan = make_plan(mesh, cfg, "train")
assert plan.stages == 4 and plan.stage == "stage"
assert plan.explicit_backend is None  # stages run the config's backend

opt = AdamW(lr=1e-3)
params = tf_model.init_params(jax.random.PRNGKey(2), cfg)
state = {"params": params, "opt_state": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
tok = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, cfg.vocab_size)
batch = {"tokens": tok, "labels": tok}

pstep = jax.jit(pipeline_train_step_fn(cfg, opt, plan, n_micro=4))
fstep = jax.jit(tf_model.train_step_fn(cfg, opt, fused_ce=False))
s1, m1 = pstep(state, batch)
s2, m2 = fstep(state, batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]), rtol=1e-4)
for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                jax.tree_util.tree_leaves(s2["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=1e-4)
s1b, m1b = pstep(s1, batch)   # state threads through a second step
assert np.isfinite(float(m1b["loss"]))
print("PP_TRAIN_OK")
""")
    assert "PP_TRAIN_OK" in out


def test_compressed_psum_shard_map():
    out = _run("""
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum
mesh = jax.make_mesh((4,), ("data",))
x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) / 7.0
f = shard_map(lambda v: compressed_psum(v, "data"), mesh=mesh,
              in_specs=P("data", None), out_specs=P("data", None))
got = f(x)
want = jnp.broadcast_to(x.mean(0), (1, 4))  # mean over the axis
np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=0.02)
print("PSUM_OK")
""")
    assert "PSUM_OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ArchConfig
from repro.models import transformer as tf_model
from repro.optim import AdamW
from repro.distributed.plan import make_plan

cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
                 remat="none", compute_dtype="float32")
key = jax.random.PRNGKey(0)
params = tf_model.init_params(key, cfg)
toks = jax.random.randint(key, (4, 16), 0, 256)
batch = {"tokens": toks, "labels": toks}
opt = AdamW(lr=1e-3)
state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}

# single-device reference
ref_step = jax.jit(tf_model.train_step_fn(cfg, opt))
sref, mref = ref_step(state, batch)

# sharded on a (2, 2) data x model mesh, threaded as a first-class plan
mesh = jax.make_mesh((2, 2), ("data", "model"))
plan = make_plan(mesh, cfg, "train")
pshard = plan.param_shardings(tf_model.param_template(cfg))
with mesh:
    params_s = jax.tree_util.tree_map(jax.device_put, params, pshard)
    state_s = {"params": params_s, "opt_state": opt.init(params_s),
               "step": jnp.zeros((), jnp.int32)}
    batch_s = jax.device_put(batch, NamedSharding(mesh, P(("data",), None)))
    step_s = jax.jit(tf_model.train_step_fn(cfg, opt, plan=plan))
    ss, ms = step_s(state_s, batch_s)
assert abs(float(mref["loss"]) - float(ms["loss"])) < 1e-4, (float(mref["loss"]), float(ms["loss"]))
d = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           sref["params"], jax.device_get(ss["params"]))
assert max(jax.tree_util.tree_leaves(d)) < 1e-4, max(jax.tree_util.tree_leaves(d))
print("SHARDED_TRAIN_OK")
""", devices=4)
    assert "SHARDED_TRAIN_OK" in out


def test_plan_pspecs_valid_for_all_archs():
    out = _run("""
from repro.configs import ALL_ARCHS, get_config
from repro.distributed.plan import make_plan
from repro.models.transformer import param_template
mesh = jax.make_mesh((2, 2), ("data", "model"))
for arch in ALL_ARCHS:
    cfg = get_config(arch)
    for mode in ("train", "decode"):
        plan = make_plan(mesh, cfg, mode)
        shards = plan.param_shardings(param_template(cfg))   # raises if invalid
        n = len(jax.tree_util.tree_leaves(shards))
        assert n > 5
print("PLAN_OK")
""")
    assert "PLAN_OK" in out


def test_divisibility_fallback_warns_once_and_strict_raises():
    """Satellite bugfix: the old policy silently replicated mis-sized leaves.
    The plan warns once (with the leaf name and axis sizes) and raises under
    strict=True."""
    out = _run("""
import warnings
from repro.configs.base import ArchConfig
from repro.distributed.plan import make_plan

# d_ff=70 does not divide the 4-wide model axis -> w_gate/w_up fall back
cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=4, d_ff=70, vocab_size=256, head_dim=16)
mesh = jax.make_mesh((1, 4), ("data", "model"))
plan = make_plan(mesh, cfg, "train")
with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    spec = plan.param_pspec("w_gate", (2, 64, 70))
    plan.param_pspec("w_gate", (2, 64, 70))  # second call: warn-once
assert spec[-1] is None  # replicated, as before — but no longer silently
msgs = [str(w.message) for w in caught if "ShardingPlan" in str(w.message)]
assert len(msgs) == 1, msgs
assert "w_gate" in msgs[0] and "70" in msgs[0] and "model" in msgs[0], msgs[0]

strict = make_plan(mesh, cfg, "train", strict=True)
try:
    strict.param_pspec("w_up", (2, 64, 70))
except ValueError as e:
    assert "w_up" in str(e) and "strict" in str(e)
else:
    raise AssertionError("strict plan did not raise on a mis-sized leaf")
print("FALLBACK_OK")
""")
    assert "FALLBACK_OK" in out


@pytest.mark.slow
def test_reduced_production_dryrun():
    """CI-sized dry-run: a reduced config against the real 512-device
    multi-pod mesh — proves the launch stack end to end."""
    out = _run("""
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.distributed.plan import make_plan, make_production_mesh
from repro.launch.specs import input_specs

cfg = get_config("llama3-8b").reduced(d_model=256, n_heads=16, n_kv_heads=16,
                                      head_dim=64, vocab_size=4096, n_layers=2)
cell = ShapeCell("train_tiny", 512, 32, "train")
mesh = make_production_mesh(multi_pod=True)
plan = make_plan(mesh, cfg, "train")
fn, args = input_specs(cfg, cell, plan)
with mesh:
    compiled = jax.jit(fn, donate_argnums=(0,)).lower(*args).compile()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca
assert ca.get("flops", 0) > 0
print("DRYRUN_OK", int(ca["flops"]))
""", devices=512, timeout=900)
    assert "DRYRUN_OK" in out
