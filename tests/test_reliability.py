"""The reliability layer's contracts (repro.reliability; docs/reliability.md).

Five load-bearing properties:

1. **No observer effect** — ``api.matmul(..., verify=True)`` returns output
   *bit-identical* to the unverified call across backend x epilogue x dtype
   (verification is a post-hoc audit, never a different computation), and a
   clean dispatch never false-positives.
2. **Detection** — a seeded bit flip / planted NaN in weight storage trips
   the probe (float) or the integer-exact storage compare (quantized);
   injection itself is deterministic (same seed => same corruption).
3. **Fail-safe training** — a corrupted ``DipWeight`` mid-run is detected by
   the fingerprint guard, the poisoned update is skipped, counters
   increment, and the trainer restores the latest checkpoint.
4. **Fail-safe serving** — a poisoned KV block surfaces as a nonfinite
   logits row; the request is retried (re-prefill on clean blocks) or
   degraded to the ``xla`` decode path while batch-mates keep streaming.
5. **Integrity under crashes** — the block allocator holds its invariants
   when alloc/free raise mid-operation (fail-points), and a checkpoint save
   crashed mid-write never corrupts the latest restorable step; storage rot
   is caught by per-leaf CRCs that name the corrupt leaf.
"""

import os

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

import jax
import jax.numpy as jnp

from repro import api
from repro import reliability as rel
from repro.checkpoint.manager import (
    CheckpointManager, restore_pytree, save_pytree,
)
from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.models import transformer as tf_model
from repro.reliability.inject import InjectedFault, failpoint
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving import BlockAllocator, Engine, EngineConfig, SamplingParams


def _weight_for(backend, w):
    be = api.get_backend(backend)
    if be.layout == "dip_q":
        return api.quant.quantize(jnp.asarray(w, jnp.float32), be.scheme)
    if be.layout == "dip":
        return api.DipWeight.from_natural(jnp.asarray(w))
    return jnp.asarray(w)


# ----------------------------------------------------------- no observer ----
VERIFY_MATRIX = [
    # backend, epilogue, dtype — one cell per backend family x epilogue
    # class x coarse dtype; bit-identity is the acceptance criterion
    ("xla", "none", "float32"),
    ("xla", "bias", "float32"),
    ("ws", "none", "bfloat16"),
    ("ws", "swiglu", "float32"),
    ("pallas_dip", "none", "float32"),
    ("pallas_dip", "bias", "bfloat16"),
    ("pallas_systolic", "residual", "float32"),
    ("dip_int8w", "none", "float32"),
    ("dip_int8w", "bias_gelu", "bfloat16"),
    ("dip_fp8", "none", "float32"),
]


def _inputs(backend, epilogue, dtype, m=16, k=64, n=64, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32)).astype(dtype)
    wg = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    wu = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    if epilogue == "swiglu":
        wobj = (_weight_for(backend, wg), _weight_for(backend, wu))
        ops = ()
    elif epilogue.startswith("bias"):
        wobj = _weight_for(backend, wg)
        ops = (jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32)),)
    elif epilogue == "residual":
        wobj = _weight_for(backend, wg)
        ops = (jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32)),)
    else:
        wobj = _weight_for(backend, wg)
        ops = ()
    wobj = rel.attach_checksums(wobj)
    return x, wobj, ops


@pytest.mark.parametrize("backend,epilogue,dtype", VERIFY_MATRIX)
def test_verified_is_bit_identical_and_clean(backend, epilogue, dtype):
    """With injection disabled: verify=True output == unverified output
    bit-for-bit, and the audit reports ok on every rung it picked."""
    x, wobj, ops = _inputs(backend, epilogue, dtype)
    plain = api.matmul(x, wobj, backend=backend, epilogue=epilogue,
                       epilogue_operands=ops)
    out, report = api.matmul(x, wobj, backend=backend, epilogue=epilogue,
                             epilogue_operands=ops, verify=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(out))
    assert bool(report["ok"]), (backend, epilogue, dtype, report)
    assert report["mode"] in ("probe", "storage")
    rel.raise_on_fault(report)  # must not raise on a clean report


def test_probe_mode_selection():
    """auto => probe exactly where the row-sum identity holds; an explicit
    probe request elsewhere is a caller error."""
    x, wobj, _ = _inputs("pallas_dip", "none", "float32")
    _, rep = api.matmul(x, wobj, backend="pallas_dip", verify=True)
    assert rep["mode"] == "probe"
    xs, wsw, _ = _inputs("pallas_dip", "swiglu", "float32")
    _, rep = api.matmul(xs, wsw, backend="pallas_dip", epilogue="swiglu",
                        verify=True)
    assert rep["mode"] == "storage"  # nonlinear epilogue: probe invalid
    with pytest.raises(ValueError, match="probe verification is invalid"):
        api.matmul(xs, wsw, backend="pallas_dip", epilogue="swiglu",
                   verify="probe")


# -------------------------------------------------------------- detection ---
def test_probe_detects_weight_bitflip():
    x, dw, _ = _inputs("pallas_systolic", "none", "float32")
    bad = rel.bitflip(dw.data, seed=3, bit=30)     # exponent bit: loud
    dwc = dw.with_data(bad, checksum=dw.checksum)  # stale checksum = reference
    out, rep = api.matmul(x, dwc, backend="pallas_systolic", verify=True)
    assert not bool(rep["ok"])
    assert int(rep["rows_flagged"]) > 0
    with pytest.raises(rel.ReliabilityError, match="ABFT verification failed"):
        rel.raise_on_fault(rep)


def test_storage_compare_detects_quant_code_flip():
    """A single int8 code flip is far below the analog probe tolerance —
    the integer-exact storage compare is what catches it."""
    x, qw, _ = _inputs("dip_int8w", "none", "float32")
    bad = rel.bitflip(qw.data, seed=5, bit=6)
    qc = qw.with_data(bad, qw.scale, checksum=qw.checksum)
    _, rep = api.matmul(x, qc, backend="dip_int8w", verify="storage")
    assert not bool(rep["ok"])
    _, rep_auto = api.matmul(x, qc, backend="dip_int8w", verify=True)
    assert not bool(rep_auto["ok"])  # probe folds the storage compare in


def test_planted_nan_output_flagged():
    x, w, _ = _inputs("xla", "none", "float32")
    xn = rel.plant_nan(x, seed=0)
    out, rep = api.matmul(xn, w, backend="xla", verify=True)
    assert not bool(rep["finite"]) and not bool(rep["ok"])


@settings(max_examples=10)
@given(seed=st.integers(0, 2**16), bit=st.integers(0, 31))
def test_injection_is_deterministic(seed, bit):
    arr = jnp.asarray(np.random.default_rng(0).normal(size=(32, 16)),
                      jnp.float32)
    a = np.asarray(rel.bitflip(arr, seed=seed, bit=bit))
    b = np.asarray(rel.bitflip(arr, seed=seed, bit=bit))
    np.testing.assert_array_equal(a, b)
    assert (a != np.asarray(arr)).sum() == 1  # exactly one element touched
    n1 = np.asarray(rel.plant_nan(arr, seed=seed))
    n2 = np.asarray(rel.plant_nan(arr, seed=seed))
    np.testing.assert_array_equal(n1, n2)
    assert np.isnan(n1).sum() == 1


def test_corrupt_pytree_targets_by_path():
    tree = {"layers": {"q": jnp.ones((4, 4)), "k": jnp.ones((4, 4))}}
    new, hit = rel.corrupt_pytree(tree, "k", seed=0, mode="nan")
    assert "k" in hit and np.isnan(np.asarray(new["layers"]["k"])).any()
    np.testing.assert_array_equal(np.asarray(new["layers"]["q"]),
                                  np.asarray(tree["layers"]["q"]))
    with pytest.raises(KeyError):
        rel.corrupt_pytree(tree, "nonexistent", seed=0)


# ---------------------------------------------------------- fail-points -----
@settings(max_examples=15)
@given(num_blocks=st.integers(4, 24), seed=st.integers(0, 10_000),
       fail_at=st.integers(1, 6))
def test_allocator_invariants_under_injected_failures(num_blocks, seed, fail_at):
    """Random alloc/free interleavings with alloc/free raising at an
    injected point: the free/allocated partition of blocks 1..nb-1 must
    survive every crash (no leak, no double-ownership)."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(num_blocks)
    held = []

    def check():
        free = set(alloc._free)
        used = set(alloc._allocated)
        assert not (free & used)
        assert free | used == set(range(1, num_blocks))
        assert BlockAllocator.NULL_BLOCK not in free | used
        held_flat = {b for blocks in held for b in blocks}
        assert held_flat == used

    name = "kv.alloc" if rng.integers(2) else "kv.free"
    with failpoint(name, exc=InjectedFault("chaos"), count=int(fail_at)):
        for _ in range(30):
            try:
                if rng.integers(2) and alloc.num_free:
                    got = alloc.alloc(int(rng.integers(1, alloc.num_free + 1)))
                    if got is not None:
                        held.append(got)
                elif held:
                    i = int(rng.integers(len(held)))
                    alloc.free(held[i])  # atomic: raises => still ours
                    held.pop(i)
            except InjectedFault:
                pass
            check()


def test_checkpoint_crc_names_corrupt_leaf(tmp_path):
    tree = {"a": jnp.arange(16, dtype=jnp.float32),
            "b": jnp.ones((4, 4), jnp.bfloat16)}
    path = str(tmp_path / "ck")
    save_pytree(path, tree)
    # rot one byte of leaf b's payload on disk
    victim = None
    import json
    with open(os.path.join(path, "manifest.json")) as f:
        for e in json.load(f)["leaves"]:
            if "b" in e["path"]:
                victim = os.path.join(path, e["file"])
    blob = bytearray(open(victim, "rb").read())
    blob[-1] ^= 0xFF
    open(victim, "wb").write(bytes(blob))
    with pytest.raises(ValueError, match="integrity failure at leaf .*b"):
        restore_pytree(path, jax.eval_shape(lambda: tree))
    # the untouched checkpoint still restores
    save_pytree(str(tmp_path / "ck2"), tree)
    restore_pytree(str(tmp_path / "ck2"), jax.eval_shape(lambda: tree))


def test_checkpoint_mid_save_crash_is_atomic(tmp_path):
    """A save killed between leaf writes (or before the rename) leaves the
    previous step fully restorable and only a GC-able tmp orphan behind."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32),
            "b": jnp.zeros((3,), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, tree, blocking=True)

    for name in ("checkpoint.save.mid_write", "checkpoint.save.pre_rename"):
        with failpoint(name, exc=InjectedFault(name)):
            with pytest.raises(InjectedFault):
                save_pytree(mgr._step_path(2), tree)
        assert mgr.latest_step() == 1, name
    restored, _ = mgr.restore(jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    # a fresh manager garbage-collects the orphaned tmp dirs
    CheckpointManager(str(tmp_path), keep=5)
    assert not [n for n in os.listdir(tmp_path) if ".tmp-" in n]


# ------------------------------------------------------- fail-safe train ----
def _tiny_cfg():
    return ArchConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=16, remat="none",
        compute_dtype="float32",
    )


def test_train_guard_detects_flip_skips_and_recovers(tmp_path):
    """Acceptance chaos test (a): a seeded bit flip planted in a parameter
    mid-run is detected, the poisoned step is skipped, counters increment,
    and training recovers from the latest checkpoint and completes."""
    fault_step = 5

    def hook(step_no, state):
        if step_no == fault_step:
            params, hit = rel.corrupt_pytree(
                state["params"], "layers", seed=7, mode="nan"
            )
            state = dict(state, params=params)
            hook.hit = hit
        return state

    tr = Trainer(
        _tiny_cfg(),
        TrainerConfig(steps=8, ckpt_every=2, ckpt_dir=str(tmp_path),
                      keep=5, async_ckpt=False, log_every=100, guard=True),
        seq_len=32, global_batch=4, step_hook=hook,
    )
    out = tr.run()
    assert out["weight_faults"] >= 1
    assert out["skipped"] >= 1
    assert out["recoveries"] >= 1
    assert int(out["state"]["step"]) == 8
    # post-recovery params are finite — the NaN never entered committed state
    for leaf in jax.tree_util.tree_leaves(out["state"]["params"]):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    skipped_metrics = [m for m in out["metrics"] if m.get("skipped")]
    assert skipped_metrics and skipped_metrics[0]["weight_fault"] == 1.0


def test_train_guard_without_checkpoint_raises(tmp_path):
    """recover_on_fault with no checkpoint on disk: the guard refuses to
    continue on corrupt weights and names the fault."""
    def hook(step_no, state):
        if step_no == 1:
            params, _ = rel.corrupt_pytree(state["params"], "layers",
                                           seed=1, mode="nan")
            state = dict(state, params=params)
        return state

    tr = Trainer(
        _tiny_cfg(),
        TrainerConfig(steps=4, ckpt_every=100, ckpt_dir=str(tmp_path),
                      async_ckpt=False, log_every=100, guard=True),
        seq_len=32, global_batch=4, step_hook=hook,
    )
    with pytest.raises(rel.ReliabilityError, match="weight corruption"):
        tr.run()


def test_guard_clean_run_matches_unguarded(tmp_path):
    """With no fault injected the guard must not change training: losses of
    guarded and unguarded runs are identical step for step."""
    def train(guard, sub):
        return Trainer(
            _tiny_cfg(),
            TrainerConfig(steps=4, ckpt_every=100,
                          ckpt_dir=str(tmp_path / sub), async_ckpt=False,
                          log_every=100, guard=guard),
            seq_len=32, global_batch=4,
        ).run()

    a, b = train(False, "a"), train(True, "b")
    for ma, mb in zip(a["metrics"], b["metrics"]):
        assert ma["loss"] == mb["loss"]
    assert b["skipped"] == 0 and b["weight_faults"] == 0


# ------------------------------------------------------- fail-safe serve ----
def _engine(verify=True, max_retries=1, slots=2, **kw):
    cfg = get_config("llama3_8b").reduced()
    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(slots=slots, max_seq=96, prefill_chunk=32,
                        verify=verify, max_retries=max_retries, **kw)
    return Engine(cfg, params, engine_cfg=ecfg, seed=0), cfg


def _run_with_kv_fault(eng, r_victim, ticks=4):
    for _ in range(ticks):
        eng.step()
    req = next(r for r in eng._slots if r is not None and r.rid == r_victim)
    blk = eng.kv.owned[req.slot][0]
    rel.corrupt_kv_block(eng.kv, blk, mode="nan")
    return eng.run()


def test_serve_kv_corruption_retried_peers_served():
    """Acceptance chaos test (b): NaN-poisoned KV block mid-decode is
    detected, the victim is retried on clean blocks and completes, the
    batch-mate streams through untouched."""
    eng, _ = _engine(verify=True, max_retries=1)
    r0 = eng.add_request(np.arange(2, 20, dtype=np.int32),
                         SamplingParams(max_new_tokens=8))
    r1 = eng.add_request(np.arange(5, 30, dtype=np.int32),
                         SamplingParams(max_new_tokens=8))
    out = _run_with_kv_fault(eng, r0)
    assert len(out[r0]) == 8 and len(out[r1]) == 8
    assert eng.last_stats["faults_detected"] == 1
    assert eng.last_stats["retries"] == 1
    assert eng.request_stats[r0]["retries"] == 1
    assert not eng.request_stats[r0]["degraded"]
    assert eng.request_stats[r1]["retries"] == 0

    # peer's tokens are bit-identical to a clean solo run (greedy)
    solo, _ = _engine(verify=True)
    rs = solo.add_request(np.arange(5, 30, dtype=np.int32),
                          SamplingParams(max_new_tokens=8))
    assert solo.run()[rs] == out[r1]


def test_serve_exhausted_retries_degrade_to_xla():
    """max_retries=0: the first fault degrades the request to the xla
    decode path; it still completes, flagged degraded, engine healthy."""
    eng, _ = _engine(verify=True, max_retries=0)
    r0 = eng.add_request(np.arange(2, 20, dtype=np.int32),
                         SamplingParams(max_new_tokens=6))
    r1 = eng.add_request(np.arange(5, 30, dtype=np.int32),
                         SamplingParams(max_new_tokens=6))
    out = _run_with_kv_fault(eng, r0, ticks=2)
    assert len(out[r0]) == 6 and len(out[r1]) == 6
    assert eng.last_stats["degraded_requests"] == 1
    assert eng.request_stats[r0]["degraded"]
    assert eng._decode_xla is not None  # the fallback path was compiled


def test_serve_verify_off_is_undisturbed():
    """verify=False: zero reliability overhead paths run; stats stay 0."""
    eng, _ = _engine(verify=False)
    r0 = eng.add_request(np.arange(2, 20, dtype=np.int32),
                         SamplingParams(max_new_tokens=4))
    out = eng.run()
    assert len(out[r0]) == 4
    assert eng.last_stats["faults_detected"] == 0
    assert eng._decode_xla is None


def test_deadline_ttl_sweeps_waiting_request():
    eng, _ = _engine(verify=False, slots=1)
    r0 = eng.add_request(np.arange(2, 20, dtype=np.int32),
                         SamplingParams(max_new_tokens=6))
    # r1 can never be admitted before its deadline (one slot, ttl ~ 0)
    r1 = eng.add_request(np.arange(5, 30, dtype=np.int32),
                         SamplingParams(max_new_tokens=6), ttl_s=0.0)
    out = eng.run()
    assert len(out[r0]) == 6
    assert out[r1] == []
    assert eng.last_stats["deadline_evictions"] == 1
    assert eng.request_stats[r1]["deadline_expired"]
    assert not eng.request_stats[r0]["deadline_expired"]


def test_admission_capacity_fail_fast():
    """Regression: a prompt whose KV need exceeds the whole pool used to
    sit at the queue head forever and spin run(); now it fails at intake."""
    cfg = get_config("llama3_8b").reduced()
    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(slots=2, max_seq=96, prefill_chunk=32, num_blocks=3)
    eng = Engine(cfg, params, engine_cfg=ecfg, seed=0)
    with pytest.raises(ValueError, match="can never be admitted"):
        eng.add_request(np.arange(2, 90, dtype=np.int32),
                        SamplingParams(max_new_tokens=4))
    # a prompt that fits is unaffected
    rid = eng.add_request(np.arange(2, 20, dtype=np.int32),
                          SamplingParams(max_new_tokens=2))
    assert len(eng.run()[rid]) == 2


# ----------------------------------------------------------- guard unit -----
def test_guarded_step_fn_skip_semantics():
    """Unit-level: nonfinite loss => params/opt unchanged, step advances,
    counters increment; healthy step commits normally."""
    def fake_step(state, batch):
        new = {
            "params": jax.tree_util.tree_map(lambda p: p + 1.0, state["params"]),
            "opt_state": state["opt_state"],
            "step": state["step"] + 1,
        }
        return new, {"loss": batch["loss"], "grad_norm": jnp.float32(1.0),
                     "step": new["step"]}

    g = rel.guarded_step_fn(fake_step)
    state = rel.init_guard_state({
        "params": {"w": jnp.zeros((2,))},
        "opt_state": {"m": jnp.zeros((2,))},
        "step": jnp.zeros((), jnp.int32),
    })
    state, m = g(state, {"loss": jnp.float32(1.0)})
    assert float(state["params"]["w"][0]) == 1.0 and int(state["step"]) == 1
    state, m = g(state, {"loss": jnp.float32(np.nan)})
    assert float(state["params"]["w"][0]) == 1.0   # poisoned update dropped
    assert int(state["step"]) == 2                 # step always advances
    assert int(state["skipped"]) == 1 and float(m["skipped"]) == 1.0
    state, m = g(state, {"loss": jnp.float32(0.5)})
    assert float(state["params"]["w"][0]) == 2.0
    assert int(state["skipped"]) == 1
