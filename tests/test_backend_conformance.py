"""Cross-backend conformance harness: the acceptance bar for new backends.

Every registered matmul backend x supported operand dtype must

  * match its pure-jnp oracle in ``kernels/ref.py`` on *aligned and ragged*
    shapes (property-generated through ``tests/_hypothesis_shim`` — real
    hypothesis when installed, the deterministic fallback otherwise), within
    the per-dtype tolerances documented in ``docs/quantization.md``;
  * honour autodiff where the backend is differentiable (activation grads
    everywhere, weight grads on the float backends — quantized storage is a
    frozen artifact, its cotangent is zero by design);
  * keep the pytree / jit / scan invariants for BOTH weight types
    (``DipWeight`` and ``QuantizedDipWeight``).

A backend that cannot pass this file must not be registered as a builtin.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_shim import given, settings, st

from repro import api
from repro.kernels import ref

# ---------------------------------------------------------------------------
# the conformance matrix: backend -> operand (activation) dtypes it supports.
# xla omits int8 deliberately: a bare jnp.matmul accumulates int8 in int8
# (overflow) — integer workloads go through the tiled kernels.
CONFORMANCE = {
    "xla": ("float32", "bfloat16"),
    "ws": ("float32", "bfloat16", "int8"),
    "pallas_dip": ("float32", "bfloat16", "int8"),
    "pallas_systolic": ("float32", "int8"),
    "dip_int8w": ("float32", "bfloat16"),
    "dip_fp8": ("float32", "bfloat16"),
}

# parity tolerance vs the oracle, keyed on activation dtype.  The quantized
# backends compare against their *quantized* oracles, where the integer
# arithmetic is exact and only f32 epilogue rounding differs.
TOL = {
    "float32": dict(atol=2e-3, rtol=2e-3),
    "bfloat16": dict(atol=0.5, rtol=0.05),
    "int8": dict(atol=0, rtol=0),
}

# shape pools mix tile-aligned and ragged (non-multiple-of-64) dims; kept
# small so interpret-mode jit caches hit across drawn examples.
MS = (1, 8, 17, 64)
KS = (64, 100, 128)
NS = (64, 127, 130, 192)


def _operands(m, k, n, dtype, seed):
    r = np.random.default_rng(seed)
    if dtype == "int8":
        x = r.integers(-20, 21, (m, k)).astype(np.int8)
        w = r.integers(-20, 21, (k, n)).astype(np.int8)
        return jnp.asarray(x), jnp.asarray(w)
    x = r.normal(0, 1, (m, k)).astype(np.float32)
    w = r.normal(0, 1, (k, n)).astype(np.float32)
    return jnp.asarray(x).astype(dtype), jnp.asarray(w).astype(dtype)


def _weight_for(backend, w):
    """The weight object a call site would hold for this backend."""
    be = api.get_backend(backend)
    if be.layout == "dip_q":
        return api.quant.quantize(w.astype(jnp.float32), be.scheme)
    if be.layout == "dip":
        return api.DipWeight.from_natural(w)
    return w


def _oracle(backend, x, wobj, w):
    """kernels/ref.py oracle for one dispatch, cropped to the logical shape."""
    be = api.get_backend(backend)
    if be.layout == "natural":
        return ref.ws_matmul_ref(x, w)
    n = wobj.d_out
    xk = jnp.pad(x, [(0, 0), (0, (-x.shape[-1]) % wobj.perm_tile)])
    if be.layout == "dip":
        return ref.dip_matmul_ref(xk, wobj.data, perm_tile=wobj.perm_tile)[..., :n]
    if be.scheme == "int8":
        return ref.dip_matmul_int8w_ref(
            xk, wobj.data, wobj.scale, perm_tile=wobj.perm_tile
        )[..., :n]
    return ref.dip_matmul_fp8_ref(
        xk, wobj.data, wobj.scale, perm_tile=wobj.perm_tile
    )[..., :n]


def test_matrix_covers_every_builtin_backend():
    missing = set(CONFORMANCE) - set(api.list_backends())
    assert not missing, f"matrix names unregistered backends: {missing}"
    builtin = {"xla", "ws", "pallas_dip", "pallas_systolic", "dip_int8w", "dip_fp8"}
    assert builtin <= set(CONFORMANCE), "a builtin backend escaped conformance"


def test_epilogue_capability_flags():
    """Every tiled builtin fuses the full epilogue set in-kernel; xla (and
    any non-tiled backend) declares none and relies on decomposition."""
    for backend in CONFORMANCE:
        be = api.get_backend(backend)
        if be.tiled:
            assert set(api.backend_epilogues(backend)) == set(api.EPILOGUES), backend
        else:
            assert api.backend_epilogues(backend) == ["none"], backend


# ----------------------------------------------------------------- parity ---
@pytest.mark.parametrize(
    "backend,dtype",
    [(b, d) for b, dts in CONFORMANCE.items() for d in dts],
)
@settings(max_examples=5)
@given(
    m=st.sampled_from(MS),
    k=st.sampled_from(KS),
    n=st.sampled_from(NS),
    seed=st.integers(0, 2**16),
)
def test_backend_matches_oracle(backend, dtype, m, k, n, seed):
    x, w = _operands(m, k, n, dtype, seed)
    wobj = _weight_for(backend, w)
    got = api.matmul(x, wobj, backend=backend)
    want = _oracle(backend, x, wobj, w)
    assert got.shape == (m, n)
    if api.get_backend(backend).layout == "dip_q":
        # kernel vs quantized oracle: integer/f32 arithmetic is exact, only
        # epilogue rounding (and the output-dtype cast) differs
        tol = (
            dict(atol=1e-3, rtol=1e-3) if dtype == "float32"
            else dict(atol=0.1, rtol=0.02)
        )
    else:
        tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol,
        err_msg=f"{backend}/{dtype} {m}x{k}x{n} seed={seed}",
    )


@pytest.mark.parametrize("scheme,bound", [("int8", 0.02), ("fp8_e4m3", 0.05)])
def test_quantized_accuracy_vs_float_reference_documented_bound(scheme, bound):
    """Acceptance: quantized matmul vs the float32 reference within the
    accuracy expectation documented in docs/quantization.md (normalized
    worst-case deviation on well-conditioned random operands)."""
    r = np.random.default_rng(7)
    for m, k, n in [(32, 128, 192), (17, 100, 130)]:
        x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
        w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
        qw = api.quant.quantize(w, scheme)
        got = api.matmul(x, qw)
        want = np.asarray(ref.ws_matmul_ref(x, w))
        dev = np.abs(np.asarray(got) - want).max() / np.abs(want).max()
        assert dev < bound, f"{scheme} {m}x{k}x{n}: deviation {dev:.4f}"


@settings(max_examples=5)
@given(
    k=st.sampled_from(KS),
    n=st.sampled_from(NS),
    scheme=st.sampled_from(sorted(api.quant.SCHEMES)),
    seed=st.integers(0, 2**16),
)
def test_quantize_dequantize_error_within_per_channel_bound(k, n, scheme, seed):
    """Elementwise |dequant(quantize(w)) - w| <= the per-channel bound
    api.quant.max_abs_error_bound documents (half a step / half a ulp)."""
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    qw = api.quant.quantize(w, scheme)
    back = api.quant.dequantize_natural(qw)
    err = np.abs(np.asarray(back) - np.asarray(w))
    bound = np.asarray(api.quant.max_abs_error_bound(qw))  # (n,)
    assert (err <= bound[None, :] + 1e-7).all()


def test_quantize_of_dipweight_equals_quantize_of_natural():
    r = np.random.default_rng(3)
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    qa = api.quant.quantize(w, "int8")
    qb = api.quant.quantize(api.DipWeight.from_natural(w), "int8")
    np.testing.assert_array_equal(np.asarray(qa.data), np.asarray(qb.data))
    np.testing.assert_allclose(np.asarray(qa.scale), np.asarray(qb.scale))


def test_scheme_mismatch_and_requantization_are_rejected():
    w = jnp.ones((64, 64), jnp.float32)
    qw = api.quant.quantize(w, "fp8_e4m3")
    with pytest.raises(ValueError, match="consumes scheme"):
        api.matmul(jnp.ones((4, 64), jnp.float32), qw, backend="dip_int8w")
    with pytest.raises(ValueError, match="requantiz"):
        api.quant.quantize(qw, "int8")
    assert api.quant.quantize(qw, "fp8_e4m3") is qw  # same scheme passes through
    with pytest.raises(ValueError, match="unknown quantization scheme"):
        api.quant.quantize(w, "int4")


def test_every_backend_accepts_a_quantized_weight():
    """Dispatch is weight-type aware: non-quantized backends dequantize a
    QuantizedDipWeight to their declared layout instead of crashing."""
    r = np.random.default_rng(5)
    x = jnp.asarray(r.normal(0, 1, (16, 100)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    qw = api.quant.quantize(w, "int8")
    want = np.asarray(api.matmul(x, api.quant.dequantize(qw), backend="xla"))
    for backend in sorted(CONFORMANCE):
        if api.get_backend(backend).layout == "dip_q":
            continue
        got = np.asarray(api.matmul(x, qw, backend=backend))
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3,
                                   err_msg=backend)


# -------------------------------------------------------------- epilogues ---
# every backend x epilogue x (representative) dtype against the kernels/ref
# epilogue oracles.  "none" is covered by test_backend_matches_oracle; the
# fused variants here exercise the flush-stage fusion AND the decomposition
# path (xla declares no fused epilogues, so its rows prove the decomposed
# fallback against the same oracles).
EPILOGUES_TESTED = ("bias", "bias_gelu", "bias_silu", "swiglu", "residual")

# int8 activations are excluded: any epilogue other than "none" widens the
# accumulator to f32 and produces a float output, which the pure-int8
# conformance rows don't model.
EPILOGUE_DTYPES = {
    b: tuple(d for d in dts if d != "int8") for b, dts in CONFORMANCE.items()
}


def _epilogue_inputs(backend, epilogue, m, k, n, dtype, seed):
    """(x, w-or-pair, epilogue_operands) as a call site would hold them."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32)).astype(dtype)
    wg = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32)).astype(dtype)
    wu = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32)).astype(dtype)
    bias = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))
    resid = jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32)).astype(dtype)
    if epilogue == "swiglu":
        return x, (_weight_for(backend, wg), _weight_for(backend, wu)), ()
    if epilogue.startswith("bias"):
        return x, _weight_for(backend, wg), (bias,)
    return x, _weight_for(backend, wg), (resid,)


def _epilogue_oracle(backend, x, wobj, epilogue, operands):
    """kernels/ref.py fused oracle for one dispatch, cropped to logical N."""
    be = api.get_backend(backend)
    primary = wobj[0] if isinstance(wobj, tuple) else wobj
    if be.layout == "natural":
        ops = (wobj[1],) if epilogue == "swiglu" else operands
        return ref.ws_matmul_epilogue_ref(x, primary if epilogue != "swiglu" else wobj[0],
                                          epilogue=epilogue, operands=ops)
    n = primary.d_out
    pad_n = (-n) % primary.perm_tile
    xk = jnp.pad(x, [(0, 0), (0, (-x.shape[-1]) % primary.perm_tile)])
    pad_cols = lambda t: jnp.pad(t, [(0, 0)] * (t.ndim - 1) + [(0, pad_n)])
    if epilogue == "swiglu":
        ops = ((wobj[1].data, wobj[1].scale) if be.layout == "dip_q"
               else (wobj[1].data,))
    elif epilogue.startswith("bias"):
        ops = (pad_cols(operands[0].reshape(1, n)),)
    else:
        ops = (pad_cols(operands[0]),)
    if be.layout == "dip":
        out = ref.dip_matmul_epilogue_ref(
            xk, primary.data, epilogue=epilogue, operands=ops,
            perm_tile=primary.perm_tile,
        )
    elif be.scheme == "int8":
        out = ref.dip_matmul_int8w_epilogue_ref(
            xk, primary.data, primary.scale, epilogue=epilogue, operands=ops,
            perm_tile=primary.perm_tile,
        )
    else:
        out = ref.dip_matmul_fp8_epilogue_ref(
            xk, primary.data, primary.scale, epilogue=epilogue, operands=ops,
            perm_tile=primary.perm_tile,
        )
    return out[..., :n]


@pytest.mark.parametrize("epilogue", EPILOGUES_TESTED)
@pytest.mark.parametrize(
    "backend,dtype",
    [(b, d) for b, dts in EPILOGUE_DTYPES.items() for d in dts],
)
def test_backend_epilogue_matches_oracle(backend, dtype, epilogue):
    """Fused-epilogue parity: every backend x epilogue x dtype against the
    kernels/ref.py fused oracles on an aligned AND a ragged shape."""
    for m, k, n, seed in ((8, 64, 64, 0), (17, 100, 130, 1)):
        x, wobj, operands = _epilogue_inputs(backend, epilogue, m, k, n, dtype, seed)
        got = api.matmul(x, wobj, backend=backend, epilogue=epilogue,
                         epilogue_operands=operands)
        want = _epilogue_oracle(backend, x, wobj, epilogue, operands)
        assert got.shape == (m, n)
        assert jnp.issubdtype(got.dtype, jnp.floating)
        if api.get_backend(backend).layout == "dip_q":
            tol = (dict(atol=2e-3, rtol=2e-3) if dtype == "float32"
                   else dict(atol=0.1, rtol=0.05))
        else:
            tol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol,
            err_msg=f"{backend}/{dtype}/{epilogue} {m}x{k}x{n}",
        )


@pytest.mark.parametrize("epilogue", EPILOGUES_TESTED)
def test_fused_and_decomposed_paths_agree(epilogue):
    """The same weights through a fused backend (pallas_dip) and the
    decomposing backend (xla) must agree — the decomposition rule is
    'identical semantics, different fusion'."""
    m, k, n = 17, 100, 130
    x, wobj, operands = _epilogue_inputs("pallas_dip", epilogue, m, k, n,
                                         "float32", 3)
    fused = api.matmul(x, wobj, backend="pallas_dip", epilogue=epilogue,
                       epilogue_operands=operands)
    decomposed = api.matmul(x, wobj, backend="xla", epilogue=epilogue,
                            epilogue_operands=operands)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(decomposed), atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("epilogue", EPILOGUES_TESTED)
@pytest.mark.parametrize("backend", sorted(CONFORMANCE))
def test_epilogue_gradients_match_decomposed_xla(backend, epilogue):
    """Grad parity for the custom_vjp recompute path: d/dx, d/d(bias|resid),
    and d/dw (float backends) through the FUSED kernel must match the
    natively-differentiated decomposed XLA path.  The fused backward
    recomputes the pre-activation from the saved matmul residuals — this is
    the test that keeps that recompute exact."""
    m, k, n = 16, 100, 130
    r = np.random.default_rng(29)
    c = jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32))
    x, wobj, operands = _epilogue_inputs(backend, epilogue, m, k, n,
                                         "float32", 31)
    be = api.get_backend(backend)
    if be.layout == "dip_q":
        # straight-through reference: the DEQUANTIZED weights through xla
        ref_w = (tuple(api.quant.dequantize(wi) for wi in wobj)
                 if isinstance(wobj, tuple) else api.quant.dequantize(wobj))
    else:
        ref_w = wobj

    def loss(backend_name, w):
        def f(xx, *ops):
            out = api.matmul(xx, w, backend=backend_name, epilogue=epilogue,
                             epilogue_operands=ops)
            return jnp.sum(out * c)
        return f

    argnums = tuple(range(1 + len(operands)))
    g = jax.grad(loss(backend, wobj), argnums=argnums)(x, *operands)
    g_ref = jax.grad(loss("xla", ref_w), argnums=argnums)(x, *operands)
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3,
            err_msg=f"{backend}/{epilogue}",
        )

    # weight grads on the float backends (quantized storage is frozen)
    if be.layout in ("natural", "dip") and be.tiled:
        gw = jax.grad(
            lambda w: loss(backend, w)(x, *operands)
        )(wobj)
        gw_ref = jax.grad(
            lambda w: loss("xla", w)(x, *operands)
        )(wobj)
        for a, b in zip(jax.tree_util.tree_leaves(gw),
                        jax.tree_util.tree_leaves(gw_ref)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3,
                err_msg=f"{backend}/{epilogue} weight grad",
            )


@pytest.mark.parametrize("scheme", sorted(api.quant.SCHEMES))
def test_quantized_scale_bias_activation_composition(scheme):
    """The quantized flush composes scale-on-output THEN bias THEN
    activation (kernels/dip_matmul_q.py): assert that exact ordering against
    a hand-built jnp expression, not just the packaged oracle."""
    m, k, n = 16, 64, 128
    r = np.random.default_rng(37)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    bias = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))
    qw = api.quant.quantize(w, scheme)
    got = api.matmul(x, qw, epilogue="bias_silu", epilogue_operands=(bias,))
    from repro.core import permute
    wn = permute.unpermute_tiled(qw.data, qw.perm_tile)
    if scheme == "int8":
        xq, xs = ref.quantize_acts_int8(x)
        z = jnp.matmul(xq, wn, preferred_element_type=jnp.int32).astype(jnp.float32)
        z = z * xs * qw.scale
    else:
        z = jnp.matmul(x, wn.astype(jnp.float32)) * qw.scale
    want = jax.nn.silu(z + bias)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3,
    )


def test_decomposed_epilogue_keeps_float_output_for_integer_matmuls():
    """The decomposition rule is 'identical semantics': an epilogue on an
    integer-accumulating dispatch yields a FLOAT result on the fused kernels
    (f32 epilogue arithmetic), so the decomposed path must too — not a
    silent truncation back to the matmul's integer dtype."""
    r = np.random.default_rng(43)
    x = jnp.asarray(r.integers(-1, 2, (8, 64)).astype(np.int8))
    w = jnp.asarray(r.integers(-1, 2, (64, 64)).astype(np.int8))
    bias = jnp.asarray(r.normal(0, 1, (64,)).astype(np.float32))
    fused = api.matmul(x, w, backend="ws", epilogue="bias_silu",
                       epilogue_operands=(bias,))
    # ws with block overrides pinned to the problem == the kernel's own
    # dtype rule; xla decomposes (declares no fused epilogues)
    decomposed = api.matmul(x.astype(jnp.float32), w.astype(jnp.float32),
                            backend="xla", epilogue="bias_silu",
                            epilogue_operands=(bias,))
    assert fused.dtype == jnp.float32
    assert decomposed.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(decomposed), atol=2e-3, rtol=2e-3,
    )


def test_epilogue_validation_rejects_malformed_inputs():
    x = jnp.ones((4, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    with pytest.raises(ValueError, match="unknown epilogue"):
        api.matmul(x, w, epilogue="bias_relu")
    with pytest.raises(ValueError, match="weight pair"):
        api.matmul(x, w, epilogue="swiglu")
    with pytest.raises(ValueError, match="only valid with the dual-weight"):
        api.matmul(x, (w, w), epilogue="bias", epilogue_operands=(jnp.ones((64,)),))
    with pytest.raises(ValueError, match="epilogue_operands"):
        api.matmul(x, w, epilogue="bias")
    with pytest.raises(ValueError, match="bias must be"):
        api.matmul(x, w, epilogue="bias", epilogue_operands=(jnp.ones((65,)),))
    with pytest.raises(ValueError, match="residual must match"):
        api.matmul(x, w, epilogue="residual",
                   epilogue_operands=(jnp.ones((5, 64)),))
    with pytest.raises(ValueError, match="share logical dims"):
        api.matmul(x, (w, jnp.ones((64, 128), jnp.float32)), epilogue="swiglu")
    with pytest.raises(ValueError, match="share a quantization scheme"):
        api.matmul(
            x,
            (api.quant.quantize(w, "int8"), api.quant.quantize(w, "fp8_e4m3")),
            backend="xla", epilogue="swiglu",
        )


def test_swiglu_pair_through_scan_and_jit():
    """The dual-weight dispatch must cross jit/scan boundaries like any
    other matmul (layer-stacked gate/up pairs scan transparently)."""
    r = np.random.default_rng(41)
    wg = jnp.asarray(r.normal(0, 1, (3, 100, 130)).astype(np.float32))
    wu = jnp.asarray(r.normal(0, 1, (3, 100, 130)).astype(np.float32))
    sg = api.DipWeight.from_natural(wg)
    su = api.DipWeight.from_natural(wu)
    x = jnp.asarray(r.normal(0, 1, (8, 100)).astype(np.float32))

    @jax.jit
    def f(xx, g, u):
        return api.matmul(xx, (g, u), backend="pallas_dip", epilogue="swiglu")

    def body(carry, lw):
        g, u = lw
        return carry, f(x, g, u)

    _, scanned = jax.lax.scan(body, 0, (sg, su))
    assert scanned.shape == (3, 8, 130)
    for i in range(3):
        want = jax.nn.silu(x @ wg[i]) * (x @ wu[i])
        np.testing.assert_allclose(
            np.asarray(scanned[i]), np.asarray(want), atol=2e-3, rtol=2e-3,
        )


# -------------------------------------------------------------- gradients ---
@pytest.mark.parametrize("backend", sorted(CONFORMANCE))
def test_activation_gradients_match_xla(backend):
    """d/dx through every backend == the natively-differentiated XLA path.

    A *linear* functional (sum(out * c)) pins the output cotangent to a
    constant, so the comparison isolates the VJP rule from forward-value
    differences (the quantized forward is approximate by design)."""
    r = np.random.default_rng(11)
    x = jnp.asarray(r.normal(0, 1, (16, 100)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    c = jnp.asarray(r.normal(0, 1, (16, 130)).astype(np.float32))
    wobj = _weight_for(backend, w)
    be = api.get_backend(backend)
    # the quantized VJP is straight-through w.r.t. the DEQUANTIZED weight
    w_ref = api.quant.dequantize(wobj) if be.layout == "dip_q" else w

    g = jax.grad(lambda xx: jnp.sum(api.matmul(xx, wobj, backend=backend) * c))(x)
    g_ref = jax.grad(lambda xx: jnp.sum(api.matmul(xx, w_ref, backend="xla") * c))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend", ["ws", "pallas_dip", "pallas_systolic"])
def test_weight_gradients_match_xla_on_float_backends(backend):
    r = np.random.default_rng(13)
    x = jnp.asarray(r.normal(0, 1, (16, 100)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    c = jnp.asarray(r.normal(0, 1, (16, 130)).astype(np.float32))
    wobj = _weight_for(backend, w)
    dw_xla = jax.grad(
        lambda d: jnp.sum(api.matmul(x, d, backend="xla") * c)
    )(api.DipWeight.from_natural(w))
    dw = jax.grad(lambda d: jnp.sum(api.matmul(x, d, backend=backend) * c))(wobj)
    if isinstance(wobj, api.DipWeight):
        assert isinstance(dw, api.DipWeight)
        np.testing.assert_allclose(
            np.asarray(dw.data), np.asarray(dw_xla.data), atol=1e-4, rtol=1e-4
        )
    else:  # natural-layout backend: plain array cotangent
        np.testing.assert_allclose(
            np.asarray(dw), np.asarray(dw_xla.to_natural()), atol=1e-4, rtol=1e-4
        )


def test_quantized_weight_cotangent_is_zero_not_garbage():
    """grad w.r.t. a QuantizedDipWeight's float leaves is exactly zero (the
    storage is frozen); the integer storage has no tangent at all."""
    x = jnp.asarray(np.random.default_rng(17).normal(0, 1, (8, 64)), jnp.float32)
    qw = api.quant.quantize(
        jnp.asarray(np.random.default_rng(18).normal(0, 1, (64, 64)), jnp.float32),
        "fp8_e4m3",
    )
    g = jax.grad(lambda q: jnp.sum(api.matmul(x, q)), allow_int=True)(qw)
    assert isinstance(g, api.QuantizedDipWeight)
    assert not np.asarray(jnp.abs(g.scale)).any()


# ------------------------------------------- pytree / jit / scan invariants --
def _mk_weights(stacked: bool):
    r = np.random.default_rng(21)
    shape = (3, 100, 130) if stacked else (100, 130)
    w = jnp.asarray(r.normal(0, 1, shape).astype(np.float32))
    return {
        "dip": api.DipWeight.from_natural(w),
        "quant_int8": api.quant.quantize(w, "int8"),
        "quant_fp8": api.quant.quantize(w, "fp8_e4m3"),
    }


@pytest.mark.parametrize("kind", ["dip", "quant_int8", "quant_fp8"])
def test_pytree_flatten_roundtrip_preserves_type_and_metadata(kind):
    wobj = _mk_weights(stacked=False)[kind]
    leaves, treedef = jax.tree_util.tree_flatten(wobj)
    assert len(leaves) == (1 if kind == "dip" else 2)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(back) is type(wobj)
    assert (back.d_in, back.d_out, back.perm_tile) == (100, 130, 64)
    if kind != "dip":
        assert back.scheme == wobj.scheme
    # eval_shape routes ShapeDtypeStructs through the same container
    spec = jax.eval_shape(lambda t: t, wobj)
    assert type(spec) is type(wobj)
    assert spec.data.shape == wobj.data.shape


@pytest.mark.parametrize("kind", ["dip", "quant_int8", "quant_fp8"])
def test_jit_boundary_and_scan_match_unjitted_per_layer_calls(kind):
    stacked = _mk_weights(stacked=True)[kind]
    x = jnp.asarray(np.random.default_rng(22).normal(0, 1, (8, 100)), jnp.float32)

    @jax.jit
    def f(xx, wobj):
        return api.matmul(xx, wobj)

    def body(carry, lw):
        return carry, f(x, lw)

    _, scanned = jax.lax.scan(body, 0, stacked)
    assert scanned.shape == (3, 8, 130)
    for i in range(3):
        sliced = jax.tree_util.tree_map(lambda t: t[i], stacked)
        assert type(sliced) is type(stacked)
        np.testing.assert_allclose(
            np.asarray(scanned[i]), np.asarray(api.matmul(x, sliced)),
            atol=1e-5, rtol=1e-5,
        )


@pytest.mark.parametrize("scheme", sorted(api.quant.SCHEMES))
def test_checkpoint_roundtrip_quantized_weight_bit_exact(tmp_path, scheme):
    """save -> restore keeps storage and scales bit-exact, the scheme in the
    manifest, and matmul parity after restore; a scheme mismatch on restore
    is detected, not silently mis-dequantized."""
    from repro.checkpoint import restore_pytree, save_pytree

    r = np.random.default_rng(23)
    w = jnp.asarray(r.normal(0, 1, (100, 130)).astype(np.float32))
    qw = api.quant.quantize(w, scheme)
    tree = {"w": qw}
    path = str(tmp_path / "ck")
    save_pytree(path, tree)

    got = restore_pytree(path, jax.eval_shape(lambda: tree))["w"]
    assert isinstance(got, api.QuantizedDipWeight) and got.scheme == scheme
    np.testing.assert_array_equal(
        np.asarray(got.data).view(np.uint8), np.asarray(qw.data).view(np.uint8)
    )
    np.testing.assert_array_equal(np.asarray(got.scale), np.asarray(qw.scale))
    x = jnp.asarray(r.normal(0, 1, (4, 100)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(api.matmul(x, got)), np.asarray(api.matmul(x, qw)),
        atol=1e-6, rtol=1e-6,
    )

    other = "fp8_e4m3" if scheme == "int8" else "int8"
    bad = {"w": api.QuantizedDipWeight(
        jax.eval_shape(lambda: tree)["w"].data,
        jax.eval_shape(lambda: tree)["w"].scale,
        100, 130, scheme=other,
    )}
    with pytest.raises(ValueError, match="metadata mismatch"):
        restore_pytree(path, bad)


def test_dequantized_fallback_keeps_activation_dtype():
    """A quantized weight through a non-quantized backend must not promote
    the output: dequantization happens AT the activation dtype, so bf16
    serving stays bf16 exactly like the float-weight path."""
    x = jnp.ones((4, 64), jnp.bfloat16)
    w = jnp.ones((64, 64), jnp.float32)
    qw = api.quant.quantize(w, "int8")
    for backend in ("xla", "ws", "pallas_dip"):
        got = api.matmul(x, qw, backend=backend)
        want = api.matmul(x, api.DipWeight.from_natural(w).astype(jnp.bfloat16),
                          backend=backend)
        assert got.dtype == want.dtype == jnp.bfloat16, backend


def test_quantize_params_validates_scheme_on_requantization():
    """quantize_params routes already-quantized nodes through quant.quantize:
    same scheme passes through untouched, a mismatch raises instead of
    silently leaving a mixed-scheme model."""
    from repro.models.transformer import quantize_params

    dw = api.DipWeight.from_natural(jnp.ones((64, 64), jnp.float32))
    qw = api.quant.quantize(jnp.ones((64, 64), jnp.float32), "fp8_e4m3")
    out = quantize_params({"a": dw, "b": qw}, "fp8_e4m3")
    assert out["a"].scheme == "fp8_e4m3" and out["b"] is qw
    with pytest.raises(ValueError, match="requantiz"):
        quantize_params({"a": dw, "b": qw}, "int8")


def test_contraction_validation_matches_float_path():
    """Quantized dispatch validates x against the LOGICAL d_in exactly like
    the float dip path (no silent zero-imputation into padding rows)."""
    qw = api.quant.quantize(jnp.ones((100, 130), jnp.float32), "int8")
    with pytest.raises(ValueError, match="contraction"):
        api.matmul(jnp.ones((4, 128), jnp.float32), qw)  # padded width
    with pytest.raises(ValueError, match="contraction"):
        api.matmul(jnp.ones((4, 96), jnp.float32), qw)   # too narrow
    with pytest.raises(ValueError, match="2-D"):
        api.matmul(
            jnp.ones((4, 100), jnp.float32),
            api.quant.quantize(jnp.ones((2, 100, 130), jnp.float32), "int8"),
        )


# -------------------------------------------------------------- prologues ---
# the mirror of the epilogue rows for the load-stage fusion: rmsnorm folded
# into the kernels' x-block load.  int8 activations are excluded for the
# same reason as epilogues (the normalized block is float arithmetic).
PROLOGUE_DTYPES = EPILOGUE_DTYPES


def test_prologue_capability_flags():
    """Every tiled builtin fuses the full prologue set at its load stage;
    xla declares none and relies on decomposition."""
    for backend in CONFORMANCE:
        be = api.get_backend(backend)
        if be.tiled:
            assert set(api.backend_prologues(backend)) == set(api.PROLOGUES), backend
        else:
            assert set(api.backend_prologues(backend)) == {"none"}, backend


def test_prologue_registration_rules():
    """Non-tiled, non-sharded backends cannot declare fused prologues (no
    load stage to fuse into); unknown prologue names are rejected at
    dispatch, not silently unfused."""
    with pytest.raises(ValueError, match="cannot fuse prologues"):
        api.register_backend("bad_pro", lambda *a, **k: None,
                             layout="natural", tiled=False,
                             prologues=("rmsnorm",))
    x = jnp.ones((4, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    with pytest.raises(ValueError, match="unknown prologue"):
        api.matmul(x, w, prologue="layernorm")
    with pytest.raises(ValueError, match="operand"):
        api.matmul(x, w, prologue="rmsnorm")  # missing gain


@pytest.mark.parametrize(
    "backend,dtype",
    [(b, d) for b, dts in PROLOGUE_DTYPES.items() for d in dts],
)
def test_backend_prologue_matches_decomposed(backend, dtype):
    """Fused rmsnorm prologue == rms_norm(x, g) -> matmul through the SAME
    backend, on an aligned and a ragged shape.  xla's rows prove the
    decomposition path; the tiled rows prove the in-kernel load rescale
    (including the ragged-K case, where the mean's divisor must stay the
    logical width, not the padded one)."""
    from repro.kernels import prologue as prologue_lib

    for m, k, n, seed in ((8, 64, 64, 0), (17, 100, 130, 1)):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32)).astype(dtype)
        w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32)).astype(dtype)
        g = jnp.asarray(r.normal(1, 0.1, (k,)).astype(np.float32))
        wobj = _weight_for(backend, w)
        got = api.matmul(x, wobj, backend=backend,
                         prologue="rmsnorm", prologue_operands=(g,))
        xn = prologue_lib.apply("rmsnorm", x, g)
        want = api.matmul(xn, wobj, backend=backend)
        assert got.shape == (m, n)
        if api.get_backend(backend).layout == "dip_q":
            tol = (dict(atol=2e-3, rtol=2e-3) if dtype == "float32"
                   else dict(atol=0.1, rtol=0.05))
        else:
            tol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol,
            err_msg=f"{backend}/{dtype} {m}x{k}x{n}",
        )


def test_prologue_epilogue_composition_single_launch():
    """rmsnorm prologue + bias_silu epilogue + the matmul is still exactly
    ONE pallas launch on the fused backends, and matches the three-step
    decomposed composition."""
    m, k, n = 16, 100, 130
    r = np.random.default_rng(47)
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    g = jnp.asarray(r.normal(1, 0.1, (k,)).astype(np.float32))
    bias = jnp.asarray(r.normal(0, 1, (n,)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)

    def fused(xx):
        return api.matmul(xx, dw, backend="pallas_dip",
                          prologue="rmsnorm", prologue_operands=(g,),
                          epilogue="bias_silu", epilogue_operands=(bias,))

    def decomposed(xx):
        return api.matmul(xx, dw, backend="xla",
                          prologue="rmsnorm", prologue_operands=(g,),
                          epilogue="bias_silu", epilogue_operands=(bias,))

    np.testing.assert_allclose(np.asarray(fused(x)), np.asarray(decomposed(x)),
                               atol=2e-3, rtol=2e-3)

    def count_pallas(fn, *args):
        closed = jax.make_jaxpr(fn)(*args)

        def walk(jx):
            return sum(
                (eqn.primitive.name == "pallas_call")
                + sum(walk(sub) for sub in jax.core.jaxprs_in_params(eqn.params))
                for eqn in jx.eqns
            )

        return walk(closed.jaxpr)

    assert count_pallas(fused, x) == 1
    assert count_pallas(decomposed, x) == 0


@pytest.mark.parametrize("backend", sorted(CONFORMANCE))
def test_prologue_gradients_match_decomposed_xla(backend):
    """d/dx, d/d(gain), and d/dw (float backends) through the FUSED
    rmsnorm-prologue kernel must match the natively-differentiated
    decomposed XLA path — the recompute VJP re-derives the normalized block
    from the raw activations, and this test keeps that recompute exact."""
    m, k, n = 16, 100, 130
    r = np.random.default_rng(53)
    c = jnp.asarray(r.normal(0, 1, (m, n)).astype(np.float32))
    x = jnp.asarray(r.normal(0, 1, (m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (k, n)).astype(np.float32))
    g = jnp.asarray(r.normal(1, 0.1, (k,)).astype(np.float32))
    wobj = _weight_for(backend, w)
    be = api.get_backend(backend)
    ref_w = api.quant.dequantize(wobj) if be.layout == "dip_q" else wobj

    def loss(backend_name, wgt):
        def f(xx, gg):
            out = api.matmul(xx, wgt, backend=backend_name,
                             prologue="rmsnorm", prologue_operands=(gg,))
            return jnp.sum(out * c)
        return f

    got = jax.grad(loss(backend, wobj), argnums=(0, 1))(x, g)
    want = jax.grad(loss("xla", ref_w), argnums=(0, 1))(x, g)
    for a, b in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3,
            err_msg=f"{backend} prologue grad",
        )

    if be.layout in ("natural", "dip") and be.tiled:
        gw = jax.grad(lambda wgt: loss(backend, wgt)(x, g))(wobj)
        gw_ref = jax.grad(lambda wgt: loss("xla", wgt)(x, g))(wobj)
        for a, b in zip(jax.tree_util.tree_leaves(gw),
                        jax.tree_util.tree_leaves(gw_ref)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3,
                err_msg=f"{backend} prologue weight grad",
            )
