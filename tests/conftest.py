"""Shared test fixtures.

IMPORTANT: no XLA_FLAGS / device-count forcing here — smoke tests and
benches must see the real (single-CPU) device topology.  Tests that need
multiple devices run their body in a subprocess with forced host devices via
:func:`run_forced_devices` (shared by tests/test_multidevice.py and
tests/test_sharded_backends.py so the mesh plumbing lives in ONE place).
"""

import os
import subprocess
import sys

# Hermeticity: a developer's ~/.cache/repro-dip tuning cache must not leak
# measured block-size entries into the suite's lookup_blocks expectations.
# Must be set before the first `repro.api` import (the cache loads there).
os.environ.setdefault("REPRO_DIP_NO_TUNING_CACHE", "1")

import numpy as np
import pytest

_FORCED_PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import jax, jax.numpy as jnp, numpy as np
"""


def run_forced_devices(body: str, devices: int = 4, timeout: int = 600) -> str:
    """Run ``body`` in a fresh interpreter with ``devices`` forced host CPU
    devices (XLA locks the device count at first init, so multi-device code
    can never run in the pytest process itself).  ``jax``/``jnp``/``np`` are
    pre-imported; asserts on the child's exit code and returns its stdout."""
    code = _FORCED_PREAMBLE.format(n=devices) + body
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": os.path.expanduser("~"), "JAX_PLATFORMS": "cpu",
             "REPRO_DIP_NO_TUNING_CACHE": "1"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
