"""Shared test fixtures.

IMPORTANT: no XLA_FLAGS / device-count forcing here — smoke tests and
benches must see the real (single-CPU) device topology.  Tests that need
multiple devices spawn subprocesses (see tests/test_multidevice.py).
"""

import os

# Hermeticity: a developer's ~/.cache/repro-dip tuning cache must not leak
# measured block-size entries into the suite's lookup_blocks expectations.
# Must be set before the first `repro.api` import (the cache loads there).
os.environ.setdefault("REPRO_DIP_NO_TUNING_CACHE", "1")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
