"""Shared test fixtures.

IMPORTANT: no XLA_FLAGS / device-count forcing here — smoke tests and
benches must see the real (single-CPU) device topology.  Tests that need
multiple devices spawn subprocesses (see tests/test_multidevice.py).
"""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
