"""Property-testing front-end: real `hypothesis` when installed, otherwise a
tiny deterministic fallback so the tier-1 suite still *runs* on a bare CPU
environment (no pip access).

The fallback implements just what these tests use — ``@settings``, ``@given``
with keyword strategies, ``st.integers``, ``st.sampled_from`` — and replays
each test ``max_examples`` times with draws from a fixed-seed RNG.  It keeps
the property-style coverage (many sampled shapes/seeds per test) without the
shrinking/database machinery; install the ``test`` extra
(``pip install -e .[test]``) for the real thing.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by whichever env runs CI
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=None):
            if max_value is None:
                max_value = 2**31 - 1
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def sampled_from(values):
            vals = list(values)
            return _Strategy(lambda rng: vals[int(rng.integers(len(vals)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", 20)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the strategy parameters as fixtures, but it
            # MUST still see the remaining ones (pytest.mark.parametrize
            # resolves names against the visible signature) — expose the
            # original signature minus the strategy-drawn parameters
            import inspect

            sig = inspect.signature(fn)
            runner.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats
            ])
            del runner.__wrapped__
            runner.hypothesis_fallback = True
            return runner

        return deco

    def settings(max_examples=20, **_):
        # applied outside @given: stamp the example count onto the runner
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco


st = strategies

__all__ = ["given", "settings", "strategies", "st", "HAVE_HYPOTHESIS"]
