"""Cycle-accurate simulators: numerically exact + timing == eqs. (1)-(7)."""

import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import analytical, permute, simulator


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 12),
    extra=st.integers(0, 12),
    s=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dip_simulator_exact_and_on_time(n, extra, s, seed):
    m = n + extra
    r = np.random.default_rng(seed)
    x = r.integers(-50, 50, size=(m, n))
    w = r.integers(-50, 50, size=(n, n))
    res = simulator.simulate_dip(x, w, stages=s)
    np.testing.assert_array_equal(res.output, x @ w)
    assert res.latency == analytical.dip_streaming_latency(n, m, s)
    assert res.tfpu == analytical.dip_tfpu(n)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 10),
    extra=st.integers(0, 10),
    s=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ws_simulator_exact_and_on_time(n, extra, s, seed):
    m = n + extra
    r = np.random.default_rng(seed)
    x = r.integers(-50, 50, size=(m, n))
    w = r.integers(-50, 50, size=(n, n))
    res = simulator.simulate_ws(x, w, stages=s)
    np.testing.assert_array_equal(res.output, x @ w)
    assert res.latency == analytical.ws_streaming_latency(n, m, s)
    # WS needs M >= 2N-1 rows to ever reach full utilization
    if m >= 2 * n - 1:
        assert res.tfpu == analytical.ws_tfpu(n)
    else:
        assert res.tfpu is None


def test_fig4_walkthrough_timing():
    """Paper Fig. 4 (3x3, 2-stage MAC): first output at cycle 3, last at 5."""
    x = np.arange(1, 10).reshape(3, 3)
    w = np.arange(9).reshape(3, 3)
    res = simulator.simulate_dip(x, w, stages=2)
    assert res.first_output_cycle == 3
    assert res.latency == 6            # cycles 0..5  == 2N+S-2
    assert res.tfpu == 3               # eq. (7)


def test_weight_load_shifts_to_permuted_layout():
    w = np.random.default_rng(1).integers(-5, 5, size=(6, 6))
    resident = simulator.simulate_weight_load_dip(w)
    np.testing.assert_array_equal(resident, permute.permute_weights_np(w))


def test_dip_fills_with_m_equals_n_but_ws_does_not():
    """DiP reaches 100% PE rows at M=N; WS's diagonal wavefront cannot."""
    n = 8
    r = np.random.default_rng(2)
    x = r.integers(-5, 5, size=(n, n))
    w = r.integers(-5, 5, size=(n, n))
    dip = simulator.simulate_dip(x, w)
    ws = simulator.simulate_ws(x, w)
    assert dip.tfpu == n
    assert ws.tfpu is None
    assert max(dip.active_rows) == n
    assert max(ws.active_rows) < n * n


def test_float_and_prepermuted_paths():
    n, m = 8, 16
    r = np.random.default_rng(3)
    x = r.normal(size=(m, n))
    w = r.normal(size=(n, n))
    res = simulator.simulate_dip(x, w)
    np.testing.assert_allclose(res.output, x @ w, rtol=1e-12)
    p = permute.permute_weights_np(w)
    res2 = simulator.simulate_dip(x, p, weights_prepermuted=True)
    np.testing.assert_allclose(res2.output, x @ w, rtol=1e-12)
