"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch is instantiated at a REDUCED same-family config (tiny
dims, few layers/experts) and runs one forward + one train step on CPU,
asserting output shapes and finiteness.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import transformer as tf_model
from repro.optim import AdamW


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced(compute_dtype="float32")
    params = tf_model.init_params(key, cfg)

    batch_size, seq = 2, 32
    if cfg.frontend != "none":
        batch = {
            "embeddings": jax.random.normal(key, (batch_size, seq, cfg.d_model)) * 0.02,
            "labels": jax.random.randint(key, (batch_size, seq), 0, cfg.vocab_size),
        }
        logits, _, _ = tf_model.forward(params, cfg, embeddings=batch["embeddings"])
    else:
        toks = jax.random.randint(key, (batch_size, seq), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        logits, _, _ = tf_model.forward(params, cfg, tokens=toks)

    assert logits.shape == (batch_size, seq, cfg.padded_vocab)
    real = logits[..., : cfg.vocab_size]
    assert bool(jnp.isfinite(real).all()), f"{arch}: non-finite logits"
    # padded lanes masked to -inf
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29

    opt = AdamW(lr=1e-3)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(tf_model.train_step_fn(cfg, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert float(metrics["grad_norm"]) > 0
    assert int(metrics["step"]) == 1


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-lite-16b", "mamba2-370m",
                                  "zamba2-2.7b"])
def test_reduced_decode_step(arch, key):
    """serve_step: one token against a warm cache (representative families)."""
    cfg = get_config(arch).reduced(compute_dtype="float32")
    params = tf_model.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    dstep = jax.jit(tf_model.decode_step_fn(cfg))
    cache = tf_model.init_cache(cfg, batch=2, max_seq=24)
    _, cache = dstep(params, cache, toks)
    logits, cache = dstep(params, cache, toks[:, :1])
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all())
    assert int(cache["pos"]) == 17


def test_full_configs_match_assignment():
    """Pin the exact assigned hyper-parameters (regression guard)."""
    spec = {
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400, n_experts=64, moe_top_k=6,
                                     kv_lora_rank=512, d_ff_expert=1408,
                                     n_shared_experts=2),
        "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, vocab_size=151936,
                                    n_experts=128, moe_top_k=8, d_ff_expert=1536),
        "mamba2-370m": dict(n_layers=48, d_model=1024, ssm_state=128,
                            vocab_size=50280),
        "llama3-8b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
                          d_ff=14336, vocab_size=128256),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=32, d_ff=13440, vocab_size=92416,
                               qkv_bias=True),
        "yi-9b": dict(n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
                      d_ff=11008, vocab_size=64000),
        "qwen2-72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=29568, vocab_size=152064, qkv_bias=True),
        "phi-3-vision-4.2b": dict(n_layers=32, d_model=3072, n_heads=32,
                                  n_kv_heads=32, d_ff=8192, vocab_size=32064,
                                  frontend="vision_stub"),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab_size=2048,
                                frontend="audio_stub"),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64, attn_every=6),
    }
    for arch_id, fields in spec.items():
        cfg = get_config(arch_id)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch_id}.{k}: {getattr(cfg, k)} != {v}"


def test_param_counts_sane():
    expect = {
        "deepseek-v2-lite-16b": 16.2e9, "qwen3-moe-235b-a22b": 235e9,
        "mamba2-370m": 0.37e9, "llama3-8b": 8.0e9, "codeqwen1.5-7b": 8.2e9,
        "yi-9b": 8.8e9, "qwen2-72b": 72.7e9, "phi-3-vision-4.2b": 3.8e9,
        "musicgen-medium": 1.8e9, "zamba2-2.7b": 2.4e9,
    }
    for arch_id, n in expect.items():
        got = get_config(arch_id).param_count()
        assert abs(got - n) / n < 0.05, f"{arch_id}: {got/1e9:.2f}B != ~{n/1e9:.1f}B"
    # MoE active params
    assert abs(get_config("qwen3-moe-235b-a22b").active_param_count() - 22.2e9) < 1.5e9


def test_long_500k_gate():
    from repro.configs import shape_cells_for

    for arch_id in ALL_ARCHS:
        cfg = get_config(arch_id)
        names = [c.name for c in shape_cells_for(cfg)]
        if arch_id in ("mamba2_370m", "zamba2_2_7b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names  # skip recorded in DESIGN.md §4
