"""Pallas kernels vs pure-jnp oracles: shape x dtype sweeps (interpret mode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core import permute
from repro.kernels import ref
from repro.kernels.dip_matmul import dip_matmul_pallas
from repro.kernels.ws_matmul import ws_matmul_pallas

SHAPES = [
    (8, 64, 64),
    (64, 64, 128),
    (128, 256, 256),
    (100, 130, 200),     # ragged (padding path)
    (1, 64, 64),         # single row
    (257, 512, 192),
]
DTYPES = ["float32", "bfloat16", "int8"]

# every M/K/N combination of off-tile dims the padding shim must absorb:
# sub-tile K/N, one-past-tile, odd everything, and aligned-K/ragged-M-N
UNALIGNED_SHAPES = [
    (33, 65, 127),
    (7, 30, 100),
    (65, 191, 66),
    (129, 64, 130),
    (16, 127, 64),
]


def _mats(m, k, n, dtype, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(0, 1, (m, k)).astype(np.float32)
    w = r.normal(0, 1, (k, n)).astype(np.float32)
    if dtype == "int8":
        return (x * 10).astype(np.int8), (w * 10).astype(np.int8)
    return x.astype(dtype), w.astype(dtype)


def _tol(dtype):
    return dict(atol=0, rtol=0) if dtype == "int8" else (
        dict(atol=1e-3, rtol=1e-3) if dtype == "float32" else dict(atol=0.5, rtol=0.05)
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dip_matmul_fast_path(shape, dtype):
    m, k, n = shape
    x, w = _mats(m, k, n, dtype)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = api.matmul(jnp.asarray(x), dw, backend="pallas_dip")
    want = ref.ws_matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_dip_systolic_wavefront_path(shape, dtype):
    m, k, n = shape
    x, w = _mats(m, k, n, dtype)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = api.matmul(jnp.asarray(x), dw, backend="pallas_systolic")
    want = ref.dip_systolic_ref(
        jnp.asarray(np.pad(x, [(0, 0), (0, (-k) % 64)])), dw.data
    )[..., :n]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_ws_baseline_kernel(shape):
    m, k, n = shape
    x, w = _mats(m, k, n, "float32")
    got = api.matmul(jnp.asarray(x), jnp.asarray(w), backend="ws")
    np.testing.assert_allclose(np.asarray(got), x @ w, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("shape", UNALIGNED_SHAPES)
@pytest.mark.parametrize(
    "backend", ["ws", "pallas_dip", "pallas_systolic", "dip_int8w", "dip_fp8"]
)
def test_unaligned_shape_parity_all_tiled_backends(shape, backend):
    """M/K/N not multiples of the perm tile: dispatch pads, kernels stay
    parity-exact vs their oracle, output is cropped to the logical shape."""
    m, k, n = shape
    x, w = _mats(m, k, n, "float32")
    x, w = jnp.asarray(x), jnp.asarray(w)
    xk = jnp.pad(x, [(0, 0), (0, (-k) % 64)])
    if backend in ("dip_int8w", "dip_fp8"):
        qw = api.quant.quantize(w, api.get_backend(backend).scheme)
        got = api.matmul(x, qw, backend=backend)
        oracle = (
            ref.dip_matmul_int8w_ref if backend == "dip_int8w"
            else ref.dip_matmul_fp8_ref
        )
        want = oracle(xk, qw.data, qw.scale)[..., :n]
        tol = dict(atol=1e-3, rtol=1e-3)
    else:
        dw = api.DipWeight.from_natural(w)
        got = api.matmul(x, dw, backend=backend)
        want = ref.dip_matmul_ref(xk, dw.data)[..., :n]
        tol = dict(atol=1e-3, rtol=1e-3)
    assert got.shape == (m, n)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_batched_inputs():
    r = np.random.default_rng(1)
    x = r.normal(size=(3, 5, 256)).astype(np.float32)
    w = r.normal(size=(256, 192)).astype(np.float32)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = api.matmul(jnp.asarray(x), dw, backend="pallas_dip")
    np.testing.assert_allclose(np.asarray(got), x @ w, atol=1e-3, rtol=1e-3)


def test_block_shape_sweep():
    """Kernel must be correct for every legal BlockSpec tiling."""
    m, k, n = 256, 256, 256
    x, w = _mats(m, k, n, "float32")
    p = api.DipWeight.from_natural(jnp.asarray(w)).data
    want = x @ w
    for bm in (64, 128, 256):
        for bk in (64, 128, 256):
            for bn in (64, 128, 256):
                got = dip_matmul_pallas(
                    jnp.asarray(x), p, block_m=bm, block_k=bk, block_n=bn,
                    interpret=True,
                )
                np.testing.assert_allclose(
                    np.asarray(got), want, atol=1e-3, rtol=1e-3,
                    err_msg=f"blocks ({bm},{bk},{bn})",
                )


def test_quantized_kernel_block_shape_sweep():
    """dip_matmul_q must be correct for every legal BlockSpec tiling — the
    int32 accumulation and the (M,1)x(1,N) scale epilogue are block-local,
    so no tiling may change the result beyond f32 epilogue rounding."""
    from repro.kernels.dip_matmul_q import dip_matmul_q_pallas

    m, k, n = 128, 128, 128
    x, w = _mats(m, k, n, "float32")
    qw = api.quant.quantize(jnp.asarray(w), "int8")
    want = ref.dip_matmul_int8w_ref(jnp.asarray(x), qw.data, qw.scale)
    for bm in (64, 128):
        for bk in (64, 128):
            for bn in (64, 128):
                got = dip_matmul_q_pallas(
                    jnp.asarray(x), qw.data, qw.scale,
                    block_m=bm, block_k=bk, block_n=bn, interpret=True,
                )
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4,
                    err_msg=f"blocks ({bm},{bk},{bn})",
                )


def test_quantized_kernel_int32_accumulation_is_exact():
    """The W8A8 path accumulates in int32 EXACTLY (ADiP's claim): pin every
    quantization scale to 1.0 (amax = 127 per row/column) so the kernel's
    output is the raw integer matmul — which f32 holds exactly below 2^24."""
    r = np.random.default_rng(9)
    xi = r.integers(-127, 128, (32, 128)).astype(np.float32)
    wi = r.integers(-127, 128, (128, 64)).astype(np.float32)
    xi[:, 0], wi[0, :] = 127, 127  # per-row / per-column amax -> scale 1.0
    qw = api.quant.quantize(jnp.asarray(wi), "int8")
    np.testing.assert_array_equal(np.asarray(qw.scale[..., :64]), 1.0)
    got = np.asarray(api.matmul(jnp.asarray(xi), qw, backend="dip_int8w"))
    want = xi.astype(np.int64) @ wi.astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_deshear_ablation_matches_ws_kernel():
    """fuse_deshear=False on natural weights == the WS baseline kernel."""
    m, k, n = 128, 128, 128
    x, w = _mats(m, k, n, "float32")
    a = dip_matmul_pallas(jnp.asarray(x), jnp.asarray(w), fuse_deshear=False,
                          block_m=64, block_k=64, block_n=64, interpret=True)
    b = ws_matmul_pallas(jnp.asarray(x), jnp.asarray(w),
                         block_m=64, block_k=64, block_n=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dip_format_storage_is_permutated():
    """The storage tensor really is the paper's permutation (per 64-tile)."""
    w = np.random.default_rng(2).normal(size=(128, 128)).astype(np.float32)
    p = np.asarray(api.DipWeight.from_natural(jnp.asarray(w)).data)
    for bi in range(2):
        for bj in range(2):
            blk = w[bi * 64:(bi + 1) * 64, bj * 64:(bj + 1) * 64]
            np.testing.assert_allclose(
                p[bi * 64:(bi + 1) * 64, bj * 64:(bj + 1) * 64],
                permute.permute_weights_np(blk),
            )


def test_int8_paper_precision_exactness():
    """INT8 (the paper's datatype) must be bit-exact vs int32 accumulation."""
    r = np.random.default_rng(3)
    x = r.integers(-128, 128, (64, 192)).astype(np.int8)
    w = r.integers(-128, 128, (192, 64)).astype(np.int8)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = np.asarray(api.matmul(jnp.asarray(x), dw, backend="pallas_dip"))
    want = x.astype(np.int32) @ w.astype(np.int32)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


def test_flash_attention_kernel_vs_dense_reference():
    """Fused flash kernel (the §Perf pair-3 lever) vs dense softmax."""
    from repro.kernels.flash_attention import flash_attention_pallas

    r = np.random.default_rng(0)
    for (bh, s, d, bq, bk) in [(4, 256, 64, 64, 64), (2, 512, 128, 128, 256)]:
        q = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32))
        k = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32))
        v = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32))
        got = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk,
                                     causal=True, interpret=True)
        sc = jnp.einsum("bqd,bkd->bqk", q, k) * (d ** -0.5)
        sc = jnp.where(np.tril(np.ones((s, s), bool))[None], sc, -1e30)
        want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(sc, -1), v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=1e-3)


def _dense_attn_ref(q, k, v, *, q_offset=0, kv_len=None, causal=True):
    """Dense masked-softmax oracle matching the flash kernel's contract:
    query i sits at absolute position q_offset + i; keys at/past kv_len are
    dead; rows with no live key return exactly zero."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    qo = np.broadcast_to(np.asarray(q_offset, np.int64).reshape(-1), (bh,))
    kvl = np.broadcast_to(
        np.asarray(sk if kv_len is None else kv_len, np.int64).reshape(-1), (bh,)
    )
    q64, k64, v64 = (np.asarray(a, np.float64) for a in (q, k, v))
    sc = np.einsum("bqd,bkd->bqk", q64, k64) * (d ** -0.5)
    kpos = np.arange(sk)[None, None, :]
    qpos = qo[:, None, None] + np.arange(sq)[None, :, None]
    live = np.broadcast_to(kpos < kvl[:, None, None], (bh, sq, sk)).copy()
    if causal:
        live &= qpos >= kpos
    sc = np.where(live, sc, -np.inf)
    m = np.max(sc, -1, keepdims=True)
    p = np.exp(sc - np.where(np.isfinite(m), m, 0.0))
    p = np.where(live, p, 0.0)
    denom = p.sum(-1, keepdims=True)
    p = np.where(denom > 0, p / np.where(denom > 0, denom, 1.0), 0.0)
    return np.einsum("bqk,bkd->bqd", p, v64)


def test_flash_attention_ragged_q_offset_parity():
    """Regression (chunked-prefill seam): Sq != Sk with a query offset —
    the kernel must mask causally by ABSOLUTE position, not block index.
    Pre-PR flash_attention had no q_offset and could only do square Sq==Sk."""
    from repro.kernels.flash_attention import flash_attention_pallas

    r = np.random.default_rng(5)
    bh, sq, sk, d = 2, 17, 100, 64
    q = jnp.asarray(r.normal(size=(bh, sq, d)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(bh, sk, d)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(bh, sk, d)).astype(np.float32))
    for qo in (0, 40, 83):
        got = flash_attention_pallas(q, k, v, q_offset=qo, kv_len=sk,
                                     block_q=64, block_k=64, interpret=True)
        want = _dense_attn_ref(q, k, v, q_offset=qo, kv_len=sk)
        np.testing.assert_allclose(np.asarray(got), want, atol=2e-3, rtol=1e-3,
                                   err_msg=f"q_offset={qo}")


def test_flash_attention_per_row_offsets_and_kv_len():
    """Per-(B*H)-row q_offset / kv_len vectors (the serving batch case) and
    the kv_len=0 hazard: a row with no live key must return exactly zero,
    not exp(0)/0 garbage."""
    from repro.kernels.flash_attention import flash_attention_pallas

    r = np.random.default_rng(6)
    bh, sq, sk, d = 4, 8, 64, 32
    q = jnp.asarray(r.normal(size=(bh, sq, d)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(bh, sk, d)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(bh, sk, d)).astype(np.float32))
    qo = np.array([0, 13, 56, 7], np.int32)
    kvl = np.array([8, 21, 64, 0], np.int32)
    got = flash_attention_pallas(q, k, v, q_offset=jnp.asarray(qo),
                                 kv_len=jnp.asarray(kvl),
                                 block_q=8, block_k=32, interpret=True)
    want = _dense_attn_ref(q, k, v, q_offset=qo, kv_len=kvl)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-3, rtol=1e-3)
    # row 3 has zero live keys everywhere: exact zeros, finite
    row3 = np.asarray(got)[3]
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_array_equal(row3, np.zeros_like(row3))


def test_flash_attention_bfloat16():
    from repro.kernels.flash_attention import flash_attention_pallas

    r = np.random.default_rng(8)
    bh, s, d = 2, 128, 64
    q = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32)).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, block_q=64, block_k=64, interpret=True)
    want = _dense_attn_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               atol=3e-2, rtol=3e-2)


def _count_pallas_calls(fn, *args) -> int:
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> int:
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                total += 1
            for sub in jax.core.jaxprs_in_params(eqn.params):
                total += walk(sub)
        return total

    return walk(closed.jaxpr)


def test_attention_registry_parity_and_single_launch():
    """api.attention: 'flash' and 'xla' backends agree on the shared
    contract (incl. zeroed fully-masked rows), and the flash path is exactly
    ONE pallas launch in the jaxpr."""
    r = np.random.default_rng(9)
    bh, sq, sk, d = 2, 16, 48, 32
    q = jnp.asarray(r.normal(size=(bh, sq, d)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(bh, sk, d)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(bh, sk, d)).astype(np.float32))
    kvl = jnp.asarray(np.array([48, 0], np.int32))
    flash = api.attention(q, k, v, backend="flash", q_offset=32, kv_len=kvl,
                          interpret=True)
    xla = api.attention(q, k, v, backend="xla", q_offset=32, kv_len=kvl)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(xla),
                               atol=2e-3, rtol=1e-3)
    n = _count_pallas_calls(
        lambda a, b, c: api.attention(a, b, c, backend="flash", interpret=True),
        q, k, v,
    )
    assert n == 1, f"flash attention dispatch launched {n} kernels, want 1"
    assert _count_pallas_calls(
        lambda a, b, c: api.attention(a, b, c, backend="xla"), q, k, v
    ) == 0


# ------------------------------------------------------- fused lm_head+CE ---
def test_fused_lm_head_ce_matches_reference():
    """Forward + grad parity vs the unfused oracle, with masking: labels at
    ignore_index (-100) and mask==0 positions contribute nothing."""
    from repro.kernels import lm_head_ce

    r = np.random.default_rng(11)
    t, d, v = 78, 64, 130           # ragged vocab (pads to 256 inside)
    for dtype, tol in ((jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)):
        x = jnp.asarray(r.normal(size=(t, d)).astype(np.float32)).astype(dtype)
        w = jnp.asarray(r.normal(size=(d, v)).astype(np.float32)).astype(dtype)
        labels = jnp.asarray(r.integers(0, v, (t,)).astype(np.int32))
        labels = labels.at[5].set(-100)
        mask = jnp.asarray((r.random(t) > 0.2).astype(np.int32))

        def fused(xx, ww):
            return lm_head_ce.fused_cross_entropy_loss(
                xx, ww, labels, mask=mask, vocab_size=v, interpret=True)

        def unfused(xx, ww):
            return lm_head_ce.reference_lm_head_ce(
                xx, ww, labels, mask=mask, vocab_size=v)

        np.testing.assert_allclose(float(fused(x, w)), float(unfused(x, w)),
                                   atol=tol, rtol=tol)
        g = jax.grad(fused, argnums=(0, 1))(x, w)
        g_ref = jax.grad(unfused, argnums=(0, 1))(x, w)
        for got, want in zip(g, g_ref):
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                atol=tol, rtol=tol,
            )


def test_fused_ce_never_materializes_logits():
    """Structural acceptance: no (rows>=T, cols>=V) intermediate appears in
    the fused loss+grad jaxpr — the unfused oracle's jaxpr (sanity) has one.
    T > D so the weight-sized dW reassembly cannot alias the predicate."""
    from repro.kernels import lm_head_ce

    t, d, v = 78, 64, 512
    r = np.random.default_rng(13)
    x = jnp.asarray(r.normal(size=(t, d)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(d, v)).astype(np.float32))
    labels = jnp.asarray(r.integers(0, v, (t,)).astype(np.int32))

    def logits_like(jaxpr):
        found = []

        def walk(jx):
            for eqn in jx.eqns:
                for var in eqn.outvars:
                    shape = getattr(getattr(var, "aval", None), "shape", ())
                    if (len(shape) >= 2 and shape[-1] >= v
                            and np.prod(shape[:-1]) >= t):
                        found.append((eqn.primitive.name, tuple(shape)))
                for sub in jax.core.jaxprs_in_params(eqn.params):
                    walk(sub)

        walk(jaxpr.jaxpr)
        return found

    def fused(xx, ww):
        return lm_head_ce.fused_cross_entropy_loss(
            xx, ww, labels, vocab_size=v, block_v=128, interpret=True)

    def unfused(xx, ww):
        return lm_head_ce.reference_lm_head_ce(xx, ww, labels, vocab_size=v)

    grad_fused = jax.make_jaxpr(jax.grad(fused, argnums=(0, 1)))(x, w)
    grad_unfused = jax.make_jaxpr(jax.grad(unfused, argnums=(0, 1)))(x, w)
    assert logits_like(grad_unfused), "oracle should materialize logits (sanity)"
    hits = logits_like(grad_fused)
    assert not hits, f"fused CE materialized logits-sized tensors: {hits}"


def test_fused_ce_all_ignored_batch_is_finite():
    """Every label ignored: loss must be exactly 0 with zero grads, not 0/0."""
    from repro.kernels import lm_head_ce

    t, d, v = 8, 64, 128
    x = jnp.ones((t, d), jnp.float32)
    w = jnp.ones((d, v), jnp.float32)
    labels = jnp.full((t,), -100, jnp.int32)
    loss, grads = jax.value_and_grad(
        lambda xx: lm_head_ce.fused_cross_entropy_loss(
            xx, w, labels, vocab_size=v, interpret=True)
    )(x)
    assert float(loss) == 0.0
    np.testing.assert_array_equal(np.asarray(grads), 0.0)
