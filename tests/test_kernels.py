"""Pallas kernels vs pure-jnp oracles: shape x dtype sweeps (interpret mode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core import permute
from repro.kernels import ref
from repro.kernels.dip_matmul import dip_matmul_pallas
from repro.kernels.ws_matmul import ws_matmul_pallas

SHAPES = [
    (8, 64, 64),
    (64, 64, 128),
    (128, 256, 256),
    (100, 130, 200),     # ragged (padding path)
    (1, 64, 64),         # single row
    (257, 512, 192),
]
DTYPES = ["float32", "bfloat16", "int8"]

# every M/K/N combination of off-tile dims the padding shim must absorb:
# sub-tile K/N, one-past-tile, odd everything, and aligned-K/ragged-M-N
UNALIGNED_SHAPES = [
    (33, 65, 127),
    (7, 30, 100),
    (65, 191, 66),
    (129, 64, 130),
    (16, 127, 64),
]


def _mats(m, k, n, dtype, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(0, 1, (m, k)).astype(np.float32)
    w = r.normal(0, 1, (k, n)).astype(np.float32)
    if dtype == "int8":
        return (x * 10).astype(np.int8), (w * 10).astype(np.int8)
    return x.astype(dtype), w.astype(dtype)


def _tol(dtype):
    return dict(atol=0, rtol=0) if dtype == "int8" else (
        dict(atol=1e-3, rtol=1e-3) if dtype == "float32" else dict(atol=0.5, rtol=0.05)
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dip_matmul_fast_path(shape, dtype):
    m, k, n = shape
    x, w = _mats(m, k, n, dtype)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = api.matmul(jnp.asarray(x), dw, backend="pallas_dip")
    want = ref.ws_matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_dip_systolic_wavefront_path(shape, dtype):
    m, k, n = shape
    x, w = _mats(m, k, n, dtype)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = api.matmul(jnp.asarray(x), dw, backend="pallas_systolic")
    want = ref.dip_systolic_ref(
        jnp.asarray(np.pad(x, [(0, 0), (0, (-k) % 64)])), dw.data
    )[..., :n]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_ws_baseline_kernel(shape):
    m, k, n = shape
    x, w = _mats(m, k, n, "float32")
    got = api.matmul(jnp.asarray(x), jnp.asarray(w), backend="ws")
    np.testing.assert_allclose(np.asarray(got), x @ w, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("shape", UNALIGNED_SHAPES)
@pytest.mark.parametrize(
    "backend", ["ws", "pallas_dip", "pallas_systolic", "dip_int8w", "dip_fp8"]
)
def test_unaligned_shape_parity_all_tiled_backends(shape, backend):
    """M/K/N not multiples of the perm tile: dispatch pads, kernels stay
    parity-exact vs their oracle, output is cropped to the logical shape."""
    m, k, n = shape
    x, w = _mats(m, k, n, "float32")
    x, w = jnp.asarray(x), jnp.asarray(w)
    xk = jnp.pad(x, [(0, 0), (0, (-k) % 64)])
    if backend in ("dip_int8w", "dip_fp8"):
        qw = api.quant.quantize(w, api.get_backend(backend).scheme)
        got = api.matmul(x, qw, backend=backend)
        oracle = (
            ref.dip_matmul_int8w_ref if backend == "dip_int8w"
            else ref.dip_matmul_fp8_ref
        )
        want = oracle(xk, qw.data, qw.scale)[..., :n]
        tol = dict(atol=1e-3, rtol=1e-3)
    else:
        dw = api.DipWeight.from_natural(w)
        got = api.matmul(x, dw, backend=backend)
        want = ref.dip_matmul_ref(xk, dw.data)[..., :n]
        tol = dict(atol=1e-3, rtol=1e-3)
    assert got.shape == (m, n)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_batched_inputs():
    r = np.random.default_rng(1)
    x = r.normal(size=(3, 5, 256)).astype(np.float32)
    w = r.normal(size=(256, 192)).astype(np.float32)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = api.matmul(jnp.asarray(x), dw, backend="pallas_dip")
    np.testing.assert_allclose(np.asarray(got), x @ w, atol=1e-3, rtol=1e-3)


def test_block_shape_sweep():
    """Kernel must be correct for every legal BlockSpec tiling."""
    m, k, n = 256, 256, 256
    x, w = _mats(m, k, n, "float32")
    p = api.DipWeight.from_natural(jnp.asarray(w)).data
    want = x @ w
    for bm in (64, 128, 256):
        for bk in (64, 128, 256):
            for bn in (64, 128, 256):
                got = dip_matmul_pallas(
                    jnp.asarray(x), p, block_m=bm, block_k=bk, block_n=bn,
                    interpret=True,
                )
                np.testing.assert_allclose(
                    np.asarray(got), want, atol=1e-3, rtol=1e-3,
                    err_msg=f"blocks ({bm},{bk},{bn})",
                )


def test_quantized_kernel_block_shape_sweep():
    """dip_matmul_q must be correct for every legal BlockSpec tiling — the
    int32 accumulation and the (M,1)x(1,N) scale epilogue are block-local,
    so no tiling may change the result beyond f32 epilogue rounding."""
    from repro.kernels.dip_matmul_q import dip_matmul_q_pallas

    m, k, n = 128, 128, 128
    x, w = _mats(m, k, n, "float32")
    qw = api.quant.quantize(jnp.asarray(w), "int8")
    want = ref.dip_matmul_int8w_ref(jnp.asarray(x), qw.data, qw.scale)
    for bm in (64, 128):
        for bk in (64, 128):
            for bn in (64, 128):
                got = dip_matmul_q_pallas(
                    jnp.asarray(x), qw.data, qw.scale,
                    block_m=bm, block_k=bk, block_n=bn, interpret=True,
                )
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4,
                    err_msg=f"blocks ({bm},{bk},{bn})",
                )


def test_quantized_kernel_int32_accumulation_is_exact():
    """The W8A8 path accumulates in int32 EXACTLY (ADiP's claim): pin every
    quantization scale to 1.0 (amax = 127 per row/column) so the kernel's
    output is the raw integer matmul — which f32 holds exactly below 2^24."""
    r = np.random.default_rng(9)
    xi = r.integers(-127, 128, (32, 128)).astype(np.float32)
    wi = r.integers(-127, 128, (128, 64)).astype(np.float32)
    xi[:, 0], wi[0, :] = 127, 127  # per-row / per-column amax -> scale 1.0
    qw = api.quant.quantize(jnp.asarray(wi), "int8")
    np.testing.assert_array_equal(np.asarray(qw.scale[..., :64]), 1.0)
    got = np.asarray(api.matmul(jnp.asarray(xi), qw, backend="dip_int8w"))
    want = xi.astype(np.int64) @ wi.astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_deshear_ablation_matches_ws_kernel():
    """fuse_deshear=False on natural weights == the WS baseline kernel."""
    m, k, n = 128, 128, 128
    x, w = _mats(m, k, n, "float32")
    a = dip_matmul_pallas(jnp.asarray(x), jnp.asarray(w), fuse_deshear=False,
                          block_m=64, block_k=64, block_n=64, interpret=True)
    b = ws_matmul_pallas(jnp.asarray(x), jnp.asarray(w),
                         block_m=64, block_k=64, block_n=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dip_format_storage_is_permutated():
    """The storage tensor really is the paper's permutation (per 64-tile)."""
    w = np.random.default_rng(2).normal(size=(128, 128)).astype(np.float32)
    p = np.asarray(api.DipWeight.from_natural(jnp.asarray(w)).data)
    for bi in range(2):
        for bj in range(2):
            blk = w[bi * 64:(bi + 1) * 64, bj * 64:(bj + 1) * 64]
            np.testing.assert_allclose(
                p[bi * 64:(bi + 1) * 64, bj * 64:(bj + 1) * 64],
                permute.permute_weights_np(blk),
            )


def test_int8_paper_precision_exactness():
    """INT8 (the paper's datatype) must be bit-exact vs int32 accumulation."""
    r = np.random.default_rng(3)
    x = r.integers(-128, 128, (64, 192)).astype(np.int8)
    w = r.integers(-128, 128, (192, 64)).astype(np.int8)
    dw = api.DipWeight.from_natural(jnp.asarray(w))
    got = np.asarray(api.matmul(jnp.asarray(x), dw, backend="pallas_dip"))
    want = x.astype(np.int32) @ w.astype(np.int32)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


def test_flash_attention_kernel_vs_dense_reference():
    """Fused flash kernel (the §Perf pair-3 lever) vs dense softmax."""
    from repro.kernels.flash_attention import flash_attention_pallas

    r = np.random.default_rng(0)
    for (bh, s, d, bq, bk) in [(4, 256, 64, 64, 64), (2, 512, 128, 128, 256)]:
        q = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32))
        k = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32))
        v = jnp.asarray(r.normal(size=(bh, s, d)).astype(np.float32))
        got = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk,
                                     causal=True, interpret=True)
        sc = jnp.einsum("bqd,bkd->bqk", q, k) * (d ** -0.5)
        sc = jnp.where(np.tril(np.ones((s, s), bool))[None], sc, -1e30)
        want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(sc, -1), v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=1e-3)
