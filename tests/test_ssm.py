"""Mamba2 SSD: chunked algorithm vs naive sequential recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import ssm, transformer as tf_model

KEY = jax.random.PRNGKey(11)


def _cfg(chunk=8):
    return ArchConfig(
        name="s", family="ssm", n_layers=1, d_model=32, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=64, ssm_state=8, ssm_headdim=16, ssm_chunk=chunk,
        remat="none", compute_dtype="float32",
    )


def _layer(cfg):
    return jax.tree_util.tree_map(lambda t: t[0], tf_model.init_params(KEY, cfg)["layers"])


def _naive_ssd_reference(x, p, cfg):
    """Token-by-token recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;
    y_t = C_t h_t + D x_t — the mathematical definition of the SSM."""
    from repro.models.layers import linear, rms_norm

    b, L, _ = x.shape
    dims = ssm.ssm_dims(cfg)
    di, h, pd, n = dims["d_inner"], dims["heads"], dims["headdim"], dims["state"]

    zxbcdt = np.asarray(linear(jnp.asarray(x), p["in_proj"],
                               compute_dtype=jnp.float32), np.float64)
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    bmat = zxbcdt[..., 2 * di:2 * di + n]
    cmat = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]

    # causal depthwise conv + silu on [x|B|C]
    xbc = np.concatenate([xin, bmat, cmat], -1)
    k = cfg.ssm_conv
    w = np.asarray(p["conv_w"], np.float64)
    bias = np.asarray(p["conv_b"], np.float64)
    padded = np.concatenate([np.zeros((b, k - 1, xbc.shape[-1])), xbc], 1)
    conv = sum(padded[:, i:i + L, :] * w[i] for i in range(k)) + bias
    conv = conv / (1 + np.exp(-conv))
    xin, bmat, cmat = conv[..., :di], conv[..., di:di + n], conv[..., di + n:]

    dt = np.log1p(np.exp(dt + np.asarray(p["dt_bias"], np.float64)))
    a = -np.exp(np.asarray(p["A_log"], np.float64))
    d = np.asarray(p["D"], np.float64)

    xh = xin.reshape(b, L, h, pd)
    hst = np.zeros((b, h, pd, n))
    ys = np.zeros((b, L, h, pd))
    for t in range(L):
        da = np.exp(dt[:, t] * a[None, :])                       # (b,h)
        hst = hst * da[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], bmat[:, t], xh[:, t]
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", hst, cmat[:, t]) + d[None, :, None] * xh[:, t]

    y = ys.reshape(b, L, di)
    zs = np.asarray(z, np.float64)
    y = y * (zs / (1 + np.exp(-zs)))
    y = np.asarray(
        rms_norm(jnp.asarray(y, jnp.float32), p["norm"], cfg.norm_eps), np.float64
    )
    out = np.asarray(
        linear(jnp.asarray(y, jnp.float32), p["out_proj"],
               compute_dtype=jnp.float32),
        np.float64,
    )
    return out, hst


def test_chunked_ssd_matches_naive_recurrence():
    cfg = _cfg(chunk=8)
    p = _layer(cfg)
    x = np.asarray(jax.random.normal(KEY, (2, 24, cfg.d_model)), np.float32) * 0.5
    got, _ = ssm.ssd_block(jnp.asarray(x), p, cfg)
    want, _ = _naive_ssd_reference(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-3, rtol=1e-2)


def test_decode_state_matches_prefill_state():
    """prefill(L tokens) then decode(1) == forward(L+1) last position."""
    cfg = _cfg(chunk=4)
    p = _layer(cfg)
    x = np.asarray(jax.random.normal(KEY, (2, 13, cfg.d_model)), np.float32) * 0.5

    cache = {k: v for k, v in ssm.init_ssm_cache(2, cfg, jnp.float32).items()}
    y_pre, cache = ssm.ssd_block(jnp.asarray(x[:, :12]), p, cfg, cache=cache)
    y_dec, cache = ssm.ssd_block(jnp.asarray(x[:, 12:13]), p, cfg, cache=cache)

    y_full, _ = ssm.ssd_block(jnp.asarray(x), p, cfg)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 12]), atol=2e-3, rtol=1e-2
    )
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :12]),
                               atol=2e-3, rtol=1e-2)
    assert int(cache["pos"]) == 13


def test_ragged_seq_padding_is_inert():
    """seq not divisible by chunk: outputs equal the chunk=seq computation."""
    cfg8 = _cfg(chunk=8)
    p = _layer(cfg8)
    x = jax.random.normal(KEY, (1, 13, cfg8.d_model)) * 0.5
    got, _ = ssm.ssd_block(x, p, cfg8)              # pads 13 -> 16
    cfg13 = _cfg(chunk=13)
    want, _ = ssm.ssd_block(x, p, cfg13)            # single chunk of 13
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=1e-2)


def test_multi_step_training_stays_finite():
    """Regression: exp-of-masked-diff once produced inf in the unselected
    where-branch, whose backward is 0*inf = NaN after enough decay range
    (caught by examples/train_lm.py, step ~10)."""
    from repro.configs import get_config
    from repro.models import transformer as tf_model
    from repro.optim import AdamW
    from repro.data import SyntheticLM

    cfg = get_config("mamba2-370m").reduced(compute_dtype="float32")
    params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=3e-4)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(tf_model.train_step_fn(cfg, opt))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=96, global_batch=4)
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = step(state, b)
        assert bool(jnp.isfinite(m["loss"])), f"NaN at step {i}"
        assert bool(jnp.isfinite(m["grad_norm"])), f"NaN grad at step {i}"
